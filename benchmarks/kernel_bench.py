"""Microbenchmarks of the Pallas kernels plus the quantized (q8) fused
round-update sweep.

Two entry points:

* ``run()`` — the compact CSV lines ``benchmarks.run`` prints alongside
  the paper tables (interpret mode on CPU: these numbers measure the
  reference semantics, not TPU runtime — the TPU story is in §Roofline);
* ``main()`` — the N×P sweep behind ``BENCH_kernels.json``: the int8
  fused round (``ops.cc_delta_update_q8``, which off-TPU dispatches to
  its vectorized XLA path) against the honest f32 comparator — the FULL
  tree-ops round a non-compressed run executes, including the O(N·P)
  ``prev_local`` roll that the int8 replay carry eliminates. Effective
  GB/s are reported against a measured same-host copy bandwidth (the
  machine-local roofline), and the per-cohort history-gather bytes give
  the sharded executor's gather traffic with and without compression.

    PYTHONPATH=src python benchmarks/kernel_bench.py \
        [--sizes 8x65536,16x262144,64x1048576] [--reps 5]
        [--cohorts 8,16,32,64] [--json BENCH_kernels.json]
        [--max-overhead 0]

``--max-overhead X`` (X > 0) turns the run into a smoke gate: exit
nonzero if at any swept size the q8 round takes more than X× the f32
round — the CI kernel-bench job pins small interpret-mode shapes with it.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_line
from repro.core.compress import quantize_rows
from repro.kernels import ops, ref


def _bench(fn, *args, iters: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


# ---------------------------------------------------------------------------
# the two round comparators
# ---------------------------------------------------------------------------


@jax.jit
def _f32_round(locals_, deltas, prev_local, trained_ever, globals_, train,
               sel):
    """One uncompressed round over flat (N, P) state, mirroring what
    ``rounds._cohort_round`` actually executes every round: the stale
    delta is computed and masked UNCONDITIONALLY (the generic round body
    builds the full ``RoundCtx``), the Algorithm-1 select/aggregate runs,
    and BOTH histories roll — Δ and the O(N·P) ``prev_local`` that the
    int8 replay carry eliminates."""
    trained = locals_ - globals_[None]
    stale = jnp.where(trained_ever[:, None] > 0,
                      prev_local - globals_[None], 0.0)
    est = deltas                          # cc replay; stale stays a dead
    del stale                             # read just like in the real round
    d = jnp.where(train[:, None] > 0, trained, est)
    aggw = (sel * train)
    g = globals_ + ((aggw[:, None] * d).sum(0)
                    / jnp.maximum(aggw.sum(), 1e-9))
    new_d = jnp.where(train[:, None] > 0, trained, deltas)
    new_prev = jnp.where(train[:, None] > 0, locals_, prev_local)
    return new_d, new_prev, g


def _q8_round(locals_, payload, scales, globals_, train, sel):
    """One int8 round through the public op (jnp path on CPU, Pallas on
    TPU): dequant→select/aggregate→requant, no ``prev_local`` at all."""
    n = locals_.shape[0]
    upd = sel * train
    ones, zeros = jnp.ones((n,)), jnp.zeros((n,))
    return ops.cc_delta_update_q8(
        locals_, payload, scales, globals_, upd, upd, upd, ones, zeros,
        ones, jnp.maximum(jnp.sum(upd), 1e-9), jnp.float32(1.0))


#: bytes touched per round (reads + writes), the effective-bandwidth
#: numerator. f32: read locals/deltas/prev_local, write deltas/prev_local
#: → 20·N·P. q8: read locals + payload, write payload → 6·N·P.
_F32_BYTES_PER_NP = 20
_Q8_BYTES_PER_NP = 6


def _copy_bandwidth_gbs(nbytes: int, reps: int) -> float:
    """Measured same-host copy bandwidth — the roofline every effective
    GB/s in the sweep is reported against (2 bytes moved per byte copied)."""
    x = jnp.zeros((max(nbytes, 1 << 20) // 4,), jnp.float32)
    t = _bench(jax.jit(lambda a: a + 1.0), x, iters=reps)
    return 2 * x.size * 4 / t / 1e9


def _bench_pair(f1, args1, f2, args2, reps: int) -> tuple[float, float]:
    """Best-of-``reps`` for two functions with their reps interleaved, so
    ambient load drift on a shared host biases neither side."""
    jax.block_until_ready(f1(*args1))
    jax.block_until_ready(f2(*args2))
    best1 = best2 = float("inf")
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(f1(*args1))
        best1 = min(best1, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(f2(*args2))
        best2 = min(best2, time.perf_counter() - t0)
    return best1, best2


def _sweep_point(n: int, p: int, reps: int, key) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    locals_ = jax.random.normal(k1, (n, p), jnp.float32)
    deltas = 0.1 * jax.random.normal(k2, (n, p), jnp.float32)
    prev = jax.random.normal(k3, (n, p), jnp.float32)
    globals_ = jnp.zeros((p,), jnp.float32)
    train = (jnp.arange(n) % 2 == 0).astype(jnp.float32)
    trained_ever = jnp.ones((n,), jnp.float32)
    sel = jnp.ones((n,), jnp.float32)
    payload, scales = quantize_rows(deltas)

    t_f32, t_q8 = _bench_pair(
        _f32_round, (locals_, deltas, prev, trained_ever, globals_, train,
                     sel),
        _q8_round, (locals_, payload, scales, globals_, train, sel), reps)
    return {
        "n": n, "p": p,
        "f32_s": t_f32, "q8_s": t_q8,
        "q8_speedup": t_f32 / t_q8,
        "f32_gbs": _F32_BYTES_PER_NP * n * p / t_f32 / 1e9,
        "q8_gbs": _Q8_BYTES_PER_NP * n * p / t_q8 / 1e9,
    }


def _history_gather_bytes(p: int, cohorts: list[int]) -> list[dict]:
    """Sharded-executor gather traffic for an M-cohort round: the f32
    carry gathers Δ + prev_local rows (8 bytes/param), the int8 replay
    carry one payload row + one f32 scale per member."""
    out = []
    for m in cohorts:
        f32 = m * p * 8
        int8 = m * (p + 4)
        out.append({"cohort": m, "f32_bytes": f32, "int8_bytes": int8,
                    "ratio": f32 / int8})
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="8x65536,16x262144,64x1048576",
                    help="comma-separated NxP sweep points")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--cohorts", default="8,16,32,64")
    ap.add_argument("--json", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_kernels.json"),
        help="write machine-readable results here ('' disables)")
    ap.add_argument("--max-overhead", type=float, default=0.0,
                    help="smoke gate: fail if q8_s > X * f32_s anywhere "
                         "(0 disables)")
    args = ap.parse_args(argv)
    sizes = [tuple(int(v) for v in s.split("x"))
             for s in args.sizes.split(",") if s]
    cohorts = [int(c) for c in args.cohorts.split(",") if c]

    key = jax.random.PRNGKey(0)
    copy_gbs = _copy_bandwidth_gbs(
        max(n * p * 4 for n, p in sizes), args.reps)
    print(f"backend={jax.default_backend()} devices={len(jax.devices())} "
          f"copy_bandwidth={copy_gbs:.1f} GB/s (best of {args.reps})")

    rows, violations = [], []
    for i, (n, p) in enumerate(sizes):
        row = _sweep_point(n, p, args.reps, jax.random.fold_in(key, i))
        row["f32_roofline_frac"] = row["f32_gbs"] / copy_gbs
        row["q8_roofline_frac"] = row["q8_gbs"] / copy_gbs
        rows.append(row)
        print(f"N={n:4d} P={p:9d}: f32 {row['f32_s'] * 1e3:8.2f} ms "
              f"({row['f32_gbs']:6.1f} GB/s) | q8 {row['q8_s'] * 1e3:8.2f} "
              f"ms ({row['q8_gbs']:6.1f} GB/s) | q8 speedup "
              f"{row['q8_speedup']:.2f}x")
        print(f"csv,kernel_q8_round,{n}x{p},{row['q8_s'] * 1e6:.0f}")
        if args.max_overhead and row["q8_s"] > args.max_overhead * row["f32_s"]:
            violations.append((n, p, row["q8_s"] / row["f32_s"]))

    gather = _history_gather_bytes(max(p for _, p in sizes), cohorts)
    for g in gather:
        print(f"history gather cohort={g['cohort']:4d}: "
              f"f32 {g['f32_bytes'] / 1e6:9.1f} MB vs int8 "
              f"{g['int8_bytes'] / 1e6:9.1f} MB ({g['ratio']:.2f}x)")

    if args.json:
        payload = {
            "bench": "kernels_q8",
            "config": {"reps": args.reps, "backend": jax.default_backend(),
                       "devices": len(jax.devices())},
            "copy_bandwidth_gbs": copy_gbs,
            "sweep": rows,
            "history_gather_bytes": gather,
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {args.json}")

    if violations:
        for n, p, ratio in violations:
            print(f"OVERHEAD VIOLATION N={n} P={p}: q8/f32 = {ratio:.2f} "
                  f"> {args.max_overhead}")
        return 1
    return 0


# ---------------------------------------------------------------------------
# the compact CSV entry points for ``benchmarks.run``
# ---------------------------------------------------------------------------


def run() -> list[str]:
    lines = []
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (1, 8, 512, 64))
    kk = jax.random.normal(k, (1, 2, 512, 64))
    v = jax.random.normal(k, (1, 2, 512, 64))
    t_ref = _bench(lambda: ref.flash_attention_ref(q, kk, v, causal=True))
    lines.append(csv_line("kernel_flash_ref_512", t_ref, "oracle"))
    a = jax.random.uniform(k, (4, 1024, 256), minval=0.5, maxval=0.99)
    b = jax.random.normal(k, (4, 1024, 256))
    h0 = jnp.zeros((4, 256))
    t_scan = _bench(lambda: ops.rglru_scan(a, b, h0))
    t_scan_ref = _bench(lambda: ref.rglru_scan_ref(a, b, h0))
    lines.append(csv_line("kernel_rglru_pallas_interp", t_scan,
                          f"ref_s={t_scan_ref:.4f}"))
    loc = jax.random.normal(k, (8, 1 << 16))
    de = jax.random.normal(k, (8, 1 << 16))
    g = jax.random.normal(k, (1 << 16,))
    tm = jnp.ones((8,))
    t_cc = _bench(lambda: ops.cc_delta_update(loc, de, g, tm, tm))
    t_cc_ref = _bench(lambda: ref.cc_delta_update_ref(loc, de, g, tm, tm))
    lines.append(csv_line("kernel_cc_update_pallas_interp", t_cc,
                          f"ref_s={t_cc_ref:.4f}"))
    # q8 vs the full f32 round at one mid-size point
    row = _sweep_point(8, 1 << 18, 3, k)
    lines.append(csv_line("kernel_cc_q8_round", row["q8_s"],
                          f"f32_s={row['f32_s']:.4f};"
                          f"speedup={row['q8_speedup']:.2f}"))
    return lines


if __name__ == "__main__":
    raise SystemExit(main())
