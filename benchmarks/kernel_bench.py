"""Microbenchmarks of the Pallas kernels (interpret mode on CPU: these
numbers measure the reference semantics, not TPU runtime — the TPU story
is in §Roofline) plus their jnp oracles for relative sanity."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_line
from repro.kernels import ops, ref


def _bench(fn, *args, iters: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def run() -> list[str]:
    lines = []
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (1, 8, 512, 64))
    kk = jax.random.normal(k, (1, 2, 512, 64))
    v = jax.random.normal(k, (1, 2, 512, 64))
    t_ref = _bench(lambda: ref.flash_attention_ref(q, kk, v, causal=True))
    lines.append(csv_line("kernel_flash_ref_512", t_ref, "oracle"))
    a = jax.random.uniform(k, (4, 1024, 256), minval=0.5, maxval=0.99)
    b = jax.random.normal(k, (4, 1024, 256))
    h0 = jnp.zeros((4, 256))
    t_scan = _bench(lambda: ops.rglru_scan(a, b, h0))
    t_scan_ref = _bench(lambda: ref.rglru_scan_ref(a, b, h0))
    lines.append(csv_line("kernel_rglru_pallas_interp", t_scan,
                          f"ref_s={t_scan_ref:.4f}"))
    loc = jax.random.normal(k, (8, 1 << 16))
    de = jax.random.normal(k, (8, 1 << 16))
    g = jax.random.normal(k, (1 << 16,))
    tm = jnp.ones((8,))
    t_cc = _bench(lambda: ops.cc_delta_update(loc, de, g, tm, tm))
    t_cc_ref = _bench(lambda: ref.cc_delta_update_ref(loc, de, g, tm, tm))
    lines.append(csv_line("kernel_cc_update_pallas_interp", t_cc,
                          f"ref_s={t_cc_ref:.4f}"))
    return lines
