"""Async executor throughput + history-store memory scaling.

Two measurements in one harness:

* **executor overhead** — the buffered-async executor at its collapse
  point (zero latency, ``buffer_size=1``) against the flat scan executor:
  structurally the same per-round work plus the dispatch/buffer/merge
  machinery, so its overhead ratio is the pure cost of the async
  bookkeeping. A non-collapse cell (buffered merges + latency) reports
  realized arrivals/s.
* **history-store scaling** — dense f32 vs sharded int8
  :class:`repro.core.history_store.HistoryStore` at parameter width P,
  swept over client counts up to N = 10⁵: carry bytes (the acceptance
  bound: int8 ≤ 30% of dense at P = 1024) and cohort gather+scatter
  throughput (rows/s), the two operations estimation replay pays per
  round.

Emits machine-readable results to ``BENCH_async.json`` (``--json`` to
change the path, empty string to disable). CI smoke-runs it on a
4-virtual-device host (``XLA_FLAGS=--xla_force_host_platform_device_
count=4``) with ``--max-overhead`` as a regression budget on the
collapse cell.

    PYTHONPATH=src python benchmarks/async_throughput.py [--clients 64]
        [--rounds 30] [--reps 3] [--buffer 4] [--latency 2.0]
        [--store-clients 1000,10000,100000] [--store-width 1024]
        [--max-overhead 2.0]
"""
import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.async_rounds import AsyncConfig, make_async_span_runner
from repro.core.history_store import HistoryStore
from repro.core.rounds import (FedConfig, init_fed_state, make_span_runner)
from repro.core.schedules import make_plan
from repro.data.federated import build_federated
from repro.data.partition import budget_law, partition_gamma
from repro.data.synthetic import make_dataset, train_test_split
from repro.models.simple import make_classifier
from repro.system.devices import make_profile, simulate_arrivals


def _block(x):
    jax.block_until_ready(jax.tree.leaves(x)[0])


def _bench_executor(args, fed, model, fd, plan, profile):
    n = args.clients
    k = jnp.full((n,), fed.local_steps, jnp.int32)
    sel = jnp.asarray(plan.selection)
    train = jnp.asarray(plan.training)

    runner = make_span_runner(model, fd, fed)
    _block(runner(init_fed_state(jax.random.PRNGKey(0), model, n),
                  sel, train, k))
    t_flat = []
    for _ in range(args.reps):
        state = init_fed_state(jax.random.PRNGKey(0), model, n)
        t0 = time.perf_counter()
        _block(runner(state, sel, train, k))
        t_flat.append(time.perf_counter() - t0)
    flat_s = min(t_flat)
    print(f"flat scan:                 {flat_s * 1e3:8.1f} ms "
          f"({n * args.rounds / flat_s:9.1f} client-rounds/s)")

    cells = []
    for label, cfg in [
            ("collapse", AsyncConfig()),
            ("buffered", AsyncConfig(buffer_size=min(args.buffer, n),
                                     latency=args.latency, jitter=0.5,
                                     staleness_decay=0.8))]:
        sched_np = simulate_arrivals(profile, np.asarray(plan.selection),
                                     buffer_size=cfg.buffer_size,
                                     latency=cfg.latency, jitter=cfg.jitter)
        sched = tuple(jnp.asarray(x) for x in sched_np)
        arun = make_async_span_runner(model, fd, fed, cfg)

        def fresh():
            from repro.core.async_rounds import init_async_carry
            st = init_fed_state(jax.random.PRNGKey(0), model, n)
            return init_async_carry(st, st["params"], n, cfg)

        _block(arun(fresh(), train, k, sched))
        times = []
        for _ in range(args.reps):
            state = fresh()
            t0 = time.perf_counter()
            _block(arun(state, train, k, sched))
            times.append(time.perf_counter() - t0)
        best = min(times)
        arrivals = int(sched_np.deliver.sum())
        overhead = best / flat_s
        cells.append({"cell": label, "buffer_size": cfg.buffer_size,
                      "latency": cfg.latency, "total_s": best,
                      "ms_per_round": best / args.rounds * 1e3,
                      "arrivals": arrivals,
                      "arrivals_per_second": arrivals / best,
                      "overhead_vs_flat": overhead})
        print(f"async {label:9s} (K={cfg.buffer_size}): "
              f"{best * 1e3:8.1f} ms ({arrivals / best:9.1f} arrivals/s, "
              f"{overhead:.2f}x flat)")
        print(f"csv,async,{label},{cfg.buffer_size},{best * 1e6:.0f}")
    return flat_s, cells


def _bench_store(args):
    """Carry bytes + cohort gather/scatter rates, dense vs int8."""
    width = args.store_width
    cohort = args.cohort
    rows_out = []
    rng = np.random.default_rng(0)
    upd = jnp.asarray(rng.standard_normal((cohort, width)), jnp.float32)
    for n in [int(v) for v in args.store_clients.split(",") if v]:
        idx = jnp.asarray(rng.choice(n, size=min(cohort, n), replace=False))
        entry = {"n_clients": n, "width": width}
        for kind in ("dense", "int8"):
            store = HistoryStore(n, width, kind=kind)
            carry = store.init()
            nbytes = HistoryStore.carry_bytes(carry)
            assert nbytes == store.nbytes()

            def step(c):
                got = store.read(c, idx)
                return store.scatter(c, idx, got + upd[:idx.shape[0]])

            step = jax.jit(step)
            carry = step(carry)               # compile + warm
            _block(carry)
            times = []
            for _ in range(args.reps):
                t0 = time.perf_counter()
                c = carry
                for _ in range(args.store_iters):
                    c = step(c)
                _block(c)
                times.append(time.perf_counter() - t0)
            best = min(times)
            rate = args.store_iters * idx.shape[0] / best
            entry[kind] = {"history_bytes": nbytes,
                           "gather_scatter_rows_per_second": rate,
                           "total_s": best}
            print(f"store {kind:5s} N={n:7d} P={width}: "
                  f"{nbytes / 1e6:9.1f} MB  ({rate:12.1f} rows/s)")
        ratio = (entry["int8"]["history_bytes"]
                 / entry["dense"]["history_bytes"])
        entry["int8_bytes_ratio"] = ratio
        print(f"csv,store,{n},{ratio:.4f}")
        rows_out.append(entry)
    return rows_out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--buffer", type=int, default=4,
                    help="K of the non-collapse async cell")
    ap.add_argument("--latency", type=float, default=2.0,
                    help="nominal latency of the non-collapse cell")
    ap.add_argument("--store-clients", default="1000,10000,100000",
                    help="comma-separated N sweep for the history store")
    ap.add_argument("--store-width", type=int, default=1024)
    ap.add_argument("--store-iters", type=int, default=10,
                    help="gather+scatter iterations per timing rep")
    ap.add_argument("--cohort", type=int, default=256,
                    help="cohort rows per gather/scatter")
    ap.add_argument("--max-overhead", type=float, default=0.0,
                    help="fail (exit 1) if the collapse cell's time "
                         "exceeds this multiple of the flat scan path "
                         "(0 = report only)")
    ap.add_argument("--json", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_async.json"),
        help="write machine-readable results here ('' disables)")
    args = ap.parse_args()

    n = args.clients
    ds = make_dataset("teacher", n=4096, dim=24, n_classes=8, seed=0)
    tr, _ = train_test_split(ds)
    fd = build_federated(tr, partition_gamma(tr, n, gamma=0.5, seed=0))
    model = make_classifier("mlp", input_shape=(24,), n_classes=8, width=8)
    p = budget_law(n, beta=4)
    plan = make_plan("adhoc", p, args.rounds, seed=0)
    fed = FedConfig(strategy="cc", local_steps=args.local_steps,
                    batch_size=32, lr=0.1)
    profile = make_profile("budget", p, seed=0)

    print(f"clients={n} rounds={args.rounds} devices={len(jax.devices())} "
          f"(best of {args.reps})")
    flat_s, exec_cells = _bench_executor(args, fed, model, fd, plan,
                                         profile)
    store_rows = _bench_store(args)

    if args.json:
        payload = {
            "bench": "async_throughput",
            "config": {"clients": n, "rounds": args.rounds,
                       "local_steps": args.local_steps, "reps": args.reps,
                       "store_width": args.store_width,
                       "cohort": args.cohort,
                       "devices": len(jax.devices())},
            "flat_scan_s": flat_s,
            "executor_cells": exec_cells,
            "history_store": store_rows,
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {args.json}")

    if args.max_overhead:
        collapse = next(c for c in exec_cells if c["cell"] == "collapse")
        if collapse["overhead_vs_flat"] > args.max_overhead:
            print(f"FAIL: collapse overhead "
                  f"{collapse['overhead_vs_flat']:.2f}x exceeds budget "
                  f"{args.max_overhead:.2f}x")
            return 1
        print(f"collapse overhead {collapse['overhead_vs_flat']:.2f}x "
              f"within budget {args.max_overhead:.2f}x")
    return 0


if __name__ == "__main__":
    sys.exit(main())
