"""Table III — CC-FedAvg(c): Strategy 3 before round τ, Strategy 2 after
(Eq. 4). Claims: CC-FedAvg(c) beats pure Strategy 2 consistently and is
competitive with default CC-FedAvg.
"""
from __future__ import annotations

from benchmarks.common import (SILO_ROUNDS, Timer, cross_silo, csv_line,
                               mean_over_seeds, run_cell)

TAU = SILO_ROUNDS // 2


def run() -> list[str]:
    lines = []
    with Timer() as t_all:
        res = {}
        for gname, gamma in {"80pct_noniid": 0.2, "50pct_noniid": 0.5}.items():
            accs = {}
            for m, tau in (("s2", 0), ("cc", 0), ("ccc", TAU)):
                acc, _ = mean_over_seeds(
                    lambda s: run_cell(cross_silo(gamma, seed=s), m,
                                       "adhoc", rounds=SILO_ROUNDS,
                                       tau=tau, seed=s)[0])
                accs[m] = acc
            res[gname] = accs
    for gname, accs in res.items():
        ok = accs["ccc"] >= accs["s2"] - 0.01
        lines.append(csv_line(
            f"table3_{gname}", t_all.seconds / len(res),
            f"s2={accs['s2']:.3f};cc={accs['cc']:.3f};"
            f"ccc={accs['ccc']:.3f};claim_ccc_beats_s2="
            f"{'PASS' if ok else 'FAIL'}"))
    return lines
