"""Uplink-channel overhead: noiseless vs exact, aircomp vs noiseless.

Three cells over the same scan span:

* **exact** — the pre-channel engine (``channel='noiseless'`` makes
  ``uplink_channel()`` return None, so the executors never even touch
  the channel code path);
* **noiseless** — identical config run again: measures that the channel
  *refactor itself* costs nothing (the acceptance gate: ≤1.2x exact);
* **aircomp** — AWGN at 20 dB + Rayleigh fading: the real cost of two
  extra PRNG draws + a fused multiply-add per round.

Emits machine-readable results to ``BENCH_channel.json`` (``--json`` to
change the path, empty string to disable). CI smoke-runs it with
``--max-overhead 1.2`` as the noiseless-vs-exact regression budget.

    PYTHONPATH=src python benchmarks/channel_overhead.py [--clients 64]
        [--rounds 30] [--reps 3] [--snr-db 20] [--max-overhead 1.2]
"""
import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.core.rounds import FedConfig, init_fed_state, make_span_runner
from repro.core.schedules import make_plan
from repro.data.federated import build_federated
from repro.data.partition import budget_law, partition_gamma
from repro.data.synthetic import make_dataset, train_test_split
from repro.models.simple import make_classifier


def _block(x):
    jax.block_until_ready(jax.tree.leaves(x)[0])


def _bench_cell(args, fed, model, fd, plan):
    n = args.clients
    k = jnp.full((n,), fed.local_steps, jnp.int32)
    sel = jnp.asarray(plan.selection)
    train = jnp.asarray(plan.training)
    runner = make_span_runner(model, fd, fed)
    _block(runner(init_fed_state(jax.random.PRNGKey(0), model, n),
                  sel, train, k))
    times = []
    for _ in range(args.reps):
        state = init_fed_state(jax.random.PRNGKey(0), model, n)
        t0 = time.perf_counter()
        _block(runner(state, sel, train, k))
        times.append(time.perf_counter() - t0)
    return min(times)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--snr-db", type=float, default=20.0)
    ap.add_argument("--max-overhead", type=float, default=0.0,
                    help="fail (exit 1) if the noiseless cell's time "
                         "exceeds this multiple of the exact baseline "
                         "(0 = report only)")
    ap.add_argument("--json", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_channel.json"),
        help="write machine-readable results here ('' disables)")
    args = ap.parse_args()

    n = args.clients
    ds = make_dataset("teacher", n=4096, dim=24, n_classes=8, seed=0)
    tr, _ = train_test_split(ds)
    fd = build_federated(tr, partition_gamma(tr, n, gamma=0.5, seed=0))
    model = make_classifier("mlp", input_shape=(24,), n_classes=8, width=8)
    plan = make_plan("adhoc", budget_law(n, beta=4), args.rounds, seed=0)

    base = dict(strategy="cc", local_steps=args.local_steps,
                batch_size=32, lr=0.1)
    cells = {}
    print(f"clients={n} rounds={args.rounds} devices={len(jax.devices())} "
          f"(best of {args.reps})")
    # "exact" and "noiseless" are the same config measured twice — the
    # gate compares two runs of the identical code path, so it bounds
    # refactor cost without rewarding or punishing machine noise
    for label, extra in [
            ("exact", {}),
            ("noiseless", dict(channel="noiseless")),
            ("aircomp", dict(channel="aircomp",
                             channel_snr_db=args.snr_db,
                             channel_fading=True))]:
        fed = FedConfig(**base, **extra)
        best = _bench_cell(args, fed, model, fd, plan)
        cells[label] = best
        print(f"{label:10s} {best * 1e3:8.1f} ms "
              f"({n * args.rounds / best:9.1f} client-rounds/s)")
        print(f"csv,channel,{label},{best * 1e6:.0f}")

    overhead_noiseless = cells["noiseless"] / cells["exact"]
    overhead_aircomp = cells["aircomp"] / cells["exact"]
    print(f"noiseless vs exact: {overhead_noiseless:.3f}x; "
          f"aircomp vs exact: {overhead_aircomp:.3f}x")

    if args.json:
        payload = {
            "bench": "channel_overhead",
            "config": {"clients": n, "rounds": args.rounds,
                       "local_steps": args.local_steps, "reps": args.reps,
                       "snr_db": args.snr_db,
                       "devices": len(jax.devices())},
            "cells_s": cells,
            "noiseless_overhead_vs_exact": overhead_noiseless,
            "aircomp_overhead_vs_exact": overhead_aircomp,
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {args.json}")

    if args.max_overhead:
        if overhead_noiseless > args.max_overhead:
            print(f"FAIL: noiseless overhead {overhead_noiseless:.2f}x "
                  f"exceeds budget {args.max_overhead:.2f}x")
            return 1
        print(f"noiseless overhead {overhead_noiseless:.2f}x within "
              f"budget {args.max_overhead:.2f}x")
    return 0


if __name__ == "__main__":
    sys.exit(main())
