"""Hierarchical two-tier executor — client-rounds/s vs edge count/period.

The two-tier executor routes every round through the edge tier: clients
train against their edge aggregator's model, edges aggregate their own
blocks, and every ``edge_period``-th round all-gathers the uploads for
the server merge. This benchmark sweeps the edge count E and the edge
period P against the flat scan executor (the single-program reference)
and reports client-rounds per second, plus the hierarchy overhead ratio
(hier time / flat time) per cell.

Emits machine-readable results to ``BENCH_hierarchy.json`` (``--json`` to
change the path, empty string to disable). CI smoke-runs it on a
4-virtual-device host mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=4``) with
``--max-overhead`` as a regression budget on the E=1 collapse cell —
structurally the flat round plus the edge-tier bookkeeping, so its
overhead is the pure cost of the hierarchy machinery.

    PYTHONPATH=src python benchmarks/hierarchy.py [--clients 64]
        [--edges 1,2,4,8] [--periods 1,5] [--rounds 30] [--reps 3]
        [--max-overhead 1.5]
"""
import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.core.hierarchy import EdgeTopology
from repro.core.rounds import (FedConfig, init_fed_state,
                               make_hierarchical_span_runner,
                               make_span_runner)
from repro.core.schedules import make_plan
from repro.data.federated import build_federated
from repro.data.partition import budget_law, partition_gamma
from repro.data.synthetic import make_dataset, train_test_split
from repro.launch.mesh import best_edge_shards
from repro.models.simple import make_classifier


def _block(state):
    jax.block_until_ready(jax.tree.leaves(state["params"])[0])


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--edges", default="1,2,4,8",
                    help="comma-separated edge counts to sweep")
    ap.add_argument("--periods", default="1,5",
                    help="comma-separated edge periods to sweep")
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--max-overhead", type=float, default=0.0,
                    help="fail (exit 1) if the E=1 cell's time exceeds "
                         "this multiple of the flat scan path (0 = "
                         "report only)")
    ap.add_argument("--json", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_hierarchy.json"),
        help="write machine-readable results here ('' disables)")
    args = ap.parse_args()
    edge_counts = [int(e) for e in args.edges.split(",") if e]
    periods = [int(p) for p in args.periods.split(",") if p]

    n = args.clients
    ds = make_dataset("teacher", n=4096, dim=24, n_classes=8, seed=0)
    tr, _ = train_test_split(ds)
    parts = partition_gamma(tr, n, gamma=0.5, seed=0)
    fd = build_federated(tr, parts)
    model = make_classifier("mlp", input_shape=(24,), n_classes=8, width=8)
    plan = make_plan("adhoc", budget_law(n, beta=4), args.rounds, seed=0)
    fed = FedConfig(strategy="cc", local_steps=args.local_steps,
                    batch_size=32, lr=0.1)
    k = jnp.full((n,), fed.local_steps, jnp.int32)
    sel = jnp.asarray(plan.selection)
    train = jnp.asarray(plan.training)

    n_dev = len(jax.devices())
    print(f"clients={n} rounds={args.rounds} devices={n_dev} "
          f"(best of {args.reps})")

    # flat scan executor: the single-program reference
    runner = make_span_runner(model, fd, fed)
    _block(runner(init_fed_state(jax.random.PRNGKey(0), model, n),
                  sel, train, k))
    t_flat = []
    for _ in range(args.reps):
        state = init_fed_state(jax.random.PRNGKey(0), model, n)
        t0 = time.perf_counter()
        _block(runner(state, sel, train, k))
        t_flat.append(time.perf_counter() - t0)
    flat_s = min(t_flat)
    flat_cps = n * args.rounds / flat_s
    print(f"flat scan:              {flat_s * 1e3:8.1f} ms "
          f"({flat_cps:9.1f} client-rounds/s)")

    rows, e1_overhead = [], None
    for e in edge_counts:
        if e > n:
            print(f"edges {e} > clients {n}, skipping")
            continue
        for period in periods:
            topo = EdgeTopology.contiguous(n, e, edge_period=period)
            shards = best_edge_shards(e)
            hier = make_hierarchical_span_runner(model, fd, fed, topo)
            s0 = init_fed_state(jax.random.PRNGKey(0), model, n,
                                topology=topo)
            _block(hier(s0, sel, train, k))
            times = []
            for _ in range(args.reps):
                state = init_fed_state(jax.random.PRNGKey(0), model, n,
                                       topology=topo)
                t0 = time.perf_counter()
                _block(hier(state, sel, train, k))
                times.append(time.perf_counter() - t0)
            best = min(times)
            cps = n * args.rounds / best
            overhead = best / flat_s
            if e == 1:
                e1_overhead = (overhead if e1_overhead is None
                               else min(e1_overhead, overhead))
            rows.append({"n_edges": e, "edge_period": period,
                         "shards": shards, "total_s": best,
                         "ms_per_round": best / args.rounds * 1e3,
                         "clients_per_second": cps,
                         "overhead_vs_flat": overhead})
            print(f"hier E={e:3d} P={period:3d} ({shards} shard"
                  f"{'s'[:shards > 1]}): {best * 1e3:8.1f} ms "
                  f"({cps:9.1f} client-rounds/s, {overhead:.2f}x flat)")
            print(f"csv,hierarchy,{e},{period},{best * 1e6:.0f}")

    if args.json:
        payload = {
            "bench": "hierarchy",
            "config": {"clients": n, "rounds": args.rounds,
                       "local_steps": args.local_steps, "reps": args.reps,
                       "devices": n_dev},
            "flat_scan_s": flat_s,
            "flat_scan_clients_per_second": flat_cps,
            "cells": rows,
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {args.json}")

    if args.max_overhead and e1_overhead is not None:
        if e1_overhead > args.max_overhead:
            print(f"FAIL: E=1 overhead {e1_overhead:.2f}x exceeds budget "
                  f"{args.max_overhead:.2f}x")
            return 1
        print(f"E=1 overhead {e1_overhead:.2f}x within budget "
              f"{args.max_overhead:.2f}x")
    return 0


if __name__ == "__main__":
    sys.exit(main())
