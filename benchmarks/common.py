"""Shared scenario builders for the paper-table benchmarks.

The paper's CIFAR/FMNIST experiments are reproduced on synthetic suites
(see DESIGN.md §8) at CPU-budget scale: what is validated is each
table/figure's *claim* (method orderings, trends), not absolute accuracy.
Every benchmark prints the scaled-down numbers next to the claim check.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core.engine import FedConfig, run_federated
from repro.core.schedules import make_plan
from repro.data.federated import FederatedData, build_federated
from repro.data.partition import (budget_law, partition_classes,
                                  partition_gamma, two_group_budget)
from repro.data.synthetic import Dataset, make_dataset, train_test_split
from repro.models.simple import Classifier, make_classifier

# scaled-down defaults (paper: N=8, T=400, K=3 epochs, CIFAR-10)
SILO_N = 8
SILO_ROUNDS = 80
SILO_K = 5
DEVICE_N = 40          # paper: 100
DEVICE_ROUNDS = 60     # paper: 400
SEEDS = (0, 1)


@dataclass
class Scenario:
    model: Classifier
    fd: FederatedData
    x_test: jnp.ndarray
    y_test: jnp.ndarray
    p: np.ndarray
    ds_train: Dataset


def cross_silo(gamma: float, *, beta: int = 4, n=SILO_N, seed: int = 0,
               dataset: str = "teacher", model: str = "mlp",
               width: int = 8) -> Scenario:
    """Table-I style: N silos, γ-heterogeneity, budget law p=(1/2)^⌊βi/N⌋."""
    ds = make_dataset(dataset, n=2048, dim=24, n_classes=8, seed=seed)
    tr, te = train_test_split(ds, seed=seed)
    parts = partition_gamma(tr, n, gamma=gamma, seed=seed)
    fd = build_federated(tr, parts)
    m = make_classifier(model, input_shape=tr.x.shape[1:], n_classes=8,
                        width=width)
    return Scenario(m, fd, jnp.asarray(te.x), jnp.asarray(te.y),
                    budget_law(n, beta), tr)


def cross_device(*, n=DEVICE_N, classes_per_client: int = 2, beta: int = 4,
                 seed: int = 0, width: int = 8) -> Scenario:
    """Table-II style: N devices, 2 classes each, random budget levels."""
    ds = make_dataset("gaussian", n=4000, dim=24, n_classes=8, seed=seed)
    tr, te = train_test_split(ds, seed=seed)
    parts = partition_classes(tr, n, classes_per_client, seed=seed)
    fd = build_federated(tr, parts)
    m = make_classifier("mlp", input_shape=tr.x.shape[1:], n_classes=8,
                        width=width)
    rng = np.random.default_rng(seed)
    p = rng.permutation(budget_law(n, beta))
    return Scenario(m, fd, jnp.asarray(te.x), jnp.asarray(te.y), p, tr)


def two_group(r: float, w: int, gamma: float = 0.1,
              seed: int = 0) -> Scenario:
    sc = cross_silo(gamma, seed=seed)
    return Scenario(sc.model, sc.fd, sc.x_test, sc.y_test,
                    two_group_budget(SILO_N, r, w), sc.ds_train)


def run_cell(sc: Scenario, strategy: str, schedule: str, *, rounds: int,
             local_steps: int = SILO_K, participation: float = 1.0,
             lr: float = 0.1, batch: int = 32, seed: int = 0,
             tau: int = 0, probe_client=None, executor: str = "scan",
             use_fused: bool = False):
    """One (method × schedule) cell. Returns (final_acc, metrics).

    ``strategy`` is any registry name (plus the ``fedavg_full`` /
    ``fedavg_dropout`` aliases that also pick their plan); eval-free spans
    run through the scan executor unless ``executor="python"``.
    """
    if strategy == "fedavg_full":
        plan = make_plan("full", np.ones_like(sc.p), rounds,
                         participation_ratio=participation, seed=seed)
        fed_strategy = "fedavg"
    elif strategy == "fedavg_dropout":
        plan = make_plan("dropout", sc.p, rounds,
                         participation_ratio=participation, seed=seed)
        fed_strategy = "dropout"
    else:
        plan = make_plan(schedule, sc.p, rounds,
                         participation_ratio=participation, seed=seed)
        fed_strategy = strategy
    fed = FedConfig(strategy=fed_strategy, local_steps=local_steps,
                    batch_size=batch, lr=lr, seed=seed,
                    tau=tau if tau else 100)
    state, metrics = run_federated(
        sc.model, sc.fd, fed, plan, x_test=sc.x_test, y_test=sc.y_test,
        eval_every=max(10, rounds // 4), probe_client=probe_client,
        executor=executor, use_fused=use_fused)
    return metrics.last("test_acc"), metrics


def mean_over_seeds(fn, seeds=SEEDS):
    vals = [fn(s) for s in seeds]
    return float(np.mean(vals)), float(np.std(vals))


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0


def csv_line(name: str, seconds: float, derived: str) -> str:
    us = seconds * 1e6
    return f"{name},{us:.0f},{derived}"
