"""Tables IV/V — budget-class skew in the cross-device setting.

The classes of training data are skewed across clients with different
compute budgets ('high': every class lives at one budget level;
'moderate': 10% of clients follow 'high'). Claims: all methods degrade
vs the random assignment of Table II, but CC-FedAvg stays the most robust
of the constrained methods (above Strategies 1/2).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (DEVICE_ROUNDS, Scenario, Timer, csv_line,
                               run_cell)
from repro.data.federated import build_federated
from repro.data.partition import skewed_budget_assignment
from repro.data.synthetic import make_dataset, train_test_split
from repro.models.simple import make_classifier


def _scenario(skew: str, seed: int = 0) -> Scenario:
    ds = make_dataset("gaussian", n=4000, dim=24, n_classes=8, seed=seed)
    tr, te = train_test_split(ds, seed=seed)
    parts, p = skewed_budget_assignment(tr, 40, 2, beta=4, skew=skew,
                                        seed=seed)
    fd = build_federated(tr, parts)
    m = make_classifier("mlp", input_shape=tr.x.shape[1:], n_classes=8,
                        width=8)
    return Scenario(m, fd, jnp.asarray(te.x), jnp.asarray(te.y), p, tr)


def run() -> list[str]:
    lines = []
    with Timer() as t_all:
        res = {}
        for skew in ("random", "high", "moderate"):
            accs = {}
            for m in ("fedavg_full", "s1", "s2", "cc"):
                acc, _ = run_cell(_scenario(skew), m, "adhoc",
                                  rounds=DEVICE_ROUNDS, participation=0.3,
                                  seed=0)
                accs[m] = float(np.asarray(acc))
            res[skew] = accs
    for skew, accs in res.items():
        robust = accs["cc"] >= max(accs["s1"], accs["s2"]) - 0.02
        lines.append(csv_line(
            f"table45_{skew}", t_all.seconds / len(res),
            ";".join(f"{m}={accs[m]:.3f}" for m in accs)
            + f";claim_cc_most_robust={'PASS' if robust else 'FAIL'}"))
    degraded = res["high"]["cc"] <= res["random"]["cc"] + 0.02
    lines.append(csv_line(
        "table45_skew_degrades", t_all.seconds,
        f"cc_random={res['random']['cc']:.3f};"
        f"cc_high={res['high']['cc']:.3f};"
        f"claim={'PASS' if degraded else 'FAIL'}"))
    return lines
