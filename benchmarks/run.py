"""Benchmark driver — one module per paper table/figure plus the roofline
table from the dry-run artifacts. Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run             # everything
    PYTHONPATH=src python -m benchmarks.run fig2 table1 # subset
"""
from __future__ import annotations

import sys
import traceback

MODULES = (
    "fig2_estimation",
    "table1_cross_silo",
    "table2_cross_device",
    "fig3_convergence",
    "fig4_fednova",
    "fig5_rw_grid",
    "fig6_efficiency",
    "table3_ccc",
    "table45_skewed",
    "kernel_bench",
    "roofline",
)


def main() -> None:
    import importlib

    want = sys.argv[1:]
    mods = [m for m in MODULES
            if not want or any(w in m for w in want)]
    print("name,us_per_call,derived")
    failures = 0
    for name in mods:
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            for line in mod.run():
                print(line, flush=True)
        except Exception as e:  # report and continue
            failures += 1
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
