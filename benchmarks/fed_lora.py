"""Federated LoRA: adapter-history memory + executor overhead.

Two measurements in one harness (ISSUE 10 tentpole acceptance):

* **adapter history scaling** — CC estimation replay on a ≥ 10⁶-param
  zoo decoder with rank-8 adapters through the async executor's int8
  :class:`repro.core.history_store.HistoryStore`. The store carries the
  ADAPTER subtree, O(N·r·d); the committed number is its carry bytes as
  a fraction of the dense N·P f32 history a non-LoRA run would pay —
  the acceptance bound is ≤ 5% (gated, exit 1). Also reports realized
  client-rounds/s on the big model.
* **executor overhead** — a rank-8 LoRA round on the simple MLP against
  the dense MLP round through the same scan executor: the adapter
  reconstruction (einsum + functional set) runs inside every local
  step, so its cost shows up directly in the round time.
  ``--max-overhead`` turns the ratio into a regression budget (the CI
  smoke gates at 1.5x).

Emits machine-readable results to ``BENCH_fed_lora.json`` (``--json`` to
change the path, empty string to disable).

    PYTHONPATH=src python benchmarks/fed_lora.py [--clients 8]
        [--rounds 6] [--reps 2] [--width 32] [--lora-rank 8]
        [--mlp-width 64] [--max-overhead 1.5]
"""
import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.async_rounds import (AsyncConfig, init_async_carry,
                                     make_async_span_runner)
from repro.core.history_store import HistoryStore
from repro.core.rounds import FedConfig, init_fed_state, make_span_runner
from repro.core.schedules import make_plan
from repro.data.federated import build_federated
from repro.data.partition import budget_law, partition_gamma
from repro.data.synthetic import make_dataset, train_test_split
from repro.models.lora import lora_classifier, lora_report
from repro.models.simple import make_classifier
from repro.models.zoo import make_zoo_classifier
from repro.system.devices import make_profile, simulate_arrivals


def _block(x):
    jax.block_until_ready(jax.tree.leaves(x)[0])


def _scenario(n, *, dim, n_classes, seed=0):
    ds = make_dataset("gaussian", n=1024, dim=dim, n_classes=n_classes,
                      seed=seed)
    tr, _ = train_test_split(ds, seed=seed)
    return build_federated(tr, partition_gamma(tr, n, gamma=0.5, seed=seed))


def _bench_adapter_history(args):
    """CC replay on the zoo decoder: int8 adapter store vs dense N·P."""
    n = args.clients
    base = make_zoo_classifier("decoder", input_shape=(16,), n_classes=8,
                               width=args.width)
    model = lora_classifier(base, jax.random.PRNGKey(0), args.lora_rank)
    rep = lora_report(base.init(jax.random.PRNGKey(0)),
                      model.init(jax.random.PRNGKey(1)))
    print(f"decoder width={args.width}: P_dense={rep['p_dense']} "
          f"P_adapter={rep['p_trainable']} "
          f"({rep['trainable_frac'] * 100:.2f}% trainable)")

    fd = _scenario(n, dim=16, n_classes=8)
    fed = FedConfig(strategy="cc", local_steps=args.local_steps,
                    batch_size=16, lr=0.1)
    cfg = AsyncConfig(history_store="int8")
    p = budget_law(n, beta=2)
    plan = make_plan("adhoc", p, args.rounds, seed=0)
    profile = make_profile("budget", p, seed=0)
    sched_np = simulate_arrivals(profile, np.asarray(plan.selection),
                                 buffer_size=cfg.buffer_size,
                                 latency=cfg.latency, jitter=cfg.jitter)
    sched = tuple(jnp.asarray(x) for x in sched_np)
    k = jnp.full((n,), fed.local_steps, jnp.int32)
    train = jnp.asarray(plan.training)
    runner = make_async_span_runner(model, fd, fed, cfg)

    def fresh():
        st = init_fed_state(jax.random.PRNGKey(0), model, n)
        return init_async_carry(st, st["params"], n, cfg,
                                needs_stale=fed.resolve().needs_stale)

    state = runner(fresh(), train, k, sched)
    _block(state)
    times = []
    for _ in range(args.reps):
        s = fresh()
        t0 = time.perf_counter()
        _block(runner(s, train, k, sched))
        times.append(time.perf_counter() - t0)
    best = min(times)
    arrivals = int(sched_np.deliver.sum())

    hist_bytes = HistoryStore.carry_bytes(state["deltas"])
    dense_bytes = 4 * n * rep["p_dense"]      # the N·P f32 history LoRA
    ratio = hist_bytes / dense_bytes          # federation never pays
    print(f"int8 adapter history:      {hist_bytes / 1e3:8.1f} kB "
          f"(dense N*P f32 would be {dense_bytes / 1e6:.1f} MB, "
          f"ratio {ratio * 100:.2f}%)")
    print(f"async span:                {best * 1e3:8.1f} ms "
          f"({arrivals / best:9.1f} client-rounds/s)")
    print(f"csv,fed_lora,history,{hist_bytes},{ratio:.5f}")
    return {"p_dense": rep["p_dense"], "p_trainable": rep["p_trainable"],
            "trainable_frac": rep["trainable_frac"],
            "lora_rank": args.lora_rank,
            "history_bytes_int8": hist_bytes,
            "history_bytes_dense_f32": dense_bytes,
            "history_bytes_ratio": ratio,
            "span_s": best, "arrivals": arrivals,
            "client_rounds_per_second": arrivals / best}


def _bench_overhead(args):
    """Rank-8 LoRA MLP round vs the dense MLP round (scan executor)."""
    n = args.clients
    fd = _scenario(n, dim=16, n_classes=8, seed=1)
    fed = FedConfig(strategy="cc", local_steps=args.local_steps,
                    batch_size=16, lr=0.1)
    plan = make_plan("adhoc", budget_law(n, beta=2), args.rounds, seed=1)
    sel, train = jnp.asarray(plan.selection), jnp.asarray(plan.training)
    k = jnp.full((n,), fed.local_steps, jnp.int32)

    dense = make_classifier("mlp", input_shape=(16,), n_classes=8,
                            width=args.mlp_width)
    lora = lora_classifier(dense, jax.random.PRNGKey(0), args.lora_rank)
    cells = {}
    for label, model in (("dense", dense), ("lora", lora)):
        runner = make_span_runner(model, fd, fed)
        _block(runner(init_fed_state(jax.random.PRNGKey(0), model, n),
                      sel, train, k))
        times = []
        for _ in range(args.reps):
            s = init_fed_state(jax.random.PRNGKey(0), model, n)
            t0 = time.perf_counter()
            _block(runner(s, sel, train, k))
            times.append(time.perf_counter() - t0)
        cells[label] = min(times)
        print(f"mlp {label:5s} round:           "
              f"{cells[label] / args.rounds * 1e3:8.2f} ms/round")
    overhead = cells["lora"] / cells["dense"]
    print(f"lora overhead vs dense:    {overhead:8.2f}x")
    print(f"csv,fed_lora,overhead,{cells['lora'] * 1e6:.0f},{overhead:.3f}")
    return {"mlp_width": args.mlp_width, "dense_s": cells["dense"],
            "lora_s": cells["lora"], "overhead_vs_dense": overhead}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--width", type=int, default=32,
                    help="zoo decoder width (d_model = 8*width; 32 -> "
                         "~1.4M dense params)")
    ap.add_argument("--lora-rank", type=int, default=8)
    ap.add_argument("--mlp-width", type=int, default=64,
                    help="width of the overhead cell's MLP")
    ap.add_argument("--max-overhead", type=float, default=0.0,
                    help="fail (exit 1) if the LoRA MLP round exceeds "
                         "this multiple of the dense round (0 = report "
                         "only)")
    ap.add_argument("--json", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_fed_lora.json"),
        help="write machine-readable results here ('' disables)")
    args = ap.parse_args()

    print(f"clients={args.clients} rounds={args.rounds} "
          f"devices={len(jax.devices())} (best of {args.reps})")
    hist = _bench_adapter_history(args)
    over = _bench_overhead(args)

    if args.json:
        payload = {
            "bench": "fed_lora",
            "config": {"clients": args.clients, "rounds": args.rounds,
                       "local_steps": args.local_steps, "reps": args.reps,
                       "width": args.width, "lora_rank": args.lora_rank,
                       "devices": len(jax.devices())},
            "adapter_history": hist,
            "overhead": over,
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {args.json}")

    # the acceptance bound is unconditional: rank-8 adapters on the 1M+
    # decoder must keep the int8 history under 5% of dense N·P f32
    if hist["history_bytes_ratio"] > 0.05:
        print(f"FAIL: history ratio {hist['history_bytes_ratio'] * 100:.2f}%"
              " exceeds the 5% acceptance bound")
        return 1
    if args.max_overhead and over["overhead_vs_dense"] > args.max_overhead:
        print(f"FAIL: lora overhead {over['overhead_vs_dense']:.2f}x "
              f"exceeds budget {args.max_overhead:.2f}x")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
