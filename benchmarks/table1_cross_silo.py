"""Table I — cross-silo comparison under data heterogeneity.

Claims validated (per γ and both schedules):
  1. CC-FedAvg ≈ FedAvg(full) (within a few points),
  2. CC-FedAvg > Strategy 1 and > Strategy 2,
  3. CC-FedAvg > FedAvg(dropout).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (SILO_ROUNDS, Timer, cross_silo, csv_line,
                               mean_over_seeds, run_cell)

GAMMAS = {"totally_noniid": 0.0, "90pct_noniid": 0.1, "80pct_noniid": 0.2,
          "50pct_noniid": 0.5, "iid": 1.0}
METHODS = ("fedavg_full", "fedavg_dropout", "s1", "s2", "cc")


def run() -> list[str]:
    lines = []
    results: dict[str, dict[str, float]] = {}
    with Timer() as t_all:
        for gname, gamma in GAMMAS.items():
            for schedule in ("round_robin", "adhoc"):
                accs = {}
                for m in METHODS:
                    acc, _ = mean_over_seeds(
                        lambda s: run_cell(cross_silo(gamma, seed=s), m,
                                           schedule, rounds=SILO_ROUNDS,
                                           seed=s)[0])
                    accs[m] = acc
                results[f"{gname}/{schedule}"] = accs
    for key, accs in results.items():
        near_full = accs["cc"] >= accs["fedavg_full"] - 0.05
        beats_s12 = accs["cc"] >= max(accs["s1"], accs["s2"]) - 0.01
        beats_drop = accs["cc"] >= accs["fedavg_dropout"] - 0.01
        ok = near_full and beats_s12 and beats_drop
        lines.append(csv_line(
            f"table1_{key}", t_all.seconds / len(results),
            ";".join(f"{m}={accs[m]:.3f}" for m in METHODS)
            + f";claims={'PASS' if ok else 'FAIL'}"))
    # aggregate claim across cells (orderings hold in the large majority)
    n_pass = sum("PASS" in ln for ln in lines)
    lines.append(csv_line(
        "table1_aggregate", t_all.seconds,
        f"cells_pass={n_pass}/{len(results)};"
        f"claim={'PASS' if n_pass >= int(0.7 * len(results)) else 'FAIL'}"))
    return lines
