"""Fig. 4 — comparison with FedNova as K (local iterations) varies.

Claims: FedNova (budget spent as fewer local iterations each round)
degrades at small K — constrained clients get K·p_i ≈ 1 iterations and
their normalized updates are too noisy — while CC-FedAvg is stable in K;
at large K FedNova catches up. (§VI-D: "FedNova … only works well in
limited scenarios".)
"""
from __future__ import annotations

from benchmarks.common import Timer, cross_silo, csv_line, run_cell

KS = (2, 16)


def run() -> list[str]:
    lines = []
    with Timer() as t_all:
        res = {}
        for k in KS:
            sc = cross_silo(gamma=0.0, seed=0)
            acc_cc, _ = run_cell(sc, "cc", "adhoc", rounds=80,
                                 local_steps=k, seed=0)
            sc = cross_silo(gamma=0.0, seed=0)
            acc_nova, _ = run_cell(sc, "fednova", "adhoc", rounds=80,
                                   local_steps=k, seed=0)
            res[k] = (acc_cc, acc_nova)
    small_k, large_k = KS[0], KS[-1]
    gap_small = res[small_k][0] - res[small_k][1]
    gap_large = res[large_k][0] - res[large_k][1]
    # CC's advantage shrinks (or flips) as K grows
    ok = gap_small >= gap_large - 0.02
    for k in KS:
        lines.append(csv_line(
            f"fig4_K{k}", t_all.seconds / len(KS),
            f"cc={res[k][0]:.3f};fednova={res[k][1]:.3f}"))
    lines.append(csv_line(
        "fig4_fednova_trend", t_all.seconds,
        f"cc_adv_smallK={gap_small:.3f};cc_adv_largeK={gap_large:.3f};"
        f"claim={'PASS' if ok else 'FAIL'}"))
    return lines
