"""Policy-decision overhead in the scan executor.

The budget-policy engine moves the train/estimate decision inside the
traced round loop (device-state advance + policy decide + ledger update
per round). This benchmark times the scan executor three ways on identical
work:

* **masks** — the seed-era mask-mode span runner (precomputed (C, N)
  train chunk, no device simulator in the carry): the baseline;
* **precompiled** — the policy engine replaying the same plan through
  ``PrecompiledPolicy`` (bit-identical decisions, in-trace);
* **energy** — a live ``EnergyAware`` policy over the simulated devices.

The acceptance target is ≤5% round-throughput overhead for the in-loop
decision machinery vs precompiled masks; all three paths run the same
local-training FLOPs, so any gap is pure decision/simulator cost.

Emits machine-readable results to ``BENCH_budget_policies.json``
(``--json`` to change the path, empty string to disable).

    PYTHONPATH=src python benchmarks/budget_policies.py [--rounds 100]
"""
import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core.budget import EnergyAware, PrecompiledPolicy
from repro.core.engine import FedConfig, init_fed_state
from repro.core.rounds import make_policy_span_runner, make_span_runner
from repro.core.schedules import make_plan
from repro.data.federated import build_federated
from repro.data.partition import budget_law, partition_gamma
from repro.data.synthetic import make_dataset, train_test_split
from repro.models.simple import make_classifier
from repro.system.devices import make_profile


def _block(state):
    jax.block_until_ready(jax.tree.leaves(state["params"])[0])


def _time_span(mk_state, run, reps):
    best = float("inf")
    for _ in range(reps):
        state = mk_state()
        t0 = time.perf_counter()
        _block(run(state))
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--json", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_budget_policies.json"),
        help="write machine-readable results here ('' disables)")
    args = ap.parse_args()

    ds = make_dataset("teacher", n=2048, dim=24, n_classes=8, seed=0)
    tr, _ = train_test_split(ds)
    parts = partition_gamma(tr, args.clients, gamma=0.5, seed=0)
    fd = build_federated(tr, parts)
    model = make_classifier("mlp", input_shape=(24,), n_classes=8, width=8)
    p = budget_law(args.clients, beta=4)
    plan = make_plan("adhoc", p, args.rounds, seed=0)
    fed = FedConfig(strategy="cc", local_steps=args.local_steps,
                    batch_size=32, lr=0.1)
    k = jnp.full((args.clients,), fed.local_steps, jnp.int32)
    sel = jnp.asarray(plan.selection)
    train = jnp.asarray(plan.training)
    profile = make_profile("budget", p, load_mean=0.3, load_jitter=0.2,
                           seed=0)
    precompiled = PrecompiledPolicy.from_plan(plan)
    energy = EnergyAware()

    key = jax.random.PRNGKey(0)
    n = fd.n_clients
    mask_run = make_span_runner(model, fd, fed)
    pre_run = make_policy_span_runner(model, fd, fed, precompiled, profile)
    egy_run = make_policy_span_runner(model, fd, fed, energy, profile)

    variants = {
        "masks": (lambda: init_fed_state(key, model, n),
                  lambda s: mask_run(s, sel, train, k)),
        "precompiled": (
            lambda: init_fed_state(key, model, n, policy=precompiled,
                                   profile=profile),
            lambda s: pre_run(s, sel, k)),
        "energy": (
            lambda: init_fed_state(key, model, n, policy=energy,
                                   profile=profile),
            lambda s: egy_run(s, sel, k)),
    }
    # warmup / compile every path before timing
    for mk, run in variants.values():
        _block(run(mk()))

    times = {name: _time_span(mk, run, args.reps)
             for name, (mk, run) in variants.items()}
    base = times["masks"]
    print(f"rounds={args.rounds} clients={args.clients} "
          f"K={args.local_steps} (best of {args.reps})")
    for name, t in times.items():
        over = (t - base) / base
        print(f"{name:<12}: {t * 1e3:8.1f} ms total "
              f"({t / args.rounds * 1e3:6.3f} ms/round, "
              f"overhead {over:+6.1%})")
        print(f"csv,budget_policies,{name},{t * 1e6:.0f}")
    if args.json:
        payload = {
            "bench": "budget_policies",
            "config": {"rounds": args.rounds, "clients": args.clients,
                       "local_steps": args.local_steps, "reps": args.reps},
            "masks_s": times["masks"],
            "precompiled_s": times["precompiled"],
            "energy_s": times["energy"],
            "precompiled_overhead_frac":
                (times["precompiled"] - base) / base,
            "energy_overhead_frac": (times["energy"] - base) / base,
            "target_overhead_frac": 0.05,
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
