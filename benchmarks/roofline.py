"""Roofline table (deliverable g) — reads the dry-run JSON records and
emits one CSV line per (arch × shape × mesh) with the three terms, the
dominant bottleneck, and the useful-FLOPs ratio. Source of EXPERIMENTS.md
§Roofline.

Also folds in the committed ``BENCH_kernels.json`` (see
``benchmarks/kernel_bench.py``): one line per q8-vs-f32 sweep point with
effective GB/s against the measured same-host copy-bandwidth roofline.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Timer, csv_line

RESULT_DIRS = ("results/dryrun_1pod_opt", "results/dryrun_2pod_opt",
               "results/dryrun_ccround_opt", "results/perf")

_KERNEL_BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_kernels.json")


def q8_roofline_lines(path: str = _KERNEL_BENCH_JSON) -> list[str]:
    """Roofline rows for the quantized round-update sweep, from the
    committed kernel-bench JSON (empty if it has not been generated)."""
    if not os.path.exists(path):
        return []
    with open(path) as fh:
        bench = json.load(fh)
    roof = bench.get("copy_bandwidth_gbs", 0.0)
    lines = []
    for row in bench.get("sweep", []):
        lines.append(csv_line(
            f"roofline_q8_round_{row['n']}x{row['p']}", row["q8_s"],
            f"q8_gbs={row['q8_gbs']:.2f};f32_gbs={row['f32_gbs']:.2f};"
            f"copy_gbs={roof:.2f};"
            f"q8_roofline_frac={row.get('q8_roofline_frac', 0):.3f};"
            f"q8_speedup={row['q8_speedup']:.2f}"))
    return lines


def load_records() -> list[dict]:
    recs = []
    for d in RESULT_DIRS:
        for f in sorted(glob.glob(os.path.join(d, "*.json"))):
            with open(f) as fh:
                recs.append(json.load(fh))
    return recs


def run() -> list[str]:
    with Timer() as t:
        recs = load_records()
    lines = []
    n_ok = 0
    for r in recs:
        if not r.get("ok"):
            lines.append(csv_line(
                f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}", 0,
                f"FAILED:{r.get('error', '?')[:60]}"))
            continue
        n_ok += 1
        rf = r["roofline"]
        step = "" if r.get("step") in ("auto", None) \
            else "_" + r["step"].replace("round", "")
        lines.append(csv_line(
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}{step}",
            t.seconds / max(1, len(recs)),
            f"compute_s={rf['compute_s']:.4f};memory_s={rf['memory_s']:.4f};"
            f"collective_s={rf['collective_s']:.4f};"
            f"bottleneck={rf['bottleneck']};"
            f"useful_flops={r.get('useful_flops_ratio', 0):.3f}"))
    lines.extend(q8_roofline_lines())
    lines.append(csv_line("roofline_summary", t.seconds,
                          f"records_ok={n_ok}/{len(recs)}"))
    return lines
