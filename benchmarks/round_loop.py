"""Scan executor vs per-round Python loop — the dispatch-overhead benchmark.

The classic federated driver dispatches one jitted round per plan row and
syncs with the host every round; at the paper's model sizes the round-trip
dominates the round's FLOPs. The scan executor stacks the (T, N) plan masks
and runs each eval-free span as ONE ``lax.scan`` program. This benchmark
times both on identical work and prints the speedup.

Emits machine-readable results to ``BENCH_round_loop.json`` (``--json`` to
change the path, empty string to disable) so CI and perf-trajectory tooling
can diff runs.

    PYTHONPATH=src python benchmarks/round_loop.py [--rounds 100] [--reps 3]
"""
import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import FedConfig, init_fed_state
from repro.core.rounds import make_round_fn, make_span_runner
from repro.core.schedules import make_plan
from repro.data.federated import build_federated
from repro.data.partition import budget_law, partition_gamma
from repro.data.synthetic import make_dataset, train_test_split
from repro.models.simple import make_classifier


def _block(state):
    jax.block_until_ready(jax.tree.leaves(state["params"])[0])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--json", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_round_loop.json"),
        help="write machine-readable results here ('' disables)")
    args = ap.parse_args()

    ds = make_dataset("teacher", n=2048, dim=24, n_classes=8, seed=0)
    tr, _ = train_test_split(ds)
    parts = partition_gamma(tr, args.clients, gamma=0.5, seed=0)
    fd = build_federated(tr, parts)
    model = make_classifier("mlp", input_shape=(24,), n_classes=8, width=8)
    p = budget_law(args.clients, beta=4)
    plan = make_plan("adhoc", p, args.rounds, seed=0)
    fed = FedConfig(strategy="cc", local_steps=args.local_steps,
                    batch_size=32, lr=0.1)
    k = jnp.full((args.clients,), fed.local_steps, jnp.int32)
    sel = jnp.asarray(plan.selection)
    train = jnp.asarray(plan.training)

    round_fn = make_round_fn(model, fd, fed)
    runner = make_span_runner(model, fd, fed)

    # warmup / compile both paths
    s0 = init_fed_state(jax.random.PRNGKey(0), model, fd.n_clients)
    _block(round_fn(s0, sel[0], train[0], k))
    _block(runner(s0, sel, train, k))

    t_loop = []
    for _ in range(args.reps):
        state = init_fed_state(jax.random.PRNGKey(0), model, fd.n_clients)
        t0 = time.perf_counter()
        for t in range(args.rounds):
            state = round_fn(state, sel[t], train[t], k)
        _block(state)
        t_loop.append(time.perf_counter() - t0)

    t_scan = []
    for _ in range(args.reps):
        state = init_fed_state(jax.random.PRNGKey(0), model, fd.n_clients)
        t0 = time.perf_counter()
        state = runner(state, sel, train, k)
        _block(state)
        t_scan.append(time.perf_counter() - t0)

    loop_s, scan_s = min(t_loop), min(t_scan)
    per_round_loop = loop_s / args.rounds * 1e3
    per_round_scan = scan_s / args.rounds * 1e3
    print(f"rounds={args.rounds} clients={args.clients} "
          f"K={args.local_steps} (best of {args.reps})")
    print(f"python loop : {loop_s * 1e3:8.1f} ms total "
          f"({per_round_loop:6.3f} ms/round)")
    print(f"lax.scan    : {scan_s * 1e3:8.1f} ms total "
          f"({per_round_scan:6.3f} ms/round)")
    print(f"speedup     : {loop_s / scan_s:8.2f}x")
    print(f"csv,round_loop,python,{loop_s * 1e6:.0f}")
    print(f"csv,round_loop,scan,{scan_s * 1e6:.0f}")
    if args.json:
        payload = {
            "bench": "round_loop",
            "config": {"rounds": args.rounds, "clients": args.clients,
                       "local_steps": args.local_steps, "reps": args.reps},
            "python_loop_s": loop_s,
            "scan_s": scan_s,
            "python_loop_ms_per_round": per_round_loop,
            "scan_ms_per_round": per_round_scan,
            "speedup": loop_s / scan_s,
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
