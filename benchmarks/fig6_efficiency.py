"""Fig. 6 — computation-efficient FL: CC-FedAvg(r=1) vs FedAvg at equal
compute (§V, §VI-F).

Equal-compute comparison: CC-FedAvg(r=1, W) for T rounds performs T/W
rounds' worth of gradient work — compare against FedAvg run for T/W
rounds. Claims: for moderate W (≤4) CC-FedAvg(r=1) ≥ FedAvg(T/W); the
synchronized-skip schedule (≈FedOpt) is much worse than ad-hoc.
"""
from __future__ import annotations

from benchmarks.common import Timer, csv_line, run_cell, two_group

T = 80
WS = (2, 4)


def run() -> list[str]:
    lines = []
    with Timer() as t_all:
        res = {}
        for w in WS:
            sc = two_group(1.0, w, seed=0)
            cc, _ = run_cell(sc, "cc", "adhoc", rounds=T, seed=0)
            fa, _ = run_cell(sc, "fedavg_full", "adhoc", rounds=T // w,
                             seed=0)
            sync, _ = run_cell(sc, "cc", "sync", rounds=T, seed=0)
            res[w] = (cc, fa, sync)
    ok = all(res[w][0] >= res[w][1] - 0.03 for w in WS) and \
        all(res[w][2] <= res[w][0] + 0.02 for w in WS)
    for w in WS:
        cc, fa, sync = res[w]
        lines.append(csv_line(
            f"fig6_W{w}", t_all.seconds / len(WS),
            f"cc_r1_T{T}={cc:.3f};fedavg_T{T // w}={fa:.3f};"
            f"sync_fedopt_like={sync:.3f}"))
    lines.append(csv_line(
        "fig6_efficiency_claim", t_all.seconds,
        f"claim={'PASS' if ok else 'FAIL'}"))
    return lines
