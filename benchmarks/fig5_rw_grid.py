"""Fig. 5 — CC-FedAvg performance over the (r, W) grid.

Claims: performance is essentially stable in r and W except when both are
extreme (r=1, W=16 degrades sharply — most updates are guesses from stale
information); moderate (r, W) costs almost nothing.
"""
from __future__ import annotations

from benchmarks.common import Timer, csv_line, run_cell, two_group

GRID = ((0.5, 2), (0.5, 8), (1.0, 2), (1.0, 16))


def run() -> list[str]:
    lines = []
    with Timer() as t_all:
        base_sc = two_group(0.0, 1, seed=0)
        base, _ = run_cell(base_sc, "fedavg_full", "adhoc", rounds=80,
                           seed=0)
        res = {}
        for r, w in GRID:
            sc = two_group(r, w, seed=0)
            acc, _ = run_cell(sc, "cc", "adhoc", rounds=80, seed=0)
            res[(r, w)] = acc
    mild = [res[(0.5, 2)], res[(0.5, 8)], res[(1.0, 2)]]
    extreme = res[(1.0, 16)]
    ok = (min(mild) >= base - 0.07) and (extreme <= min(mild) + 0.02)
    for (r, w), acc in res.items():
        lines.append(csv_line(f"fig5_r{r}_W{w}",
                              t_all.seconds / (len(GRID) + 1),
                              f"acc={acc:.3f};fedavg={base:.3f}"))
    lines.append(csv_line(
        "fig5_rw_claim", t_all.seconds,
        f"mild_min={min(mild):.3f};extreme_r1W16={extreme:.3f};"
        f"fedavg={base:.3f};claim={'PASS' if ok else 'FAIL'}"))
    return lines
