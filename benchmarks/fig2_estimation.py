"""Fig. 2 — deviation of Strategy-2/3 estimates from the true local model.

Claim: the Strategy-3 estimate (x_t + Δ_{t−1}) is closer to the truly
trained model than Strategy 2's stale model (x_{t−1,K}), Euclidean-wise,
especially in early training; its moving direction also has higher cosine
alignment with the true update.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, cross_silo, csv_line, run_cell


def run() -> list[str]:
    with Timer() as t:
        sc = cross_silo(gamma=0.5, seed=0)
        _, metrics = run_cell(sc, "cc", "adhoc", rounds=60, probe_client=0)
        e2 = np.array(metrics.series("euclid_s2"))
        e3 = np.array(metrics.series("euclid_s3"))
        c2 = np.array(metrics.series("cos_s2"))
        c3 = np.array(metrics.series("cos_s3"))
    early = slice(1, 20)
    s3_closer_early = float(np.mean(e3[early] < e2[early]))
    s3_aligned = float(np.mean(c3 > c2))
    claim = s3_closer_early >= 0.5 and float(np.mean(c3[early])) > \
        float(np.mean(c2[early]))
    return [
        csv_line("fig2_estimation", t.seconds,
                 f"s3_closer_early_frac={s3_closer_early:.2f};"
                 f"cos_s3={np.mean(c3):.3f};cos_s2={np.mean(c2):.3f};"
                 f"s3_better_cos_frac={s3_aligned:.2f};"
                 f"claim_s3_beats_s2={'PASS' if claim else 'FAIL'}")
    ]
