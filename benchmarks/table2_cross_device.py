"""Table II — cross-device comparison over server participation ratios.

Claims: CC-FedAvg within ~3 points of FedAvg(full) and above Strategy 1/2
and FedAvg(dropout,last) across participation ratios; all methods
stabilize as participation grows.
"""
from __future__ import annotations

from benchmarks.common import (Timer, cross_device, csv_line,
                               mean_over_seeds, run_cell)

RATIOS = (0.2, 0.4)
ROUNDS = 120          # low-participation orderings need more rounds to
METHODS = ("fedavg_full", "fedavg_dropout", "s1", "s2", "cc")  # stabilize


def run() -> list[str]:
    lines = []
    with Timer() as t_all:
        results = {}
        for ratio in RATIOS:
            accs = {}
            for m in METHODS:
                acc, _ = mean_over_seeds(
                    lambda s: run_cell(cross_device(seed=s), m, "adhoc",
                                       rounds=ROUNDS,
                                       participation=ratio, seed=s)[0])
                accs[m] = acc
            results[ratio] = accs
    for ratio, accs in results.items():
        ok = (accs["cc"] >= accs["fedavg_full"] - 0.05
              and accs["cc"] >= max(accs["s1"], accs["s2"]) - 0.01
              and accs["cc"] >= accs["fedavg_dropout"] - 0.01)
        lines.append(csv_line(
            f"table2_part{int(ratio * 100)}", t_all.seconds / len(results),
            ";".join(f"{m}={accs[m]:.3f}" for m in METHODS)
            + f";claims={'PASS' if ok else 'FAIL'}"))
    return lines
