"""Fig. 3 — convergence curves under 90% non-IID (γ=0.1).

Claims: CC-FedAvg's curve tracks FedAvg(full) closely (same convergence
rate, Corollary 1); Strategy 1 is unstable (high round-to-round variance);
Strategy 2 converges but below FedAvg/CC.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import SILO_ROUNDS, Timer, cross_silo, csv_line, \
    run_cell


def _acc_series(metrics):
    return np.array(metrics.series("test_acc"))


def run() -> list[str]:
    with Timer() as t:
        curves = {}
        for m in ("fedavg_full", "s1", "s2", "cc"):
            sc = cross_silo(gamma=0.1, seed=0)
            _, metrics = run_cell(sc, m, "adhoc", rounds=SILO_ROUNDS,
                                  seed=0)
            curves[m] = _acc_series(metrics)
    final_gap = float(curves["fedavg_full"][-1] - curves["cc"][-1])
    s1_var = float(np.std(np.diff(curves["s1"])))
    cc_var = float(np.std(np.diff(curves["cc"])))
    s2_below = float(curves["cc"][-1] - curves["s2"][-1])
    ok = final_gap < 0.06 and s2_below > -0.02
    return [csv_line(
        "fig3_convergence", t.seconds,
        f"gap_cc_vs_full={final_gap:.3f};s1_step_std={s1_var:.3f};"
        f"cc_step_std={cc_var:.3f};cc_minus_s2={s2_below:.3f};"
        f"claim={'PASS' if ok else 'FAIL'}")]
