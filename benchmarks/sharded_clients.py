"""Sharded large-cohort executor — clients-per-second vs cohort size.

CC-FedAvg targets numerous IoT devices: N clients far exceeding the
devices available, with only an M-client cohort participating per round.
The sharded executor gathers each round's cohort, ``shard_map``s it over
the ``clients`` mesh axis and scatters updated history back; this
benchmark sweeps the cohort size and reports client-rounds per second,
plus the full-federation scan executor as the single-device reference.

Emits machine-readable results to ``BENCH_sharded_clients.json``
(``--json`` to change the path, empty string to disable). CI smoke-runs it
on a 4-virtual-device host mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=4``); on real
multi-device hosts the mesh picks up every visible device.

    PYTHONPATH=src python benchmarks/sharded_clients.py [--clients 64]
        [--cohorts 8,16,32,64] [--rounds 30] [--reps 3]
"""
import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core.rounds import (FedConfig, init_fed_state,
                               make_sharded_span_runner, make_span_runner)
from repro.core.schedules import make_plan
from repro.data.federated import CohortSampler, build_federated
from repro.data.partition import budget_law, partition_gamma
from repro.data.synthetic import make_dataset, train_test_split
from repro.launch.mesh import best_client_shards
from repro.models.simple import make_classifier


def _block(state):
    jax.block_until_ready(jax.tree.leaves(state["params"])[0])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--cohorts", default="8,16,32,64",
                    help="comma-separated cohort sizes to sweep")
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--width", type=int, default=16,
                    help="client model width: sized so per-client work "
                         "(not per-round dispatch) dominates, which is the "
                         "regime the cohort-scaling comparison is about")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--cooldown", type=float, default=0.0,
                    help="idle seconds before every timed call, letting a "
                         "sustained-turbo host recover its clock so each "
                         "measurement starts from the same DVFS state")
    ap.add_argument("--json", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_sharded_clients.json"),
        help="write machine-readable results here ('' disables)")
    args = ap.parse_args()
    cohorts = [int(c) for c in args.cohorts.split(",") if c]

    n = args.clients
    ds = make_dataset("teacher", n=4096, dim=24, n_classes=8, seed=0)
    tr, _ = train_test_split(ds)
    parts = partition_gamma(tr, n, gamma=0.5, seed=0)
    fd = build_federated(tr, parts)
    model = make_classifier("mlp", input_shape=(24,), n_classes=8,
                            width=args.width)
    plan = make_plan("adhoc", budget_law(n, beta=4), args.rounds, seed=0)
    fed = FedConfig(strategy="cc", local_steps=args.local_steps,
                    batch_size=32, lr=0.1)
    k = jnp.full((n,), fed.local_steps, jnp.int32)
    sel = jnp.asarray(plan.selection)
    train = jnp.asarray(plan.training)

    n_dev = len(jax.devices())
    print(f"clients={n} rounds={args.rounds} devices={n_dev} "
          f"(best of {args.reps})")

    # full-federation scan executor: the single-program reference
    runner = make_span_runner(model, fd, fed)
    s0 = init_fed_state(jax.random.PRNGKey(0), model, n)
    _block(runner(s0, sel, train, k))
    t_scan = []
    for _ in range(args.reps):
        state = init_fed_state(jax.random.PRNGKey(0), model, n)
        t0 = time.perf_counter()
        _block(runner(state, sel, train, k))
        t_scan.append(time.perf_counter() - t0)
    scan_s = min(t_scan)
    scan_cps = n * args.rounds / scan_s
    print(f"scan (full federation): {scan_s * 1e3:8.1f} ms "
          f"({scan_cps:9.1f} client-rounds/s)")

    # Equal-work sweep: every cohort size runs the SAME total number of
    # client-rounds per timed call (rounds scale inversely with cohort
    # size). Equal call durations keep the sustained-AVX downclock state
    # of a shared single-core host identical across sizes — with a fixed
    # round count the cohort-64 call runs ~2× longer than cohort-32 and
    # finishes at a lower clock, which reads as a phantom scaling
    # regression. Compile + warm every size first, then interleave the
    # timed reps (ping-pong order) so ambient load drift biases no size.
    work = n * args.rounds                       # client-rounds per call
    runners = []
    for m in cohorts:
        if m > n:
            print(f"cohort {m} > clients {n}, skipping")
            continue
        rounds_m = max(1, work // m)
        plan_m = make_plan("adhoc", budget_law(n, beta=4), rounds_m, seed=0)
        xs = (jnp.asarray(plan_m.selection), jnp.asarray(plan_m.training),
              jnp.asarray(CohortSampler(n, m, seed=0).indices(rounds_m)))
        sharded = make_sharded_span_runner(model, fd, fed, cohort_size=m)
        s0 = init_fed_state(jax.random.PRNGKey(0), model, n)
        _block(sharded(s0, xs[0], xs[1], k, xs[2]))
        runners.append((m, rounds_m, sharded, xs))
    best = {m: float("inf") for m, _, _, _ in runners}
    for r in range(args.reps):
        order = runners if r % 2 == 0 else runners[::-1]
        for m, rounds_m, sharded, xs in order:
            if args.cooldown:
                time.sleep(args.cooldown)
            state = init_fed_state(jax.random.PRNGKey(0), model, n)
            t0 = time.perf_counter()
            _block(sharded(state, xs[0], xs[1], k, xs[2]))
            best[m] = min(best[m], time.perf_counter() - t0)

    rows = []
    for m, rounds_m, _, _ in runners:
        shards = best_client_shards(m)
        cps = m * rounds_m / best[m]
        rows.append({"cohort_size": m, "shards": shards,
                     "rounds": rounds_m, "total_s": best[m],
                     "ms_per_round": best[m] / rounds_m * 1e3,
                     "clients_per_second": cps})
        print(f"sharded cohort={m:5d} ({shards} shard{'s'[:shards > 1]}, "
              f"{rounds_m} rounds): {best[m] * 1e3:8.1f} ms "
              f"({cps:9.1f} client-rounds/s)")
        print(f"csv,sharded_clients,{m},{best[m] * 1e6:.0f}")

    if args.json:
        payload = {
            "bench": "sharded_clients",
            "config": {"clients": n, "rounds": args.rounds,
                       "local_steps": args.local_steps,
                       "width": args.width, "reps": args.reps,
                       "cooldown_s": args.cooldown, "devices": n_dev},
            "scan_full_s": scan_s,
            "scan_full_clients_per_second": scan_cps,
            "cohorts": rows,
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
