"""Session lifecycle hooks.

The callback protocol replaces the ``verbose`` / ``probe_client`` keyword
special cases of the old ``run_federated`` monolith: a callback receives
the live :class:`~repro.api.session.Session` and may read its state or
record extra metrics through ``session.metrics``.

Hooks:

* ``on_round_end(session, t)``   — after round ``t`` completes (``t`` is the
  1-based count of completed rounds). Under the scan executor this fires at
  span boundaries only (mid-span rounds never touch the host); callbacks
  that must observe *every* round set ``needs_python_loop = True`` and the
  session falls back to the per-round executor.
* ``on_eval(session, t, acc)``   — after each test-set evaluation.
* ``on_checkpoint(session, t, path)`` — after ``session.save()``.
"""
from __future__ import annotations

from repro.utils.logging import log


class Callback:
    """Base class: every hook is a no-op; override what you need."""

    #: set True when the callback must run between *consecutive* rounds —
    #: the session then uses the per-round python executor for correctness
    needs_python_loop: bool = False

    #: request a host sync (and an ``on_round_end`` firing) every N rounds;
    #: the scan executor splits its spans at these rounds so the callback
    #: keeps its cadence without forcing the per-round loop
    sync_every: int | None = None

    def on_round_end(self, session, t: int) -> None:
        pass

    def on_eval(self, session, t: int, acc: float) -> None:
        pass

    def on_checkpoint(self, session, t: int, path: str) -> None:
        pass


class VerboseLogger(Callback):
    """The old ``verbose=True``: one log line per evaluation."""

    def on_eval(self, session, t, acc):
        log(f"round {t}/{session.plan.rounds}",
            strategy=session.fed.strategy, acc=f"{acc:.4f}")


class ProbeCallback(Callback):
    """The old ``probe_client=i``: Fig.-2 estimation-quality probes.

    Records the distance between the estimated local models (Strategies
    2/3) and the true locally-trained model for one client, every round.
    Matches the legacy cadence exactly: the probe of the monolith ran at
    the *start* of round t for t ≥ 1, which is the end of round t — both
    see the same post-round state and record at step t.
    """

    needs_python_loop = True

    def __init__(self, client: int):
        self.client = client
        self._probe = None

    def on_round_end(self, session, t):
        if t >= session.plan.rounds:     # legacy loop never probed after
            return                       # the final round
        if self._probe is None:
            from repro.core.engine import make_probe_fn
            self._probe = make_probe_fn(session.model, session.data,
                                        session.fed, self.client)
        import jax
        pk = jax.random.fold_in(session.state["key"], 1234)
        pm = self._probe(session.state, pk)
        session.metrics.record(t, **{k: float(v) for k, v in pm.items()})


class CheckpointCallback(Callback):
    """Periodic full-state checkpointing through the session's manager."""

    def __init__(self, every: int):
        if every < 1:
            raise ValueError(f"every must be >= 1, got {every}")
        self.every = every
        self.sync_every = every

    def on_round_end(self, session, t):
        if t % self.every == 0 and t < session.plan.rounds:
            session.save()               # final-round save is the caller's
