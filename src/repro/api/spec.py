"""Declarative, serializable experiment specifications.

An :class:`ExperimentSpec` is the single source of truth for a federated
run: dataset + partition + budget law + model + :class:`FedConfig` fields +
plan kind + eval cadence, all as plain scalars, so a run is reproducible
from its spec alone. ``to_dict``/``from_dict`` round-trip exactly (pinned
by test) and ``save``/``load`` move specs through JSON files — the unit of
work for the sweep runner (:mod:`repro.api.sweep`) and the ``python -m
repro`` CLI.

``build()`` materializes the spec into the concrete objects the round
executors consume (model, stacked client data, plan, test split); it is
deterministic in ``seed``.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.async_rounds import (STALENESS_SCHEDULES, AsyncConfig)
from repro.core.budget import POLICY_KINDS, BudgetPolicy, make_policy
from repro.core.channel import CHANNEL_KINDS
from repro.core.hierarchy import TOPOLOGY_KINDS, EdgeTopology
from repro.core.history_store import STORE_KINDS
from repro.core.rounds import COMPRESS_KINDS, EXECUTORS, FedConfig
from repro.core.schedules import Plan, make_plan
from repro.data.federated import FederatedData, build_federated
from repro.data.partition import (budget_law, partition_classes,
                                  partition_gamma, two_group_budget)
from repro.data.synthetic import make_dataset, train_test_split
from repro.models.lora import lora_classifier
from repro.models.simple import Classifier, make_classifier
from repro.models.zoo import ZOO_KINDS, make_zoo_classifier
from repro.system.devices import (PROFILE_KINDS, DeviceProfile,
                                  edge_scaled_profile, make_profile)

#: schema version embedded in serialized specs; bump on breaking changes
#: (v2: runtime budget policies + device-profile fields; v3: two-tier
#: edge topologies — topology/n_edges/edge_period/edge_speed/edge_harvest;
#: v4: int8 Δ-history compression — compress; v5: async executor —
#: async_buffer/staleness_decay/staleness_schedule/async_latency/
#: async_jitter/history_store; v6: fedprox/feddyn hyperparameters +
#: uplink channel — prox_mu/feddyn_alpha/channel/channel_snr_db/
#: channel_fading; v7: federated LoRA over the model zoo —
#: lora_rank/freeze_base + the decoder|moe|xlstm model kinds)
SPEC_VERSION = 7

#: first spec version each non-v1 field appeared in — ``from_dict`` uses
#: this to reject a field that postdates the version a spec declares with
#: a precise message instead of an opaque ``TypeError`` from ``cls(**d)``
_FIELD_INTRO = {
    **{f: 2 for f in ("policy", "device_profile", "energy_capacity",
                      "energy_init", "harvest_scale", "load_mean",
                      "load_rho", "load_jitter", "deadline", "adapt_eta")},
    **{f: 3 for f in ("topology", "n_edges", "edge_period", "edge_speed",
                      "edge_harvest")},
    "compress": 4,
    **{f: 5 for f in ("async_buffer", "staleness_decay",
                      "staleness_schedule", "async_latency",
                      "async_jitter", "history_store")},
    **{f: 6 for f in ("channel", "channel_snr_db", "channel_fading",
                      "prox_mu", "feddyn_alpha")},
    **{f: 7 for f in ("lora_rank", "freeze_base")},
}

# choice tables: every registry-backed one is imported from its registry so
# registering a new kind there makes it reachable here (and in the CLI) —
# never restate those literals
_COMPRESS = COMPRESS_KINDS
#: "simple" is an alias for "mlp" — the spec-v7 surface names the simple
#: (dense-federable) family in contrast to the zoo kinds
_SIMPLE_MODELS = ("mlp", "cnn", "resnet18", "simple")

_DATASETS = ("gaussian", "teacher", "image")
_PARTITIONS = ("gamma", "classes")
_BUDGETS = ("power", "two_group", "uniform", "explicit")
_MODELS = _SIMPLE_MODELS + ZOO_KINDS
_SCHEDULES = ("adhoc", "round_robin", "sync", "dropout", "full")
_EXECUTORS = EXECUTORS
_DEVICE_PROFILES = PROFILE_KINDS
_TOPOLOGIES = ("flat",) + TOPOLOGY_KINDS


@dataclass(frozen=True)
class Bundle:
    """The materialized objects a :class:`repro.api.session.Session` runs."""
    model: Classifier
    data: FederatedData
    fed: FedConfig
    plan: Plan
    x_test: jnp.ndarray
    y_test: jnp.ndarray
    p: np.ndarray
    policy: BudgetPolicy
    profile: DeviceProfile
    topology: EdgeTopology | None = None
    async_cfg: AsyncConfig | None = None


@dataclass(frozen=True)
class ExperimentSpec:
    """Everything needed to reproduce one federated run, as plain scalars."""

    # ---- data -----------------------------------------------------------
    dataset: str = "teacher"       # gaussian | teacher | image
    n_samples: int = 2048
    dim: int = 24                  # feature dim (gaussian/teacher)
    hw: int = 8                    # image side (image)
    channels: int = 1              # image channels (image)
    n_classes: int = 8
    test_frac: float = 0.2

    # ---- partition ------------------------------------------------------
    n_clients: int = 8
    partition: str = "gamma"       # gamma | classes
    gamma: float = 0.5             # IID share (partition="gamma")
    classes_per_client: int = 2    # (partition="classes")

    # ---- compute budgets ------------------------------------------------
    budget: str = "power"          # power | two_group | uniform | explicit
    beta: int = 4                  # p_i = (1/2)^⌊β·i/N⌋  (budget="power")
    r: float = 0.5                 # constrained fraction (budget="two_group")
    w: int = 4                     # 1/p of constrained    (budget="two_group")
    p: tuple[float, ...] | None = None   # explicit budgets (budget="explicit")

    # ---- model ----------------------------------------------------------
    model: str = "mlp"    # mlp | cnn | resnet18 | decoder | moe | xlstm
    width: int = 8
    #: LoRA rank r: 0 trains the model densely (simple models only); r >= 1
    #: wraps the model with rank-r adapters (models/lora.py) so the
    #: federated trainable subtree — and with it every executor's Δ history
    #: — is O(r·d) instead of O(P). Required (>= 1) for the zoo kinds.
    lora_rank: int = 0
    #: with LoRA: freeze everything but the adapters (True, the default) or
    #: additionally train the non-adapted leaves (biases/norms/embeddings)
    freeze_base: bool = True

    # ---- federated config (mirrors FedConfig) ---------------------------
    strategy: str = "cc"
    variant: str = "client"
    local_steps: int = 5
    batch_size: int = 32
    lr: float = 0.05
    tau: int = 100
    prox_mu: float = 0.0           # FedProx proximal weight (strategy="fedprox")
    feddyn_alpha: float = 0.0      # FedDyn regularization α (strategy="feddyn")

    # ---- uplink channel (core/channel.py) -------------------------------
    #: aggregation uplink model: "noiseless" (exact, bit-for-bit) |
    #: "aircomp" (over-the-air superposition: AWGN at channel_snr_db,
    #: optional per-client Rayleigh fading)
    channel: str = "noiseless"
    channel_snr_db: float = 20.0   # receive SNR in dB (channel="aircomp")
    channel_fading: bool = False   # Rayleigh gains (channel="aircomp")

    # ---- plan -----------------------------------------------------------
    schedule: str = "adhoc"
    rounds: int = 80
    participation: float = 1.0

    # ---- budget policy + device runtime ---------------------------------
    #: train/estimate decision maker (core/budget.py): "precompiled"
    #: replays the legacy ``schedule`` plan bit-for-bit; the runtime kinds
    #: (energy | deadline | adaptive) decide in-loop from device state
    policy: str = "precompiled"
    device_profile: str = "budget"   # budget | uniform (system/devices.py)
    energy_capacity: float = 4.0     # reserve ceiling (train-cost units)
    energy_init: float = 1.0         # round-0 reserve
    harvest_scale: float = 1.0       # × p_i energy recovered per round
    load_mean: float = 0.0           # stationary background load
    load_rho: float = 0.7            # AR(1) load persistence
    load_jitter: float = 0.0         # load noise amplitude
    deadline: float = 2.0            # DeadlineAware: × nominal round time
    adapt_eta: float = 0.5           # AdaptiveProbability feedback gain

    # ---- two-tier topology (executor="hierarchical") --------------------
    #: client→edge assignment scheme: "flat" (no edge tier) or an
    #: EdgeTopology kind ("contiguous" | "striped", core/hierarchy.py)
    topology: str = "flat"
    n_edges: int = 1               # E edge aggregators
    edge_period: int = 1           # intra-edge rounds per server sync
    #: optional per-edge device heterogeneity (length-E multipliers on the
    #: member clients' flops_rate / harvest rows — heterogeneous gateways)
    edge_speed: tuple[float, ...] | None = None
    edge_harvest: tuple[float, ...] | None = None

    # ---- async executor (executor="async", core/async_rounds.py) --------
    async_buffer: int = 1            # merge every K-th arrival (FedBuff K)
    staleness_decay: float = 0.9     # γ of the merge weight w(s)
    staleness_schedule: str = "geometric"  # geometric | polynomial
    async_latency: float = 0.0       # nominal rounds-in-flight per update
    async_jitter: float = 0.0        # uniform latency noise amplitude
    #: Δ-history carry layout (core/history_store.py): "dense" f32 |
    #: "int8" sharded quantized store (N = 10⁵-scale estimation replay)
    history_store: str = "dense"

    # ---- execution ------------------------------------------------------
    eval_every: int = 20
    executor: str = "scan"  # scan | python | sharded | hierarchical | async
    use_fused: bool = False
    #: Δ-history wire/storage format: "none" (f32) | "int8" (quantized
    #: payload + per-row scales; requires use_fused)
    compress: str = "none"
    cohort_size: int | None = None  # sharded executor: participants/round
    seed: int = 0

    def __post_init__(self):
        _check("dataset", self.dataset, _DATASETS)
        _check("partition", self.partition, _PARTITIONS)
        _check("budget", self.budget, _BUDGETS)
        _check("model", self.model, _MODELS)
        if self.lora_rank < 0:
            raise ValueError(f"lora_rank must be >= 0, got {self.lora_rank}")
        if self.model in ZOO_KINDS and self.lora_rank < 1:
            raise ValueError(
                f"model={self.model!r} is a zoo stack; federating it "
                "densely is exactly the O(N·P) history blow-up LoRA "
                "avoids — set lora_rank >= 1")
        if not self.freeze_base and self.lora_rank == 0:
            raise ValueError("freeze_base=False only applies to LoRA runs "
                             "(lora_rank >= 1)")
        _check("schedule", self.schedule, _SCHEDULES)
        _check("executor", self.executor, _EXECUTORS)
        _check("policy", self.policy, POLICY_KINDS)
        _check("device_profile", self.device_profile, _DEVICE_PROFILES)
        if self.energy_capacity <= 0:
            raise ValueError(f"energy_capacity must be > 0, got "
                             f"{self.energy_capacity}")
        if self.deadline <= 0:
            raise ValueError(f"deadline must be > 0, got {self.deadline}")
        if self.adapt_eta < 0:
            raise ValueError(f"adapt_eta must be >= 0, got "
                             f"{self.adapt_eta}")
        if self.budget == "explicit":
            if not self.p:
                raise ValueError("budget='explicit' requires p=(...)")
            if len(self.p) != self.n_clients:
                raise ValueError(
                    f"explicit budgets need one entry per client: "
                    f"len(p)={len(self.p)} vs n_clients={self.n_clients}")
            object.__setattr__(self, "p", tuple(float(v) for v in self.p))
        if self.rounds < 1:
            raise ValueError(f"rounds must be >= 1, got {self.rounds}")
        if self.eval_every < 1:
            raise ValueError(f"eval_every must be >= 1, got {self.eval_every}")
        if self.cohort_size is not None:
            if self.executor not in ("sharded", "async"):
                raise ValueError("cohort_size requires executor='sharded' "
                                 "or executor='async' (the other executors "
                                 "run the full federation every round)")
            if not 1 <= self.cohort_size <= self.n_clients:
                raise ValueError(
                    f"cohort_size must be in [1, {self.n_clients}], "
                    f"got {self.cohort_size}")
        if self.executor == "sharded" and self.use_fused:
            raise ValueError("use_fused is not supported by the sharded "
                             "executor; pick one fast path")
        _check("compress", self.compress, _COMPRESS)
        if self.compress == "int8" and not self.use_fused:
            raise ValueError(
                "compress='int8' stores the Δ history in the fused "
                "kernels' int8 layout; it requires use_fused=True")
        _check("topology", self.topology, _TOPOLOGIES)
        if (self.executor == "hierarchical") != (self.topology != "flat"):
            raise ValueError(
                "two-tier runs need BOTH executor='hierarchical' AND a "
                f"non-flat topology (got executor={self.executor!r}, "
                f"topology={self.topology!r})")
        if self.topology == "flat":
            if self.n_edges != 1 or self.edge_period != 1:
                raise ValueError(
                    "n_edges/edge_period require a non-flat topology "
                    f"(got n_edges={self.n_edges}, "
                    f"edge_period={self.edge_period})")
            if self.edge_speed is not None or self.edge_harvest is not None:
                raise ValueError("edge_speed/edge_harvest require a "
                                 "non-flat topology")
        else:
            if self.executor == "hierarchical" and self.use_fused:
                raise ValueError("use_fused is not supported by the "
                                 "hierarchical executor; pick one fast "
                                 "path")
            if not 1 <= self.n_edges <= self.n_clients:
                raise ValueError(
                    f"n_edges must be in [1, {self.n_clients}], got "
                    f"{self.n_edges}")
            if self.edge_period < 1:
                raise ValueError(f"edge_period must be >= 1, got "
                                 f"{self.edge_period}")
            for name in ("edge_speed", "edge_harvest"):
                v = getattr(self, name)
                if v is None:
                    continue
                if len(v) != self.n_edges:
                    raise ValueError(
                        f"{name} needs one entry per edge: len={len(v)} "
                        f"vs n_edges={self.n_edges}")
                if not all(s > 0 for s in v):
                    raise ValueError(f"{name} factors must be > 0")
                object.__setattr__(self, name,
                                   tuple(float(s) for s in v))
        if self.executor == "async":
            if self.use_fused:
                raise ValueError("use_fused is not supported by the async "
                                 "executor; pick one fast path")
            self.async_config()     # validates the async_* fields eagerly
            if self.async_buffer > self.n_clients:
                raise ValueError(
                    f"async_buffer must be <= n_clients="
                    f"{self.n_clients} (each client parks at most one "
                    f"update in the merge buffer), got {self.async_buffer}")
            if (self.cohort_size is not None
                    and self.cohort_size < self.async_buffer):
                raise ValueError(
                    f"cohort_size={self.cohort_size} < async_buffer="
                    f"{self.async_buffer} can never fill the merge buffer "
                    "— at most cohort_size updates are ever in flight, so "
                    "the merge loop deadlocks; raise cohort_size or lower "
                    "async_buffer")
        else:
            _check("staleness_schedule", self.staleness_schedule,
                   STALENESS_SCHEDULES)
            _check("history_store", self.history_store, STORE_KINDS)
            defaults = dict(async_buffer=1, staleness_decay=0.9,
                            staleness_schedule="geometric",
                            async_latency=0.0, async_jitter=0.0,
                            history_store="dense")
            off = [k for k, v in defaults.items()
                   if getattr(self, k) != v]
            if off:
                raise ValueError(
                    f"{off} require executor='async' (only the async "
                    "executor runs the arrival process and staleness-"
                    "decayed merges)")
        _check("channel", self.channel, CHANNEL_KINDS)
        if self.channel != "aircomp":
            chan_defaults = dict(channel_snr_db=20.0, channel_fading=False)
            off = [k for k, v in chan_defaults.items()
                   if getattr(self, k) != v]
            if off:
                raise ValueError(
                    f"{off} require channel='aircomp' (the noiseless "
                    "channel has no SNR or fading)")
        if self.prox_mu < 0:
            raise ValueError(f"prox_mu must be >= 0, got {self.prox_mu}")
        if self.feddyn_alpha < 0:
            raise ValueError(f"feddyn_alpha must be >= 0, got "
                             f"{self.feddyn_alpha}")
        if self.prox_mu != 0.0 and self.strategy != "fedprox":
            raise ValueError(
                f"prox_mu={self.prox_mu} requires strategy='fedprox' "
                f"(got strategy={self.strategy!r})")
        if self.feddyn_alpha != 0.0 and self.strategy != "feddyn":
            raise ValueError(
                f"feddyn_alpha={self.feddyn_alpha} requires "
                f"strategy='feddyn' (got strategy={self.strategy!r})")
        self.fed_config()               # validates strategy name eagerly

    # ---- serialization --------------------------------------------------

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["spec_version"] = SPEC_VERSION
        for key in ("p", "edge_speed", "edge_harvest"):
            if d[key] is not None:
                d[key] = list(d[key])
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ExperimentSpec":
        d = dict(d)
        version = d.pop("spec_version", SPEC_VERSION)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(d) - known)
        if version > SPEC_VERSION:
            hint = (f"; it also carries unknown fields {unknown} — likely "
                    "written by a newer schema" if unknown else "")
            raise ValueError(f"spec_version {version} is newer than "
                             f"supported {SPEC_VERSION}{hint}")
        if unknown:
            raise ValueError(f"unknown spec fields: {unknown}")
        # a field that postdates the declared version is only an error
        # when it carries a non-default value — at its default it is
        # indistinguishable from absent (old writers + new round-trips)
        defaults = {f.name: f.default for f in dataclasses.fields(cls)}
        late = sorted((k, _FIELD_INTRO[k]) for k in d
                      if _FIELD_INTRO.get(k, 1) > version
                      and d[k] != defaults.get(k))
        if late:
            k, intro = late[0]
            raise ValueError(
                f"field {k!r} was introduced in spec v{intro}, but this "
                f"spec declares spec_version={version}; update "
                f"spec_version or drop "
                f"{sorted(name for name, _ in late)}")
        for key in ("p", "edge_speed", "edge_harvest"):
            if d.get(key) is not None:
                d[key] = tuple(d[key])
        return cls(**d)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")
        return path

    @classmethod
    def load(cls, path: str) -> "ExperimentSpec":
        with open(path) as f:
            return cls.from_json(f.read())

    def replace(self, **kw) -> "ExperimentSpec":
        return dataclasses.replace(self, **kw)

    # ---- materialization ------------------------------------------------

    def fed_config(self) -> FedConfig:
        return FedConfig(strategy=self.strategy, variant=self.variant,
                         local_steps=self.local_steps,
                         batch_size=self.batch_size, lr=self.lr,
                         tau=self.tau, seed=self.seed,
                         cohort_size=self.cohort_size,
                         compress=self.compress,
                         prox_mu=self.prox_mu,
                         feddyn_alpha=self.feddyn_alpha,
                         channel=self.channel,
                         channel_snr_db=self.channel_snr_db,
                         channel_fading=self.channel_fading)

    def budgets(self) -> np.ndarray:
        if self.budget == "power":
            return budget_law(self.n_clients, self.beta)
        if self.budget == "two_group":
            return two_group_budget(self.n_clients, self.r, self.w)
        if self.budget == "uniform":
            return np.ones(self.n_clients)
        return np.asarray(self.p, float)          # explicit

    def build(self) -> Bundle:
        """Materialize data, model, budgets and plan (deterministic in
        ``seed``)."""
        if self.dataset == "image":
            ds = make_dataset("image", n=self.n_samples,
                              n_classes=self.n_classes, hw=self.hw,
                              channels=self.channels, seed=self.seed)
        else:
            ds = make_dataset(self.dataset, n=self.n_samples, dim=self.dim,
                              n_classes=self.n_classes, seed=self.seed)
        train, test = train_test_split(ds, test_frac=self.test_frac,
                                       seed=self.seed)
        if self.partition == "gamma":
            parts = partition_gamma(train, self.n_clients, gamma=self.gamma,
                                    seed=self.seed)
        else:
            parts = partition_classes(train, self.n_clients,
                                      self.classes_per_client,
                                      seed=self.seed)
        data = build_federated(train, parts)
        if self.model in ZOO_KINDS:
            model = make_zoo_classifier(
                self.model, input_shape=train.x.shape[1:],
                n_classes=self.n_classes, width=self.width)
        else:
            kind = "mlp" if self.model == "simple" else self.model
            model = make_classifier(
                kind, input_shape=train.x.shape[1:],
                n_classes=self.n_classes, width=self.width)
        if self.lora_rank > 0:
            import jax
            # base weights come from a fixed fold of the spec seed, so the
            # frozen base is reproducible from the spec alone (the engine's
            # model.init(PRNGKey(seed)) then draws only the adapters)
            base_rng = jax.random.fold_in(jax.random.PRNGKey(self.seed),
                                          0x10ad)
            model = lora_classifier(model, base_rng, self.lora_rank,
                                    freeze_base=self.freeze_base)
        p = self.budgets()
        plan = make_plan(self.schedule, p, self.rounds,
                         participation_ratio=self.participation,
                         seed=self.seed)
        profile = make_profile(
            self.device_profile, p, capacity=self.energy_capacity,
            init_energy=self.energy_init, harvest_scale=self.harvest_scale,
            load_mean=self.load_mean, load_rho=self.load_rho,
            load_jitter=self.load_jitter, seed=self.seed)
        topology = self.edge_topology()
        if topology is not None:
            profile = edge_scaled_profile(
                profile, topology.assignment, flops_scale=self.edge_speed,
                harvest_scale=self.edge_harvest)
        policy = make_policy(self.policy, plan=plan, deadline=self.deadline,
                             eta=self.adapt_eta)
        return Bundle(model=model, data=data, fed=self.fed_config(),
                      plan=plan, x_test=jnp.asarray(test.x),
                      y_test=jnp.asarray(test.y), p=p, policy=policy,
                      profile=profile, topology=topology,
                      async_cfg=self.async_config())

    def async_config(self) -> AsyncConfig | None:
        """The spec's async-executor config (validates the ``async_*``
        fields — buffer K ≥ 1, decay ∈ (0, 1], latency/jitter ≥ 0); None
        for synchronous executors."""
        if self.executor != "async":
            return None
        return AsyncConfig(buffer_size=self.async_buffer,
                           staleness_decay=self.staleness_decay,
                           schedule=self.staleness_schedule,
                           latency=self.async_latency,
                           jitter=self.async_jitter,
                           history_store=self.history_store)

    def edge_topology(self) -> EdgeTopology | None:
        """The spec's two-tier topology (deterministic in its fields, so a
        resumed session rebuilds the identical assignment); None for flat
        runs."""
        if self.topology == "flat":
            return None
        return EdgeTopology.make(self.topology, self.n_clients,
                                 self.n_edges, self.edge_period)


def _check(name: str, value: str, allowed: Sequence[str]) -> None:
    if value not in allowed:
        raise ValueError(f"{name} must be one of {tuple(allowed)}, "
                         f"got {value!r}")
