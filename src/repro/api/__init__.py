"""Public experiment API: declarative specs, resumable sessions, sweeps.

This package is the repo's front door:

* :class:`~repro.api.spec.ExperimentSpec` — a serializable description of
  one federated run (``to_dict``/``from_dict`` round-trip exactly);
* :class:`~repro.api.session.Session` — stepwise execution with
  ``run``/``step``/``eval``/``save``/``restore`` and bit-identical resume;
* :mod:`~repro.api.callbacks` — lifecycle hooks replacing the old
  ``verbose``/``probe_client`` keywords;
* :func:`~repro.api.sweep.run_sweep` — strategy/budget grids with a
  Table-I-style comparison;
* ``python -m repro`` — ``run`` / ``sweep`` / ``resume`` / ``init``
  subcommands driven by spec files (:mod:`repro.api.cli`).

The legacy ``repro.core.engine.run_federated`` remains as a thin shim
over :class:`Session`.
"""
from repro.api.callbacks import (  # noqa: F401
    Callback,
    CheckpointCallback,
    ProbeCallback,
    VerboseLogger,
)
from repro.api.session import Session, plan_k_active  # noqa: F401
from repro.api.spec import Bundle, ExperimentSpec  # noqa: F401
from repro.api.sweep import expand_grid, format_table, run_sweep  # noqa: F401
