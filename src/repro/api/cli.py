"""``python -m repro`` — run federated experiments from spec files.

Subcommands:

* ``init SPEC.json [--set field=value ...]``
      write a (possibly overridden) default spec file to start from;
* ``run SPEC.json [--set field=value ...] [--ckpt-dir D] [--save-every N]``
      run one session from a spec, optionally checkpointing as it goes;
* ``resume CKPT_DIR [--rounds N]``
      continue an interrupted run purely from its checkpoint directory
      (the spec travels inside the checkpoint);
* ``sweep SPEC.json --grid field=v1,v2 [--grid ...]``
      expand the spec over grids and print a Table-I-style comparison.

Examples:
    python -m repro init /tmp/exp.json --set rounds=3 --set strategy=cc
    python -m repro run /tmp/exp.json --ckpt-dir /tmp/ckpt --save-every 10
    python -m repro resume /tmp/ckpt
    python -m repro sweep /tmp/exp.json --grid strategy=cc,s2,fedavg

Executor selection rides the spec fields: ``--set executor=sharded --set
cohort_size=8`` runs each round's sampled cohort shard_map'ed over the
client mesh (all visible devices), ``--set use_fused=true`` takes the
fused Pallas path. ``--compress int8`` (with ``--set use_fused=true``)
stores the Δ history as int8 payload + per-client scales and runs the
quantized fused kernel — ~4× less history memory/wire traffic.

Budget policies: ``--policy {precompiled,energy,deadline,adaptive}`` picks
the in-loop train/estimate decision maker and ``--device-profile
{budget,uniform}`` the simulated device runtime (shorthands for the spec
fields of the same names; fine-grained knobs ride ``--set``, e.g. ``--set
energy_capacity=2.0 --set load_mean=0.3 --set deadline=1.5``):

    python -m repro run exp.json --policy energy --set harvest_scale=0.8
    python -m repro sweep exp.json --grid policy=precompiled,energy,adaptive

Two-tier topologies: ``--topology {contiguous,striped}``, ``--edges E``
and ``--edge-period P`` (shorthands for the spec fields ``topology`` /
``n_edges`` / ``edge_period``) run the hierarchical client→edge→server
executor — pair them with ``--set executor=hierarchical``; per-edge
heterogeneity rides ``--set edge_speed=[1.0,0.5]``:

    python -m repro run exp.json --set executor=hierarchical \
        --topology contiguous --edges 4 --edge-period 5
    python -m repro sweep exp.json --set executor=hierarchical \
        --topology contiguous --edges 4 --grid edge_period=1,5,10

Asynchronous federation: ``--set executor=async`` runs the staleness-
tolerant buffered executor — clients deliver after a device-dependent
latency (``--set async_latency=2.0 --set async_jitter=0.5``) and the
server merges every K-th arrival (``--async-buffer K``) with staleness-
decayed weights (``--staleness-decay γ``, shape via ``--set
staleness_schedule=polynomial``). ``--history-store int8`` carries the
Δ history as the sharded quantized store (~25% of dense f32 at large P).
Zero latency with K=1 (the defaults) is bit-for-bit the scan executor:

    python -m repro run exp.json --set executor=async \
        --async-buffer 4 --staleness-decay 0.8 --set async_latency=2.0
    python -m repro sweep exp.json --set executor=async \
        --grid staleness_decay=0.5,0.8,1.0

Strategies and channels: ``--strategy`` picks the aggregation strategy
(choices generated from the registry, including the proximal ``fedprox``
with ``--set prox_mu=0.1`` and the dynamic-regularization ``feddyn`` with
``--set feddyn_alpha=0.1``); ``--channel aircomp`` uploads deltas over a
noisy over-the-air channel at ``--snr-db`` receive SNR, ``--set
channel_fading=true`` adds per-client Rayleigh gains:

    python -m repro run exp.json --strategy fedprox --set prox_mu=0.1
    python -m repro run exp.json --channel aircomp --snr-db 10 \
        --set channel_fading=true
    python -m repro sweep exp.json --channel aircomp --grid \
        channel_snr_db=0,10,20
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.api.callbacks import CheckpointCallback, VerboseLogger
from repro.api.session import Session
from repro.api.spec import ExperimentSpec
from repro.api.sweep import format_table, run_sweep
from repro.utils.logging import log


def _parse_value(text: str):
    try:
        return json.loads(text)
    except json.JSONDecodeError:
        return text                       # bare strings need no quotes


def _parse_sets(pairs: list[str]) -> dict:
    out = {}
    for pair in pairs:
        if "=" not in pair:
            raise SystemExit(f"--set expects field=value, got {pair!r}")
        k, v = pair.split("=", 1)
        out[k] = _parse_value(v)
    return out


def _parse_grids(pairs: list[str]) -> dict:
    out = {}
    for pair in pairs:
        if "=" not in pair:
            raise SystemExit(f"--grid expects field=v1,v2,..., got {pair!r}")
        k, vs = pair.split("=", 1)
        out[k] = [_parse_value(v) for v in vs.split(",")]
    return out


def _load_spec(path: str, sets: list[str],
               policy: str | None = None,
               device_profile: str | None = None,
               topology: str | None = None,
               edges: int | None = None,
               edge_period: int | None = None,
               compress: str | None = None,
               async_buffer: int | None = None,
               staleness_decay: float | None = None,
               history_store: str | None = None,
               strategy: str | None = None,
               channel: str | None = None,
               snr_db: float | None = None,
               executor: str | None = None) -> ExperimentSpec:
    spec = ExperimentSpec.load(path)
    overrides = _parse_sets(sets)
    if policy:
        overrides["policy"] = policy
    if device_profile:
        overrides["device_profile"] = device_profile
    if topology:
        overrides["topology"] = topology
    if edges is not None:
        overrides["n_edges"] = edges
    if edge_period is not None:
        overrides["edge_period"] = edge_period
    if compress:
        overrides["compress"] = compress
    if async_buffer is not None:
        overrides["async_buffer"] = async_buffer
    if staleness_decay is not None:
        overrides["staleness_decay"] = staleness_decay
    if history_store:
        overrides["history_store"] = history_store
    if strategy:
        overrides["strategy"] = strategy
    if channel:
        overrides["channel"] = channel
    if snr_db is not None:
        overrides["channel_snr_db"] = snr_db
    if executor:
        overrides["executor"] = executor
    return spec.replace(**overrides) if overrides else spec


def _dump(obj: dict, path: str | None) -> None:
    if path:
        with open(path, "w") as f:
            json.dump(obj, f, indent=2)
        log(f"wrote {path}")


def cmd_init(args) -> int:
    # from_dict rather than the constructor: typo'd --set fields get the
    # "unknown spec fields" error instead of a raw TypeError
    spec = ExperimentSpec.from_dict(_parse_sets(args.set))
    spec.save(args.spec)
    log(f"wrote spec {args.spec}", strategy=spec.strategy,
        rounds=spec.rounds)
    return 0


def cmd_run(args) -> int:
    spec = _load_spec(args.spec, args.set, policy=args.policy,
                      device_profile=args.device_profile,
                      topology=args.topology, edges=args.edges,
                      edge_period=args.edge_period, compress=args.compress,
                      async_buffer=args.async_buffer,
                      staleness_decay=args.staleness_decay,
                      history_store=args.history_store,
                      strategy=args.strategy, channel=args.channel,
                      snr_db=args.snr_db, executor=args.executor)
    callbacks = [] if args.quiet else [VerboseLogger()]
    if args.save_every and not args.ckpt_dir:
        raise SystemExit("--save-every needs --ckpt-dir (nowhere to save)")
    if args.save_every:
        callbacks.append(CheckpointCallback(args.save_every))
    sess = Session.from_spec(spec, callbacks=callbacks,
                             ckpt_dir=args.ckpt_dir or None)
    sess.run()
    if args.ckpt_dir:
        sess.save()
    rep = sess.cost_report()
    log("run done", **{k: f"{v:.4f}" if isinstance(v, float) else v
                       for k, v in sess.summary().items()})
    out = {"spec": spec.to_dict(), "summary": sess.summary(),
           "metrics": sess.metrics.history, "cost": rep}
    _dump(out, args.out)
    print(json.dumps(sess.summary()))
    return 0


def cmd_resume(args) -> int:
    callbacks = [] if args.quiet else [VerboseLogger()]
    sess = Session.restore_from(args.ckpt_dir, callbacks=callbacks)
    log(f"resumed at round {sess.t}/{sess.plan.rounds}",
        strategy=sess.fed.strategy)
    sess.run(args.rounds)
    sess.save()
    out = {"summary": sess.summary(), "metrics": sess.metrics.history}
    _dump(out, args.out)
    print(json.dumps(sess.summary()))
    return 0


def cmd_sweep(args) -> int:
    spec = _load_spec(args.spec, args.set, policy=args.policy,
                      device_profile=args.device_profile,
                      topology=args.topology, edges=args.edges,
                      edge_period=args.edge_period, compress=args.compress,
                      async_buffer=args.async_buffer,
                      staleness_decay=args.staleness_decay,
                      history_store=args.history_store,
                      strategy=args.strategy, channel=args.channel,
                      snr_db=args.snr_db, executor=args.executor)
    grid = _parse_grids(args.grid)
    result = run_sweep(spec, grid, verbose=not args.quiet)
    _dump(result, args.out)
    print(format_table(result))
    return 0


def _add_policy_flags(p: argparse.ArgumentParser) -> None:
    # every choices= below is derived from the owning registry — a newly
    # registered strategy/executor/kind is reachable from the CLI without
    # touching this file (pinned by tests/test_cli_registries.py)
    from repro.core.budget import POLICY_KINDS
    from repro.core.channel import CHANNEL_KINDS
    from repro.core.hierarchy import TOPOLOGY_KINDS
    from repro.core.history_store import STORE_KINDS
    from repro.core.rounds import COMPRESS_KINDS, EXECUTORS
    from repro.core.strategies import available_strategies
    from repro.system.devices import PROFILE_KINDS
    p.add_argument("--strategy", default=None,
                   choices=available_strategies(),
                   help="aggregation strategy (shorthand for --set "
                        "strategy=...; choices come from the registry)")
    p.add_argument("--executor", default=None, choices=EXECUTORS,
                   help="round executor (shorthand for --set "
                        "executor=...)")
    p.add_argument("--channel", default=None, choices=CHANNEL_KINDS,
                   help="uplink channel model (shorthand for --set "
                        "channel=...; aircomp adds AWGN at --snr-db)")
    p.add_argument("--snr-db", type=float, default=None,
                   help="aircomp receive SNR in dB (shorthand for --set "
                        "channel_snr_db=...; needs --channel aircomp)")
    p.add_argument("--policy", default=None, choices=POLICY_KINDS,
                   help="budget policy (shorthand for --set policy=...)")
    p.add_argument("--device-profile", default=None,
                   choices=PROFILE_KINDS,
                   help="device runtime (shorthand for --set "
                        "device_profile=...)")
    p.add_argument("--topology", default=None, choices=TOPOLOGY_KINDS,
                   help="two-tier client→edge assignment (shorthand for "
                        "--set topology=...; needs "
                        "--set executor=hierarchical)")
    p.add_argument("--edges", type=int, default=None,
                   help="edge aggregator count (shorthand for "
                        "--set n_edges=...)")
    p.add_argument("--edge-period", type=int, default=None,
                   help="intra-edge rounds per server sync (shorthand "
                        "for --set edge_period=...)")
    p.add_argument("--compress", default=None, choices=COMPRESS_KINDS,
                   help="Δ-history wire/memory format (shorthand for "
                        "--set compress=...; int8 needs "
                        "--set use_fused=true)")
    p.add_argument("--async-buffer", type=int, default=None,
                   help="merge every K-th arrival (shorthand for --set "
                        "async_buffer=...; needs --set executor=async)")
    p.add_argument("--staleness-decay", type=float, default=None,
                   help="γ of the staleness merge weight w(s) (shorthand "
                        "for --set staleness_decay=...)")
    p.add_argument("--history-store", default=None,
                   choices=STORE_KINDS,
                   help="async Δ-history carry layout (shorthand for "
                        "--set history_store=...)")


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(prog="python -m repro",
                                 description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("init", help="write a default spec file")
    p.add_argument("spec")
    p.add_argument("--set", action="append", default=[],
                   metavar="FIELD=VALUE")
    p.set_defaults(fn=cmd_init)

    p = sub.add_parser("run", help="run one session from a spec")
    p.add_argument("spec")
    p.add_argument("--set", action="append", default=[],
                   metavar="FIELD=VALUE")
    _add_policy_flags(p)
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--save-every", type=int, default=0,
                   help="checkpoint every N rounds (with --ckpt-dir)")
    p.add_argument("--out", default="", help="write metrics JSON here")
    p.add_argument("--quiet", action="store_true")
    p.set_defaults(fn=cmd_run)

    p = sub.add_parser("resume", help="continue from a checkpoint dir")
    p.add_argument("ckpt_dir")
    p.add_argument("--rounds", type=int, default=None,
                   help="how many more rounds (default: finish the plan)")
    p.add_argument("--out", default="")
    p.add_argument("--quiet", action="store_true")
    p.set_defaults(fn=cmd_resume)

    p = sub.add_parser("sweep", help="grid-expand a spec and compare")
    p.add_argument("spec")
    p.add_argument("--set", action="append", default=[],
                   metavar="FIELD=VALUE")
    _add_policy_flags(p)
    p.add_argument("--grid", action="append", default=[], required=True,
                   metavar="FIELD=V1,V2")
    p.add_argument("--out", default="")
    p.add_argument("--quiet", action="store_true")
    p.set_defaults(fn=cmd_sweep)
    return ap


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
