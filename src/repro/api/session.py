"""Stepwise, resumable federated sessions.

A :class:`Session` owns one federated run: the materialized model/data/
plan, the full round state, the metric history and the eval cadence. It
wraps the executors of :mod:`repro.core.rounds` — per-round jit,
``lax.scan`` spans (``use_fused=True`` routes rounds through the Pallas
kernel), ``executor="sharded"`` spans that ``shard_map`` each round's
sampled cohort over the client mesh, or ``executor="async"`` spans that
replay a precomputed arrival schedule through the staleness-tolerant
buffered executor (:mod:`repro.core.async_rounds`) — behind
``run(n_rounds)`` / ``step()`` / ``eval()`` / ``save()`` / ``restore()``.

Determinism contract (pinned by ``tests/test_api.py``):

* a Session run and the legacy ``run_federated`` produce identical final
  params and metric streams;
* ``save()`` checkpoints the FULL state (params, Δ history, stale local
  models, RNG key, round counter, metrics — plus the budget-policy rows,
  simulated device state and energy ledger), so a killed run restored with
  :meth:`Session.restore_from` continues bit-identically — evaluation
  points follow the *absolute* round cadence, never the resume point.

Every session runs the budget-policy engine (:mod:`repro.core.budget`):
train/estimate decisions happen inside the traced round loop against
simulated device state (:mod:`repro.system.devices`). A session built
without an explicit ``policy`` replays its plan's training table through
``PrecompiledPolicy`` — bit-for-bit the legacy static-plan behaviour.
"""
from __future__ import annotations

from typing import Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.callbacks import Callback
from repro.checkpoint.store import CheckpointManager
from repro.core.async_rounds import AsyncConfig, make_async_span_runner
from repro.core.budget import PrecompiledPolicy
from repro.core.evaluation import evaluate
from repro.core.rounds import (EXECUTORS, FedConfig, init_fed_state,
                               make_hierarchical_span_runner,
                               make_policy_round_fn,
                               make_policy_span_runner,
                               make_sharded_span_runner, span_boundaries)
from repro.core.schedules import Plan, fednova_local_steps
from repro.data.federated import CohortSampler, FederatedData
from repro.models.simple import Classifier
from repro.system.devices import make_profile, simulate_arrivals
from repro.utils.logging import MetricLogger
from repro.utils.pytree import PyTree, tree_bytes


def plan_k_active(data: FederatedData, fed: FedConfig,
                  plan: Plan) -> jax.Array:
    """Per-client local-step counts: FedNova spends its budget as fewer
    iterations every round; everyone else runs the full K."""
    if fed.strategy == "fednova":
        k_active_all = fednova_local_steps(plan.p, fed.local_steps)
    else:
        k_active_all = np.full(data.n_clients, fed.local_steps, np.int32)
    return jnp.asarray(k_active_all)


class Session:
    """One federated run with explicit control over its lifecycle."""

    def __init__(self, model: Classifier, data: FederatedData,
                 fed: FedConfig, plan: Plan, *, x_test=None, y_test=None,
                 eval_every: int = 10, executor: str = "scan",
                 use_fused: bool = False,
                 callbacks: Iterable[Callback] = (),
                 ckpt_dir: str | None = None, keep: int = 3,
                 spec=None, policy=None, profile=None, topology=None,
                 async_cfg=None):
        if executor not in EXECUTORS:
            raise ValueError(f"unknown executor {executor!r}; "
                             f"available: {EXECUTORS}")
        if executor in ("sharded", "hierarchical", "async") and use_fused:
            raise ValueError(f"use_fused is not supported by the "
                             f"{executor} executor; pick one fast path")
        if async_cfg is not None and executor != "async":
            raise ValueError("async_cfg requires executor='async' (only "
                             "the async executor runs the arrival process)")
        if executor == "async" and async_cfg is None:
            async_cfg = AsyncConfig()
        if (executor == "hierarchical") != (topology is not None):
            raise ValueError(
                "the hierarchical executor needs an EdgeTopology (pass "
                "topology=...), and a topology needs "
                "executor='hierarchical'")
        if eval_every < 1:
            raise ValueError(f"eval_every must be >= 1, got {eval_every}")
        if fed.compress == "int8" and not use_fused:
            raise ValueError(
                "compress='int8' carries the Δ history in the fused "
                "kernels' flat int8 layout, which only the fused executor "
                "consumes; pass use_fused=True (executor 'scan' or "
                "'python'), or compress='none'")
        if (policy is None) != (profile is None):
            raise ValueError("pass policy and profile together (or neither "
                             "for the plan-replaying default)")
        if policy is None:
            # every session runs the budget-policy engine; a bare plan is
            # replayed bit-for-bit through PrecompiledPolicy over a
            # budget-shaped device profile
            policy = PrecompiledPolicy.from_plan(plan)
            profile = make_profile("budget", plan.p, seed=fed.seed)
        self.model = model
        self.data = data
        self.fed = fed
        self.plan = plan
        self.policy = policy
        self.profile = profile
        self.topology = topology
        self.async_cfg = async_cfg
        self.x_test = x_test
        self.y_test = y_test
        self.eval_every = eval_every
        self.executor = executor
        self.use_fused = use_fused
        self.callbacks: list[Callback] = list(callbacks)
        self.spec = spec
        self.metrics = MetricLogger()
        self.k_active = plan_k_active(data, fed, plan)
        self.state: PyTree = init_fed_state(jax.random.PRNGKey(fed.seed),
                                            model, data.n_clients,
                                            policy=policy, profile=profile,
                                            topology=topology,
                                            compress=fed.compress,
                                            async_cfg=async_cfg,
                                            needs_stale=fed.resolve()
                                            .needs_stale,
                                            strategy=fed.resolve())
        self._t = 0                              # completed rounds
        self._sel = jnp.asarray(plan.selection)
        self._cohort = None
        self._sched = None
        if executor == "async":
            # the arrival process is precomputed host-side from the device
            # profile (load dynamics never depend on training decisions),
            # keyed by absolute round — a resumed session replays the same
            # dispatch/delivery/merge events
            sel_np = np.asarray(plan.selection)
            if fed.cohort_size is not None:
                if fed.cohort_size < async_cfg.buffer_size:
                    raise ValueError(
                        f"cohort_size={fed.cohort_size} < async_buffer="
                        f"{async_cfg.buffer_size} can never fill the merge "
                        "buffer — the merge loop deadlocks; raise "
                        "cohort_size or lower async_buffer")
                # absolute-round-keyed cohort thinning: only sampled
                # cohort members may dispatch each round (same sampler
                # contract as the sharded executor, so a resumed session
                # replays the identical arrival stream)
                sampler = CohortSampler(data.n_clients, fed.cohort_size,
                                        seed=fed.seed)
                idx = np.asarray(sampler.indices(plan.rounds))
                member = np.zeros(sel_np.shape, dtype=bool)
                np.put_along_axis(member, idx, True, axis=1)
                sel_np = sel_np & member
            self._sched = simulate_arrivals(
                profile, sel_np,
                buffer_size=async_cfg.buffer_size,
                latency=async_cfg.latency, jitter=async_cfg.jitter)
        if executor == "sharded":
            # absolute-round-keyed cohorts: resumed sessions sample the
            # same participants, mirroring the plan-mask contract
            sampler = CohortSampler(data.n_clients,
                                    fed.cohort_size or data.n_clients,
                                    seed=fed.seed)
            self._cohort = jnp.asarray(sampler.indices(plan.rounds))
        self._round_fn = None
        self._span_runner = None
        self._mgr = (CheckpointManager(ckpt_dir, keep=keep)
                     if ckpt_dir else None)

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    @classmethod
    def from_spec(cls, spec, *, callbacks: Iterable[Callback] = (),
                  ckpt_dir: str | None = None, keep: int = 3) -> "Session":
        """Materialize an :class:`~repro.api.spec.ExperimentSpec`."""
        b = spec.build()
        return cls(b.model, b.data, b.fed, b.plan, x_test=b.x_test,
                   y_test=b.y_test, eval_every=spec.eval_every,
                   executor=spec.executor, use_fused=spec.use_fused,
                   callbacks=callbacks, ckpt_dir=ckpt_dir, keep=keep,
                   spec=spec, policy=b.policy, profile=b.profile,
                   topology=b.topology, async_cfg=b.async_cfg)

    @classmethod
    def restore_from(cls, ckpt_dir: str, *, step: int | None = None,
                     callbacks: Iterable[Callback] = ()) -> "Session":
        """Rebuild a session purely from a checkpoint directory: the spec
        stored in the checkpoint reconstructs data/model/plan, then the
        full state and metric history are restored."""
        from repro.api.spec import ExperimentSpec
        mgr = CheckpointManager(ckpt_dir)
        extra = mgr.read_extra(step)
        if not extra.get("spec"):
            raise ValueError(
                f"checkpoint in {ckpt_dir!r} carries no spec; restore it "
                "through a Session constructed from the original objects")
        spec = ExperimentSpec.from_dict(extra["spec"])
        sess = cls.from_spec(spec, callbacks=callbacks, ckpt_dir=ckpt_dir)
        sess.restore(step=step)
        return sess

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------

    @property
    def t(self) -> int:
        """Completed rounds (== ``int(state['round'])``)."""
        return self._t

    @property
    def done(self) -> bool:
        return self._t >= self.plan.rounds

    def _get_round_fn(self):
        if self._round_fn is None:
            self._round_fn = make_policy_round_fn(
                self.model, self.data, self.fed, self.policy, self.profile,
                fused=self.use_fused)
        return self._round_fn

    def _get_span_runner(self):
        if self._span_runner is None:
            if self.executor == "sharded":
                self._span_runner = make_sharded_span_runner(
                    self.model, self.data, self.fed, policy=self.policy,
                    profile=self.profile)
            elif self.executor == "hierarchical":
                self._span_runner = make_hierarchical_span_runner(
                    self.model, self.data, self.fed, self.topology,
                    policy=self.policy, profile=self.profile)
            elif self.executor == "async":
                self._span_runner = make_async_span_runner(
                    self.model, self.data, self.fed, self.async_cfg,
                    policy=self.policy, profile=self.profile)
            else:
                self._span_runner = make_policy_span_runner(
                    self.model, self.data, self.fed, self.policy,
                    self.profile, fused=self.use_fused)
        return self._span_runner

    def _advance_span(self, stop: int) -> None:
        """Run rounds ``self._t .. stop`` as one span with the configured
        span runner (the sharded runner additionally takes its cohort
        table slice). Training decisions are made in-trace by the budget
        policy; only the selection masks are staged."""
        t, run_span = self._t, self._get_span_runner()
        if self.executor == "sharded":
            self.state = run_span(self.state, self._sel[t:stop],
                                  self.k_active, self._cohort[t:stop])
        elif self.executor == "async":
            sched = tuple(jnp.asarray(x[t:stop]) for x in self._sched)
            self.state = run_span(self.state, self.k_active, sched)
        else:
            self.state = run_span(self.state, self._sel[t:stop],
                                  self.k_active)
        self._t = stop

    def step(self) -> PyTree:
        """Advance exactly one round (per-round executor; the sharded and
        hierarchical executors run a one-round span so cohort sampling /
        edge-tier state still apply) and fire ``on_round_end``. Evaluation
        stays on the absolute cadence and is driven by :meth:`run`; a bare
        ``step()`` never records metrics."""
        t = self._t
        if t >= self.plan.rounds:
            raise RuntimeError(
                f"plan exhausted: {t}/{self.plan.rounds} rounds done")
        if self.executor in ("sharded", "hierarchical", "async"):
            self._advance_span(t + 1)
        else:
            self.state = self._get_round_fn()(
                self.state, self._sel[t], self.k_active)
            self._t = t + 1
        for cb in self.callbacks:
            cb.on_round_end(self, self._t)
        return self.state

    def _eval_due(self, t: int) -> bool:
        return t % self.eval_every == 0 or t == self.plan.rounds

    def _run_eval(self) -> float:
        acc = self.eval()
        self.metrics.record(self._t, test_acc=acc)
        for cb in self.callbacks:
            cb.on_eval(self, self._t, acc)
        return acc

    def run(self, n_rounds: int | None = None) -> "Session":
        """Advance ``n_rounds`` (default: to the end of the plan),
        evaluating on the absolute ``eval_every`` cadence plus the final
        plan round. Uses the scan executor between host-sync points unless
        ``executor='python'`` or a callback needs the per-round loop."""
        total = self.plan.rounds
        target = (total if n_rounds is None
                  else min(total, self._t + n_rounds))
        if target <= self._t:               # nothing to do; never re-fires
            return self                     # hooks or re-records an eval
        per_round_cbs = any(cb.needs_python_loop for cb in self.callbacks)
        # the sharded/hierarchical/async executors have no python-loop
        # fallback (it would drop cohort sampling / the edge tier / the
        # arrival buffer); per-round callbacks split their spans instead
        needs_python = (self.executor == "python"
                        or (per_round_cbs and self.executor
                            not in ("sharded", "hierarchical", "async")))
        if needs_python:
            while self._t < target:
                self.step()
                if self._eval_due(self._t):
                    self._run_eval()
            return self

        eval_stops = set(span_boundaries(total, self.eval_every))
        stops = set(eval_stops)
        for cb in self.callbacks:
            if cb.sync_every:
                stops.update(range(cb.sync_every, total + 1, cb.sync_every))
        if per_round_cbs:                   # sharded + per-round callbacks
            stops.update(range(self._t + 1, target + 1))
        stops = sorted(s for s in stops if self._t < s <= target)
        if not stops or stops[-1] != target:
            stops.append(target)
        for stop in stops:
            if stop > self._t:
                self._advance_span(stop)
            for cb in self.callbacks:
                cb.on_round_end(self, self._t)
            if self._t in eval_stops:
                self._run_eval()
        return self

    def eval(self) -> float:
        """Test-set accuracy of the current global model (no recording)."""
        if self.x_test is None or self.y_test is None:
            raise ValueError("session has no test set; pass x_test/y_test")
        return evaluate(self.model, self.state["params"],
                        self.x_test, self.y_test)

    # ------------------------------------------------------------------
    # persistence
    # ------------------------------------------------------------------

    def save(self, ckpt_dir: str | None = None) -> str:
        """Checkpoint the full federated state + metrics + spec; the file
        alone suffices for :meth:`restore_from` to continue the run."""
        mgr = self._require_mgr(ckpt_dir)
        extra = {
            "round": self._t,
            "metrics": self.metrics.history,
            "spec": self.spec.to_dict() if self.spec is not None else None,
        }
        path = mgr.save_fed(self._t, self.state, extra=extra)
        for cb in self.callbacks:
            cb.on_checkpoint(self, self._t, path)
        return path

    def restore(self, step: int | None = None,
                ckpt_dir: str | None = None) -> "Session":
        """Restore full state + metric history from a checkpoint written
        by :meth:`save` (in-place; session config must match)."""
        mgr = self._require_mgr(ckpt_dir)
        like = init_fed_state(jax.random.PRNGKey(self.fed.seed),
                              self.model, self.data.n_clients,
                              policy=self.policy, profile=self.profile,
                              topology=self.topology,
                              compress=self.fed.compress,
                              async_cfg=self.async_cfg,
                              needs_stale=self.fed.resolve().needs_stale,
                              strategy=self.fed.resolve())
        state, extra = mgr.restore(like, step=step)
        self.state = state
        self._t = int(extra.get("round", extra.get("step", 0)))
        history = extra.get("metrics") or {}
        self.metrics = MetricLogger(history={
            k: [(int(s), float(v)) for s, v in series]
            for k, series in history.items()})
        return self

    def _require_mgr(self, ckpt_dir: str | None) -> CheckpointManager:
        if ckpt_dir is not None:
            self._mgr = CheckpointManager(ckpt_dir)
        if self._mgr is None:
            raise ValueError("no checkpoint directory: pass ckpt_dir to the "
                             "Session or to save()/restore()")
        return self._mgr

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------

    def cost_report(self, variant: str | None = None,
                    mixed_client_frac: float = 0.5) -> dict:
        """Appendix-A storage/upload accounting from the REALIZED ledger —
        the train/estimate decisions the policy actually made, not the
        static plan's table (for ``PrecompiledPolicy`` over a fully-run
        plan the two coincide; for runtime policies only the ledger is
        truthful).

        Every report carries the int8-quantized upload figure
        (:mod:`repro.core.compress`). With ``compress="none"`` it is
        *accounted* (``upload_bytes / 4``, the what-if estimate); with
        ``compress="int8"`` it is *measured* from the carried wire format —
        tile-padded int8 payload rows plus one f32 scale per upload —
        flagged by ``upload_bytes_int8_measured``. Two-tier sessions
        additionally break uploads down per hop under ``"tiers"`` —
        client→edge bytes every decided round vs edge→server bytes only on
        the ``edge_period``-boundary syncs.

        Async sessions account uploads per REALIZED arrival: the ledger
        books each dispatched update exactly once, at the round its
        delivery lands on the server (a stale update in flight for s
        rounds is still one upload), so ``upload_rounds`` = arrivals so
        far — in-flight work is not yet an upload. The report then also
        carries the raw ``arrivals``/``merges`` counters."""
        from repro.core.compress import (BYTES_PER_PARAM_F32,
                                         tier_upload_report)
        from repro.core.engine import cost_report_from_counts
        led = self.ledger()
        decided = led["train_rounds"] + led["est_rounds"]
        per_client = led["train_rounds"] / np.maximum(1, decided)
        model_bytes = tree_bytes(self.state["params"])
        rep = cost_report_from_counts(
            int(led["train_rounds"].sum()), int(led["est_rounds"].sum()),
            self.data.n_clients, model_bytes,
            variant=variant or self.fed.variant,
            mixed_client_frac=mixed_client_frac, per_client=per_client)
        if self.fed.compress == "int8":
            q = self.state["deltas"]
            wire_bytes = (q["payload"].shape[1] * q["payload"].dtype.itemsize
                          + q["scales"].dtype.itemsize)
            rep["upload_bytes_int8"] = int(
                rep["upload_bytes"] / model_bytes * wire_bytes)
            rep["upload_bytes_int8_measured"] = True
        else:
            rep["upload_bytes_int8"] = (rep["upload_bytes"]
                                        // BYTES_PER_PARAM_F32)
            rep["upload_bytes_int8_measured"] = False
        if self.topology is not None:
            rep["tiers"] = tier_upload_report(
                client_upload_bytes=rep["upload_bytes"],
                n_syncs=self.topology.sync_count(self._t),
                n_edges=self.topology.n_edges, model_bytes=model_bytes)
        if "async" in self.state:
            stats = self.state["async"]["stats"]
            rep["arrivals"] = int(stats["arrivals"])
            rep["merges"] = int(stats["merges"])
        return rep

    def staleness_summary(self) -> dict:
        """Arrival/staleness statistics of an async session's ledger-side
        counters (carried in the round state, so they survive a resume):
        realized arrivals and merges, mean/max staleness over all arrivals,
        mean buffer occupancy at merge time, and the updates currently
        buffered awaiting the next merge."""
        if "async" not in self.state:
            raise ValueError("staleness_summary() needs executor='async' "
                             "(synchronous executors have no arrival "
                             "process)")
        a = self.state["async"]
        s = a["stats"]
        arrivals = int(s["arrivals"])
        merges = int(s["merges"])
        return {
            "arrivals": arrivals,
            "merges": merges,
            "mean_staleness": float(s["stale_sum"]) / max(1, arrivals),
            "max_staleness": int(s["stale_max"]),
            "mean_buffer_occupancy":
                int(s["occupancy_sum"]) / max(1, merges),
            "pending_now": int(np.asarray(a["pending_mask"]).sum()),
        }

    def ledger(self) -> dict:
        """Per-client energy/cost books accumulated in the round carry:
        ``energy_spent`` / ``train_rounds`` / ``est_rounds`` numpy arrays
        (checkpointed with the state, so they survive a resume)."""
        return {k: np.asarray(v) for k, v in self.state["ledger"].items()}

    def summary(self) -> dict:
        out = {"rounds_done": self._t, "strategy": self.fed.strategy,
               "policy": self.policy.name}
        if "test_acc" in self.metrics.history:
            out["test_acc"] = self.metrics.last("test_acc")
            out["test_acc_best"] = self.metrics.best("test_acc")
        led = self.ledger()
        decided = int(led["train_rounds"].sum() + led["est_rounds"].sum())
        out["train_fraction"] = (
            float(led["train_rounds"].sum()) / max(1, decided))
        out["energy_spent"] = float(led["energy_spent"].sum())
        return out
