"""Sweep runner: expand a base spec over parameter grids, run sessions,
emit a Table-I-style comparison.

The paper's headline result is a *family* of runs — strategies × budget
profiles × schedules under one data/model scenario. A sweep is exactly
that: a base :class:`~repro.api.spec.ExperimentSpec` plus a grid of field
overrides. Each cell runs as its own :class:`~repro.api.session.Session`
and reports final/best accuracy plus the Appendix-A ``cost_report``.

    spec = ExperimentSpec(rounds=80)
    result = run_sweep(spec, {"strategy": ["cc", "s2", "fedavg"],
                              "beta": [2, 4]})
    print(format_table(result))
"""
from __future__ import annotations

import itertools
from typing import Any, Callable, Mapping, Sequence

from repro.api.session import Session
from repro.api.spec import ExperimentSpec
from repro.utils.logging import log


def expand_grid(base: ExperimentSpec,
                grid: Mapping[str, Sequence[Any]]
                ) -> list[tuple[dict, ExperimentSpec]]:
    """Cartesian product of field overrides; returns (overrides, spec)
    per cell, in deterministic field-then-value order."""
    if not grid:
        return [({}, base)]
    names = list(grid)
    cells = []
    for values in itertools.product(*(grid[n] for n in names)):
        overrides = dict(zip(names, values))
        cells.append((overrides, base.replace(**overrides)))
    return cells


def _cell_key(overrides: dict) -> str:
    return ",".join(f"{k}={v}" for k, v in overrides.items()) or "base"


def run_sweep(base: ExperimentSpec, grid: Mapping[str, Sequence[Any]],
              *, verbose: bool = True,
              session_hook: Callable[[Session], None] | None = None) -> dict:
    """Run every grid cell; returns a comparison dict:

    ``{"grid": ..., "cells": {key: {"overrides", "spec", "acc",
    "acc_best", "metrics", "cost"}}, "ranking": [...]}``

    ``session_hook`` (if given) is called with each constructed session
    before it runs — the place to attach callbacks or checkpointing.
    """
    cells = {}
    for overrides, spec in expand_grid(base, grid):
        key = _cell_key(overrides)
        if verbose:
            log(f"sweep cell {key}", rounds=spec.rounds)
        sess = Session.from_spec(spec)
        if session_hook is not None:
            session_hook(sess)
        sess.run()
        cells[key] = {
            "overrides": dict(overrides),
            "spec": spec.to_dict(),
            "acc": sess.metrics.last("test_acc"),
            "acc_best": sess.metrics.best("test_acc"),
            "metrics": sess.metrics.history,
            "cost": sess.cost_report(),
        }
    ranking = sorted(cells, key=lambda k: -cells[k]["acc"])
    return {"grid": {k: list(v) for k, v in grid.items()},
            "base": base.to_dict(), "cells": cells, "ranking": ranking}


def format_table(result: dict) -> str:
    """Table-I-style text comparison: one row per cell, sorted by final
    accuracy, with the compute/upload savings next to it. The compute
    column shows the per-client breakdown (``cost_report``'s
    ``compute_frac_per_client``) as a min–max work range — the scalar mean
    hides exactly the heterogeneity the budget law creates."""
    rows = [f"{'cell':<36}{'acc':>8}{'best':>8}"
            f"{'compute saved':>15}{'client work':>14}{'upload MB':>11}"]
    for key in result["ranking"]:
        c = result["cells"][key]
        per_client = c["cost"]["compute_frac_per_client"]
        spread = f"{min(per_client):.2f}-{max(per_client):.2f}"
        rows.append(f"{key:<36}{c['acc']:>8.3f}{c['acc_best']:>8.3f}"
                    f"{c['cost']['compute_saved_frac']:>14.1%}"
                    f"{spread:>14}"
                    f"{c['cost']['upload_bytes'] / 1e6:>11.1f}")
    return "\n".join(rows)
