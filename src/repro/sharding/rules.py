"""Parameter/activation sharding rules for the production meshes.

Parameters get logical axes from their leaf *name* (the trailing dict key)
via :func:`param_logical_axes`; extra leading dims (layer-stacking, client
axis) are padded with None / 'clients'. The launcher builds a rule table per
(mesh, mode) with :func:`make_rules` and installs it as a
:class:`~repro.sharding.api.ShardingContext`.

Default layout (single pod, 16×16 ``(data, model)``):

  * **tensor parallel** over ``model``: head/ffn/vocab dims, MoE expert d_ff
    (ETP), RG-LRU channels, latent dims;
  * **FSDP** over ``data``: the other matmul dim of every weight (ZeRO-3 —
    params and optimizer state are fully sharded);
  * **activations**: batch over ``data`` (+``pod``), residual-stream seq dim
    over ``model`` (Megatron-style sequence parallelism) in train/prefill;
  * **federated state**: client axis over ``pod``.

``expert_parallel=True`` flips MoE expert weights to be sharded over experts
(EP) instead of d_ff — the §Perf alternative that introduces all-to-all.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.sharding.api import Rule, ShardingContext

# name -> logical axes of the TRAILING dims (leading dims padded with None)
_PARAM_AXES: dict[str, tuple] = {
    # embeddings / heads
    "table": ("vocab", "embed"),
    "lm_head": ("embed", "vocab"),
    # attention
    "wq": ("embed", "heads_flat"),
    "wk": ("embed", "kv_flat"),
    "wv": ("embed", "kv_flat"),
    "wo": ("heads_flat", "embed"),
    # MLA
    "wq_a": ("embed", "lora"),
    "wq_b": ("lora", "heads_flat"),
    "wkv_a": ("embed", "lora"),
    "wk_b": ("kv_lora", "heads_flat"),
    "wv_b": ("kv_lora", "heads_flat"),
    # dense FFN
    "w_gate": ("embed", "ffn"),
    "w_up": ("embed", "ffn"),
    "w_down": ("ffn", "embed"),
    # router
    "router": ("embed", None),
    # RG-LRU
    "w_gate_branch": ("embed", "rnn"),
    "w_rnn_branch": ("embed", "rnn"),
    "w_out": ("rnn", "embed"),
    "w_a": ("embed", "rnn"),
    "w_x": ("embed", "rnn"),
    "b_a": ("rnn",),
    "b_x": ("rnn",),
    "lam": ("rnn",),
    "conv_w": (None, "rnn"),
    "conv_b": ("rnn",),
    # xLSTM
    "w_in": ("embed", "ffn"),
    "w_if": ("ffn", None),
    "r": (None, None, None, None),
    "b_if": (None,),
    "b_in": (None,),
    "norm_scale": (None,),
    # norms / scalars
    "scale": (None,),
    "bias": (None,),
    # LoRA adapter factors (repro.models.lora); the rank dim carries the
    # 'lora' logical axis -> tensor-parallel over 'model'
    "lora_a": ("embed", "lora"),
    "lora_b": ("lora", "embed"),
}

# MoE expert tensors are disambiguated by rank (they live under 'ffn' too)
_MOE_AXES = {
    "w_gate": ("experts", "embed", "expert_ffn"),
    "w_up": ("experts", "embed", "expert_ffn"),
    "w_down": ("experts", "expert_ffn", "embed"),
}


def param_logical_axes(path: str, leaf: Any) -> tuple:
    parts = path.split("/")
    name = parts[-1]
    base: tuple | None = None
    if name in _MOE_AXES and leaf.ndim >= 3 and "shared" not in parts:
        base = _MOE_AXES[name]
    elif name in _PARAM_AXES:
        base = _PARAM_AXES[name]
    if base is None:
        base = (None,) * leaf.ndim
    if len(base) > leaf.ndim:
        base = base[-leaf.ndim:]
    pad = leaf.ndim - len(base)
    return (None,) * pad + tuple(base)


def params_pspecs(ctx: ShardingContext, params, *, client_axis: bool = False):
    """PartitionSpecs for a (possibly client-stacked) param pytree."""
    from repro.utils.pytree import tree_map_with_path

    def one(path, leaf):
        axes = param_logical_axes(path, leaf)
        if client_axis:
            axes = ("clients",) + axes[1:]
        return ctx.spec(axes, tuple(leaf.shape))

    return tree_map_with_path(one, params)


def make_rules(*, multi_pod: bool, mode: str,
               expert_parallel: bool = False,
               fsdp: bool = True, seq_parallel: bool = True,
               context_parallel_attn: bool = False,
               kv_divisible: bool = True
               ) -> dict[str, Rule]:
    """Build the logical→mesh table.

    mode: 'train' | 'prefill' | 'decode'.

    ``context_parallel_attn``: shard the attention *query seq* dim over
    ``model`` instead of heads — the launcher sets this when n_heads does
    not divide the model axis (e.g. qwen2-vl's 28 heads on 16-way TP).

    The KV *head_dim* is never sharded in train/prefill: it is the QKᵀ
    contracting dim, and sharding it makes XLA all-reduce the (B,H,Sq,Sk)
    score tensor — orders of magnitude more traffic than replicating K/V
    (§Perf iteration 1). In decode the scores are (B,H,1,C) ≈ tiny while
    the KV cache is huge, so there head_dim sharding is the right call.
    """
    dp = ("pod", "data") if multi_pod else ("data",)
    fsdp_ax: Rule = ["data"] if fsdp else []
    model: Rule = ["model"]
    rules: dict[str, Rule] = {
        # --- parameters ---
        "vocab": model,
        "embed": fsdp_ax,
        "heads_flat": model,
        "kv_flat": model,
        "lora": model,
        "kv_lora": model,
        "ffn": model,
        "expert_ffn": [] if expert_parallel else model,
        "experts": model if expert_parallel else [],
        # MoE token groups: under ETP shard groups as much as divisibility
        # allows; under EP leave `model` to the experts dim (the
        # group→expert resharding of the dispatch einsum is the all-to-all)
        "moe_groups": ["data"] if expert_parallel
        else [("data", "model"), "data", "model"],
        "rnn": model,
        # --- activations ---
        "batch": [dp if multi_pod else "data"],
        # decode with kv_heads ∤ model: q must follow the cache's
        # *head_dim* sharding (heads off) or GSPMD re-shards the whole
        # cache every token (§Perf D1); scores then partial-AR, which is
        # tiny for 1-token queries
        "heads": [] if (context_parallel_attn
                        or (mode == "decode" and not kv_divisible))
        else model,
        "kv_heads": [] if context_parallel_attn else model,
        "kv_head_dim": model if mode == "decode" else [],
        "qseq": model if (context_parallel_attn
                          and mode in ("train", "prefill")) else [],
        # --- federated state ---
        "clients": ["pod"] if multi_pod else ["data"],
    }
    if mode in ("train", "prefill") and seq_parallel:
        rules["seq"] = model
    else:
        rules["seq"] = []
    return rules


def make_fed_rules() -> dict[str, Rule]:
    """Logical→mesh table for the 2-D ``("clients", "model")`` federated
    mesh (:func:`repro.launch.mesh.make_fed_mesh`): stacked per-client
    adapter trees shard their leading dim over ``clients`` and the LoRA
    rank dim over ``model``; every other logical axis stays replicated —
    the bulk O(r·d) factor dims are what the client axis already splits.
    """
    return {
        "clients": ["clients"],
        "lora": ["model"],
        "kv_lora": ["model"],
    }


def batch_pspecs(ctx: ShardingContext, batch: dict):
    """Input-batch shardings: leading batch dim over data(+pod)."""
    out = {}
    for k, v in batch.items():
        if k == "pos3":                      # (3, B, S)
            out[k] = ctx.spec((None, "batch", None), tuple(v.shape))
        elif hasattr(v, "ndim") and v.ndim >= 1:
            out[k] = ctx.spec(("batch",) + (None,) * (v.ndim - 1),
                              tuple(v.shape))
        else:
            out[k] = ctx.spec((), ())
    return out


def cache_logical_axes(path: str, leaf) -> tuple:
    """Trailing-dim logical axes by leaf name; leading (layer-stack) dims are
    padded with None. States that are tiny either way stay unannotated."""
    name = path.split("/")[-1]
    nd = leaf.ndim
    if name in ("k", "v"):                  # (B, C, Kv, hd)
        base = ("batch", None, "kv_heads", "kv_head_dim")
    elif name == "ckv":                     # (B, C, d_c)
        base = ("batch", None, "kv_lora")
    elif name == "krope":
        base = ("batch", None, None)
    elif name == "conv":                    # (B, w−1, d)
        base = ("batch", None, "rnn")
    elif name == "c" and nd >= 4:           # mLSTM matrix memory
        base = ("batch", "heads", None, None)
    elif name == "h":                       # RG-LRU / sLSTM state (B, D)
        base = ("batch", "rnn")
    elif name in ("c", "n", "m"):
        base = (None,) * nd                 # small scalar-memory states
    elif name in ("pos", "idx"):
        base = (None,) * nd
    else:
        base = ("batch",) + (None,) * max(0, nd - 1)
    base = tuple(base)[-nd:] if len(base) > nd else tuple(base)
    return (None,) * (nd - len(base)) + base


def cache_pspecs(ctx: ShardingContext, caches, *, stacked: bool):
    """Specs for the decode caches produced by ``decoder.init_caches``.

    ``stacked``: leaves of scanned segments carry a leading layer dim.
    """
    from repro.utils.pytree import tree_map_with_path

    def one(path, leaf):
        axes = cache_logical_axes(path, leaf)
        if len(axes) < leaf.ndim:
            axes = (None,) * (leaf.ndim - len(axes)) + tuple(axes)
        return ctx.spec(axes, tuple(leaf.shape))

    del stacked
    return tree_map_with_path(one, caches)
