"""Logical-axis sharding API.

Model code never names mesh axes directly; it annotates arrays with *logical*
axes (``constrain(x, ("batch", "seq", "embed"))``) and parameter leaves get
logical axes from name-based rules (:mod:`repro.sharding.rules`). A
:class:`ShardingContext` installed by the launcher maps logical names →
mesh axes and applies ``with_sharding_constraint``; without a context every
call is the identity, so the same model code runs unsharded on CPU tests.

Assignment is greedy and divisibility-aware: for each tensor dim the first
mesh axis (or axis tuple) from the rule that (a) is not already used by an
earlier dim and (b) divides the dim size is taken; otherwise the dim falls
back to the next candidate in the rule list, then to unsharded. This is how
e.g. a KV cache declared ``("batch", None, "kv_heads", "kv_head_dim")`` ends
up head-sharded for 32-head models but head_dim-sharded for 8-KV-head models
on a 16-way tensor axis.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()

# a rule value: list of candidate mesh-axis assignments, each a str or tuple
Rule = list


@dataclass
class ShardingContext:
    mesh: Mesh
    rules: dict[str, Rule] = field(default_factory=dict)
    enabled: bool = True

    def _axis_size(self, mesh_ax) -> int:
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        if isinstance(mesh_ax, str):
            return sizes[mesh_ax]
        n = 1
        for m in mesh_ax:
            n *= sizes[m]
        return n

    def spec(self, logical_axes: tuple, shape: tuple | None = None) -> P:
        assigned: list = []
        used: set[str] = set()
        for i, ax in enumerate(logical_axes):
            if ax is None:
                assigned.append(None)
                continue
            candidates = self.rules.get(ax) or []
            if isinstance(candidates, (str, tuple)):
                candidates = [candidates]
            pick = None
            for cand in candidates:
                if cand is None:
                    break
                flat = (cand,) if isinstance(cand, str) else tuple(cand)
                if any(m in used for m in flat):
                    continue
                if shape is not None and shape[i] % self._axis_size(cand):
                    continue
                pick = cand
                used.update(flat)
                break
            assigned.append(pick)
        return P(*assigned)

    def sharding(self, logical_axes: tuple,
                 shape: tuple | None = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))


def current() -> ShardingContext | None:
    return getattr(_STATE, "ctx", None)


@contextmanager
def use_sharding(ctx: ShardingContext | None):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = ctx
    try:
        yield ctx
    finally:
        _STATE.ctx = prev


def constrain(x: jax.Array, logical_axes: tuple) -> jax.Array:
    """Constrain ``x``'s sharding by logical axes; identity w/o context."""
    ctx = current()
    if ctx is None or not ctx.enabled:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(
            f"logical axes {logical_axes} do not match rank {x.ndim}")
    return jax.lax.with_sharding_constraint(
        x, ctx.sharding(logical_axes, tuple(x.shape)))
