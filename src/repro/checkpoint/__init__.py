from repro.checkpoint.store import save_pytree, load_pytree, CheckpointManager  # noqa: F401
