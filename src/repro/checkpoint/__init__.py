from repro.checkpoint.store import (  # noqa: F401
    CheckpointManager,
    FED_STATE_KEYS,
    load_fed_state,
    load_pytree,
    save_fed_state,
    save_pytree,
)
