"""Pytree checkpointing to .npz (no orbax in the container).

Leaves are flattened with '/'-joined key paths; dtypes/shapes round-trip
exactly. ``CheckpointManager`` adds step-numbered saves with retention and
atomic writes (tmp + rename) so a crash mid-save never corrupts the latest
checkpoint — the property the federated launcher relies on for resuming
long cross-silo runs.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any
_SEP = "/"

#: the full federated round state (see ``repro.core.rounds.init_fed_state``)
#: — everything a bit-identical resume needs. ``params`` alone is NOT
#: enough: the stored Δ, stale local models, RNG key and round counter all
#: feed the next round's transition.
FED_STATE_KEYS = ("params", "deltas", "prev_local", "trained_ever",
                  "round", "key")

#: policy-mode carry keys (budget-policy rows, simulated device state,
#: energy/cost ledger — ``repro.core.budget`` / ``repro.system.devices``).
#: Saved whenever present; a stateful policy resumed without them would
#: silently restart its decision state, so ``save_fed_state`` treats them
#: as required once any of them appears in the state.
POLICY_STATE_KEYS = ("policy", "device", "ledger")

#: two-tier carry keys (the (E,)-stacked edge-aggregator models of
#: ``repro.core.hierarchy``). Saved whenever present so a mid-edge-period
#: resume continues bit-identically: the edge displacements accumulated
#: since the last server sync live ONLY here — restarting them from the
#: global params would silently rewind the current period.
HIER_STATE_KEYS = ("edge_params",)

#: async-executor carry key (the FedBuff machinery of
#: ``repro.core.async_rounds.init_async_carry``: in-flight pulled models,
#: pull-round/staleness counters, the pending delta buffer + masks, and
#: arrival/merge statistics). Saved whenever present so a mid-run resume
#: is bit-identical: a client whose update is still in flight — or
#: buffered awaiting the K-th arrival — lives ONLY here.
ASYNC_STATE_KEYS = ("async",)

#: subtrees an ``async`` carry must hold to be resumable
_ASYNC_SUBKEYS = ("inflight", "inflight_train", "pull_round", "pending",
                  "pending_mask", "pending_train", "pending_stale",
                  "pending_k", "stats")


def _is_typed_key(leaf) -> bool:
    try:
        return jax.dtypes.issubdtype(leaf.dtype, jax.dtypes.prng_key)
    except (AttributeError, TypeError):
        return False


def _flatten(tree: PyTree) -> tuple[dict[str, np.ndarray], dict]:
    flat = {}

    def _name(entry) -> str:
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.SequenceKey):
            return f"#{entry.idx}"
        return str(entry)

    dtypes = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_name(p) for p in path)
        if _is_typed_key(leaf):              # typed PRNG key: store raw
            dtypes[key] = f"prngkey:{jax.random.key_impl(leaf)}"
            arr = jax.random.key_data(leaf)
        else:
            arr = jnp.asarray(leaf)
        if arr.dtype == jnp.bfloat16:        # numpy has no bf16: store as
            dtypes[key] = "bfloat16"         # f32 (exact) + dtype tag
            arr = arr.astype(jnp.float32)
        flat[key] = np.asarray(arr)
    return flat, dtypes


def save_pytree(path: str, tree: PyTree, extra: dict | None = None) -> None:
    flat, dtypes = _flatten(tree)
    meta = {"keys": sorted(flat), "dtypes": dtypes, "extra": extra or {}}
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp.npz")
    os.close(fd)
    try:
        np.savez(tmp, __meta__=np.frombuffer(
            json.dumps(meta).encode(), dtype=np.uint8), **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def load_pytree(path: str, like: PyTree | None = None
                ) -> tuple[PyTree, dict]:
    """Load a checkpoint. If ``like`` given, restore its exact structure."""
    with np.load(path) as z:
        meta = json.loads(bytes(z["__meta__"]).decode())
        flat = {k: z[k] for k in z.files if k != "__meta__"}
    dtypes = meta.get("dtypes", {})

    def _revive(key: str, arr: np.ndarray):
        tag = dtypes.get(key, "")
        if tag == "bfloat16":
            return jnp.asarray(arr).astype(jnp.bfloat16)
        if tag.startswith("prngkey:"):
            return jax.random.wrap_key_data(
                jnp.asarray(arr), impl=tag.split(":", 1)[1])
        return jnp.asarray(arr)

    if like is None:
        # rebuild nested dicts from '/'-paths
        out: dict = {}
        for k, v in flat.items():
            node = out
            parts = k.split(_SEP)
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = _revive(k, v)
        return out, meta["extra"]
    paths = jax.tree_util.tree_flatten_with_path(like)[0]

    def _name(entry) -> str:
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.SequenceKey):
            return f"#{entry.idx}"
        return str(entry)

    leaves = []
    for path_entries, leaf in paths:
        key = _SEP.join(_name(p) for p in path_entries)
        if key not in flat:
            raise KeyError(
                f"checkpoint missing leaf {key!r} — the file predates the "
                "current state schema (e.g. a pre-policy-engine checkpoint "
                "without policy/device/ledger state) or was saved from a "
                "different configuration; re-run from the spec instead of "
                "resuming")
        arr = flat[key]
        if dtypes.get(key, "").startswith("prngkey:"):
            leaves.append(_revive(key, arr))
            continue
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key!r}: ckpt {arr.shape} vs {leaf.shape}")
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves), meta["extra"]


def _is_quantized_history(deltas) -> bool:
    """int8 Δ-history carry (``FedConfig.compress="int8"``): a flat
    payload/scales dict instead of the f32 client tree."""
    return isinstance(deltas, dict) and set(deltas) == {"payload", "scales"}


def _required_fed_keys(state: PyTree) -> tuple[str, ...]:
    """``prev_local`` is part of the resumable state EXCEPT for the int8
    replay carry, which provably never reads it (the strategy's estimate
    is a pure Δ replay) and so drops it from the round state entirely."""
    if _is_quantized_history(state.get("deltas")):
        return tuple(k for k in FED_STATE_KEYS if k != "prev_local")
    return FED_STATE_KEYS


def save_fed_state(path: str, state: PyTree,
                   extra: dict | None = None) -> None:
    """Checkpoint the *full* federated state (not just params).

    Refuses partial states: resuming from params alone silently restarts
    the Δ history, RNG stream and round counter, which is exactly the
    "cosmetic resume" bug this helper exists to prevent.
    """
    missing = [k for k in _required_fed_keys(state) if k not in state]
    if missing:
        raise ValueError(
            f"federated state is missing {missing}; a resumable checkpoint "
            f"needs all of {list(FED_STATE_KEYS)} (got {sorted(state)})")
    if any(k in state for k in POLICY_STATE_KEYS):
        missing = [k for k in POLICY_STATE_KEYS if k not in state]
        if missing:
            raise ValueError(
                f"policy-mode state is missing {missing}; a resumable "
                f"checkpoint needs all of {list(POLICY_STATE_KEYS)} once "
                "any is present")
    if "async" in state:
        missing = [k for k in _ASYNC_SUBKEYS if k not in state["async"]]
        if missing:
            raise ValueError(
                f"async carry is missing {missing}; a resumable async "
                f"checkpoint needs all of {list(_ASYNC_SUBKEYS)} — an "
                "in-flight or buffered update lives only there")
    save_pytree(path, state, extra=extra)


def load_fed_state(path: str, like: PyTree) -> tuple[PyTree, dict]:
    """Restore a full federated state saved by :func:`save_fed_state`;
    ``like`` is a freshly-initialized state supplying structure/dtypes."""
    state, extra = load_pytree(path, like=like)
    missing = [k for k in _required_fed_keys(state) if k not in state]
    if missing:
        raise ValueError(f"checkpoint {path!r} lacks federated state "
                         f"keys {missing}")
    return state, extra


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step:08d}.npz")

    def save(self, step: int, tree: PyTree, extra: dict | None = None) -> str:
        path = self._path(step)
        save_pytree(path, tree, extra={"step": step, **(extra or {})})
        self._gc()
        return path

    def save_fed(self, step: int, state: PyTree,
                 extra: dict | None = None) -> str:
        """Step-numbered :func:`save_fed_state` (full resumable state)."""
        path = self._path(step)
        save_fed_state(path, state, extra={"step": step, **(extra or {})})
        self._gc()
        return path

    def read_extra(self, step: int | None = None) -> dict:
        """Read a checkpoint's metadata without materializing its arrays —
        how a resume learns the spec/metrics before rebuilding the state."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        with np.load(self._path(step)) as z:
            meta = json.loads(bytes(z["__meta__"]).decode())
        return meta["extra"]

    def steps(self) -> list[int]:
        out = []
        for f in os.listdir(self.directory):
            if f.startswith("ckpt_") and f.endswith(".npz"):
                out.append(int(f[len("ckpt_"):-len(".npz")]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, like: PyTree, step: int | None = None
                ) -> tuple[PyTree, dict]:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return load_pytree(self._path(step), like=like)

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            os.remove(self._path(s))
