"""Unified decoder-only model covering all ten assigned architectures.

Layers are organized into config-declared segments (see
:mod:`repro.models.config`); a segment with ``repeat > 1`` is executed as one
``lax.scan`` over stacked per-super-block parameters, so the lowered HLO is
O(one super-block) regardless of depth — this is what keeps 62-layer configs
compilable and what bounds the remat carry stack.

Three entry points per model:
  * ``loss_and_metrics`` — training forward + chunked LM loss (+ MoE aux),
  * ``prefill``          — prompt forward that builds the decode caches,
  * ``decode_step``      — one token against the caches (``serve_step``).

Modality handling (the allowed frontend stubs):
  * VLM (qwen2-vl): the first ``n_vision_tokens`` positions take precomputed
    patch embeddings from the batch (vision tower is stubbed); positions are
    M-RoPE (3, B, S) ids.
  * Audio (musicgen): tokens are (B, K, S) EnCodec codebook streams; the
    embedding sums per-codebook tables and the loss averages K codebook
    heads (delay-pattern bookkeeping lives in the data pipeline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import blocks as blk
from repro.models import losses, nn
from repro.models.config import ArchConfig, Segment
from repro.sharding.api import constrain
from repro.utils.pytree import PyTree


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _segment_init(rng, cfg: ArchConfig, seg: Segment, dtype):
    out = []
    for j, kind in enumerate(seg.pattern):
        kj = jax.random.fold_in(rng, j)
        if seg.repeat > 1:
            keys = jax.random.split(kj, seg.repeat)
            pj = jax.vmap(lambda k, kind=kind: blk.block_init(
                k, cfg, kind, dtype))(keys)
        else:
            pj = blk.block_init(kj, cfg, kind, dtype)
        out.append(pj)
    return out


def model_init(rng, cfg: ArchConfig) -> PyTree:
    dtype = jnp.dtype(cfg.param_dtype)
    k_emb, k_seg, k_head = jax.random.split(rng, 3)
    params: dict = {}
    if cfg.n_codebooks:
        params["embed"] = {"table": nn.normal_init(
            k_emb, (cfg.n_codebooks, cfg.vocab, cfg.d_model), std=0.02,
            dtype=dtype)}
    else:
        params["embed"] = nn.embedding_init(k_emb, cfg.vocab, cfg.d_model,
                                            dtype=dtype)
    params["segments"] = [
        _segment_init(jax.random.fold_in(k_seg, i), cfg, seg, dtype)
        for i, seg in enumerate(cfg.segments)]
    params["final_norm"] = nn.rmsnorm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        if cfg.n_codebooks:
            params["lm_head"] = nn.normal_init(
                k_head, (cfg.n_codebooks, cfg.d_model, cfg.vocab),
                std=cfg.d_model ** -0.5, dtype=dtype)
        else:
            params["lm_head"] = nn.normal_init(
                k_head, (cfg.d_model, cfg.vocab), std=cfg.d_model ** -0.5,
                dtype=dtype)
    return params


def lm_heads(params, cfg: ArchConfig):
    """Return (D, V) head or (K, D, V) stacked codebook heads."""
    if cfg.tie_embeddings:
        t = params["embed"]["table"]
        if cfg.n_codebooks:
            return jnp.swapaxes(t, 1, 2)  # (K, D, V)
        return t.T
    return params["lm_head"]


# ---------------------------------------------------------------------------
# embedding / batch handling
# ---------------------------------------------------------------------------


def embed_batch(params, cfg: ArchConfig, batch: dict):
    """Returns (embeds (B,S,D), positions (S,), pos3 or None,
    targets, loss_mask)."""
    if cfg.n_codebooks:
        tokens = batch["tokens"]                       # (B, K, S)
        b, k, s = tokens.shape
        tabs = params["embed"]["table"]                # (K, V, D)
        embeds = jnp.zeros((b, s, cfg.d_model), tabs.dtype)
        for j in range(k):
            embeds = embeds + jnp.take(tabs[j], tokens[:, j], axis=0)
        targets = jnp.roll(tokens, -1, axis=-1)        # (B,K,S)
        mask = jnp.ones((b, s), jnp.float32).at[:, -1].set(0.0)
        positions = jnp.arange(s, dtype=jnp.int32)
        return embeds, positions, None, targets, mask
    tokens = batch["tokens"]                           # (B, S)
    b, s = tokens.shape
    embeds = nn.embedding_apply(params["embed"], tokens)
    pos3 = None
    if cfg.n_vision_tokens:
        nv = cfg.n_vision_tokens
        ve = batch["vision_embeds"].astype(embeds.dtype)  # (B, nv, D)
        embeds = jnp.concatenate([ve, embeds[:, nv:]], axis=1)
        pos3 = batch["pos3"]                           # (3, B, S)
        mask = jnp.concatenate(
            [jnp.zeros((b, nv)), jnp.ones((b, s - nv))], axis=1
        ).astype(jnp.float32).at[:, -1].set(0.0)
    elif cfg.mrope_sections:
        pos3 = batch.get("pos3")
        if pos3 is None:
            pos3 = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (3, b, s))
        mask = jnp.ones((b, s), jnp.float32).at[:, -1].set(0.0)
    else:
        mask = jnp.ones((b, s), jnp.float32).at[:, -1].set(0.0)
    targets = jnp.roll(tokens, -1, axis=-1)
    positions = jnp.arange(s, dtype=jnp.int32)
    return embeds, positions, pos3, targets, mask


# ---------------------------------------------------------------------------
# segment execution
# ---------------------------------------------------------------------------


def _pos3_slice(pos3):
    return pos3  # positions are shared across layers; placeholder for clarity


def run_segments(params, cfg: ArchConfig, x, positions, pos3, *,
                 mode: str, caches=None, capacity: int = 0,
                 force_window: int = 0):
    """Run all segments. mode: 'train' | 'prefill' | 'decode'.

    Returns (x, new_caches, aux). ``caches`` is required for decode; prefill
    creates caches; train returns None.
    """
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = []
    for si, seg in enumerate(cfg.segments):
        seg_params = params["segments"][si]
        seg_caches = caches[si] if caches is not None else None

        if seg.repeat == 1:
            ncs = []
            for j, kind in enumerate(seg.pattern):
                c = seg_caches[j] if seg_caches is not None else None
                if mode == "prefill":
                    x, nc, a = blk.block_prefill(
                        seg_params[j], cfg, kind, x, positions=positions,
                        pos3=pos3, capacity=capacity,
                        force_window=force_window)
                else:
                    x, nc, a = blk.block_apply(
                        seg_params[j], cfg, kind, x, positions=positions,
                        pos3=pos3, cache=c, force_window=force_window)
                ncs.append(nc)
                aux_total = aux_total + a
            new_caches.append(ncs if mode != "train" else None)
            continue

        # ---- scanned segment -----------------------------------------
        if mode == "train":
            def body(carry, xs):
                h, aux = carry
                blk_params = xs
                for j, kind in enumerate(seg.pattern):
                    h, _, a = blk.block_apply(
                        blk_params[j], cfg, kind, h, positions=positions,
                        pos3=pos3, cache=None, force_window=force_window)
                    aux = aux + a
                return (h, aux), None

            if cfg.remat:
                body = jax.checkpoint(body)
            (x, aux_total), _ = jax.lax.scan(
                body, (x, aux_total), seg_params)
            new_caches.append(None)
        elif mode == "prefill":
            def body(carry, xs):
                h, aux = carry
                blk_params = xs
                ncs = []
                for j, kind in enumerate(seg.pattern):
                    h, nc, a = blk.block_prefill(
                        blk_params[j], cfg, kind, h, positions=positions,
                        pos3=pos3, capacity=capacity,
                        force_window=force_window)
                    ncs.append(nc)
                    aux = aux + a
                return (h, aux), tuple(ncs)

            (x, aux_total), seg_new = jax.lax.scan(
                body, (x, aux_total), seg_params)
            new_caches.append(list(seg_new))
        else:  # decode
            def body(carry, xs):
                h, aux = carry
                blk_params, blk_caches = xs
                ncs = []
                for j, kind in enumerate(seg.pattern):
                    h, nc, a = blk.block_apply(
                        blk_params[j], cfg, kind, h, positions=positions,
                        pos3=pos3, cache=blk_caches[j],
                        force_window=force_window)
                    ncs.append(nc)
                    aux = aux + a
                return (h, aux), tuple(ncs)

            (x, aux_total), seg_new = jax.lax.scan(
                body, (x, aux_total), (seg_params, tuple(seg_caches)))
            new_caches.append(list(seg_new))
    if mode == "train":
        new_caches = None
    return x, new_caches, aux_total


# ---------------------------------------------------------------------------
# top-level entry points
# ---------------------------------------------------------------------------


def loss_and_metrics(params, cfg: ArchConfig, batch: dict):
    embeds, positions, pos3, targets, mask = embed_batch(params, cfg, batch)
    # bf16 residual stream (master weights stay f32): halves activation
    # collectives and remat traffic (§Perf iteration 2)
    x = constrain(embeds.astype(jnp.dtype(cfg.compute_dtype)),
                  ("batch", "seq", None))
    x, _, aux = run_segments(params, cfg, x, positions, pos3, mode="train")
    x = nn.rmsnorm_apply(params["final_norm"], x)
    x = constrain(x, ("batch", "seq", None))
    heads = lm_heads(params, cfg)
    if cfg.n_codebooks:
        loss, acc = losses.multihead_codebook_xent(
            x, targets, mask, heads, chunk=cfg.loss_chunk)
    else:
        loss, acc = losses.chunked_causal_xent(
            x, targets, mask, heads, chunk=cfg.loss_chunk)
    total = loss
    if cfg.moe is not None:
        total = total + cfg.moe.aux_loss_coef * aux / max(1, cfg.n_layers)
    metrics = {"loss": loss, "acc": acc, "aux": aux}
    return total, metrics


def prefill(params, cfg: ArchConfig, batch: dict, *, capacity: int,
            force_window: int = 0):
    """Prompt forward; returns (caches, logits of the last position)."""
    embeds, positions, pos3, _, _ = embed_batch(params, cfg, batch)
    x = constrain(embeds.astype(jnp.dtype(cfg.compute_dtype)),
                  ("batch", "seq", None))
    x, caches, _ = run_segments(params, cfg, x, positions, pos3,
                                mode="prefill", capacity=capacity,
                                force_window=force_window)
    x = nn.rmsnorm_apply(params["final_norm"], x)
    heads = lm_heads(params, cfg)
    last = x[:, -1:].astype(jnp.float32)
    if cfg.n_codebooks:
        logits = jnp.einsum("bsd,kdv->bskv", last,
                            heads.astype(jnp.float32))
    else:
        logits = last @ heads.astype(jnp.float32)
    return caches, logits


def decode_step(params, cfg: ArchConfig, tokens, t, caches, *,
                force_window: int = 0, pos3=None):
    """One serving step: embed token(s) at position ``t``, attend to caches.

    tokens: (B, 1) int32 — or (B, K, 1) for codebook archs. t: () int32.
    Returns (logits, new_caches).
    """
    positions = t[None].astype(jnp.int32)
    if cfg.n_codebooks:
        b = tokens.shape[0]
        tabs = params["embed"]["table"]
        embeds = jnp.zeros((b, 1, cfg.d_model), tabs.dtype)
        for j in range(cfg.n_codebooks):
            embeds = embeds + jnp.take(tabs[j], tokens[:, j], axis=0)
    else:
        embeds = nn.embedding_apply(params["embed"], tokens)
        b = tokens.shape[0]
    if cfg.mrope_sections and pos3 is None:
        pos3 = jnp.broadcast_to(t, (3, b, 1)).astype(jnp.int32)
    x = constrain(embeds.astype(jnp.dtype(cfg.compute_dtype)),
                  ("batch", None, None))
    x, new_caches, _ = run_segments(params, cfg, x, positions, pos3,
                                    mode="decode", caches=caches,
                                    force_window=force_window)
    x = nn.rmsnorm_apply(params["final_norm"], x)
    heads = lm_heads(params, cfg)
    xf = x.astype(jnp.float32)
    if cfg.n_codebooks:
        logits = jnp.einsum("bsd,kdv->bskv", xf, heads.astype(jnp.float32))
    else:
        logits = xf @ heads.astype(jnp.float32)
    return logits, new_caches


def init_caches(cfg: ArchConfig, batch: int, capacity: int,
                force_window: int = 0):
    """Zero caches matching run_segments' decode structure."""
    out = []
    for seg in cfg.segments:
        seg_caches = []
        for kind in seg.pattern:
            c = blk.init_block_cache(cfg, kind, batch, capacity,
                                     force_window=force_window)
            if seg.repeat > 1:
                c = jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x[None], (seg.repeat,) + x.shape), c)
            seg_caches.append(c)
        out.append(seg_caches)
    return out
