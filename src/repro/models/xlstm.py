"""xLSTM blocks — mLSTM (matrix memory) and sLSTM (scalar memory),
following arXiv:2405.04517.

* **mLSTM** is parallelizable: training/prefill uses the *chunkwise* form
  (intra-chunk quadratic attention-like term + inter-chunk recurrent state
  ``(C, n, m)`` carried by ``lax.scan``), decode is the O(1) recurrent step.
  Exponential input gate + sigmoid forget gate with the paper's max-state
  ``m`` stabilization.
* **sLSTM** has hidden-to-gate recurrence (R matrices, block-diagonal per
  head) and is inherently sequential: training scans over time.

Both blocks are self-contained (the assignment's ``d_ff=0``): mLSTM wraps the
cell in up/gate/down projections (pf=2), sLSTM follows with a small gated MLP
(pf=4/3). Simplifications vs. the reference implementation (learnable skip
scales, bias init schedules) are noted in DESIGN.md.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import nn
from repro.models.config import ArchConfig
from repro.sharding.api import constrain

_CHUNK = 256


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


class MLSTMCache(NamedTuple):
    c: jax.Array   # (B, H, hd, hd) matrix memory
    n: jax.Array   # (B, H, hd) normalizer
    m: jax.Array   # (B, H) stabilizer
    conv: jax.Array  # (B, w-1, d_inner) trailing conv inputs


def mlstm_init(rng, cfg: ArchConfig, dtype):
    d = cfg.d_model
    di = 2 * d                      # pf = 2 up-projection
    h = cfg.n_heads
    hd = di // h
    ks = jax.random.split(rng, 8)
    return {
        "w_up": nn.normal_init(ks[0], (d, di), std=d ** -0.5, dtype=dtype),
        "w_gate": nn.normal_init(ks[1], (d, di), std=d ** -0.5, dtype=dtype),
        "conv_w": nn.normal_init(ks[2], (4, di), std=0.5, dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": nn.normal_init(ks[3], (di, di), std=di ** -0.5, dtype=dtype),
        "wk": nn.normal_init(ks[4], (di, di), std=di ** -0.5, dtype=dtype),
        "wv": nn.normal_init(ks[5], (di, di), std=di ** -0.5, dtype=dtype),
        "w_if": nn.normal_init(ks[6], (di, 2 * h), std=di ** -0.5,
                               dtype=dtype),
        "b_if": jnp.concatenate([jnp.zeros((h,)), 3.0 * jnp.ones((h,))]
                                ).astype(jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "w_down": nn.normal_init(ks[7], (di, d), std=di ** -0.5, dtype=dtype),
    }


def init_mlstm_cache(batch: int, cfg: ArchConfig) -> MLSTMCache:
    di = 2 * cfg.d_model
    h = cfg.n_heads
    hd = di // h
    return MLSTMCache(
        c=jnp.zeros((batch, h, hd, hd), jnp.float32),
        n=jnp.zeros((batch, h, hd), jnp.float32),
        m=jnp.full((batch, h), -1e30, jnp.float32),
        conv=jnp.zeros((batch, 3, di), jnp.dtype(cfg.compute_dtype)),
    )


def _mlstm_chunk(q, k, v, lf, li, state):
    """One chunk of the stabilized chunkwise mLSTM.

    q,k,v: (B,H,L,hd) fp32 (k pre-scaled by hd^-0.5); lf, li: (B,H,L) log
    forget / log input gates; state: (c, n, m).
    """
    c_prev, n_prev, m_prev = state
    b = jnp.cumsum(lf, axis=-1)                       # inclusive Σ log f
    btot = b[..., -1:]                                # (B,H,1)
    # intra-chunk decay matrix D[t,s] = b_t − b_s + ĩ_s  (s ≤ t)
    dmat = b[..., :, None] - b[..., None, :] + li[..., None, :]
    ltri = jnp.tril(jnp.ones(dmat.shape[-2:], bool))
    dmat = jnp.where(ltri, dmat, -1e30)
    m_intra = jnp.max(dmat, axis=-1)                  # (B,H,L)
    m_inter = b + m_prev[..., None]
    m_t = jnp.maximum(m_inter, m_intra)
    dexp = jnp.exp(dmat - m_t[..., None])
    s_intra = jnp.einsum("bhtd,bhsd->bhts", q, k) * dexp
    h_intra = jnp.einsum("bhts,bhsd->bhtd", s_intra, v)
    n_intra = jnp.sum(s_intra, axis=-1)
    w_inter = jnp.exp(m_inter - m_t)                  # (B,H,L)
    h_inter = jnp.einsum("bhtd,bhdv->bhtv", q, c_prev) * w_inter[..., None]
    n_inter = jnp.einsum("bhtd,bhd->bht", q, n_prev) * w_inter
    denom = jnp.maximum(jnp.abs(n_intra + n_inter), jnp.exp(-m_t))
    h_out = (h_intra + h_inter) / denom[..., None]
    # ---- state update to chunk end ----
    g = btot - b + li                                 # B − b_s + ĩ_s
    m_new = jnp.maximum(btot[..., 0] + m_prev, jnp.max(g, axis=-1))
    wkv = jnp.exp(g - m_new[..., None])               # (B,H,L)
    c_new = (jnp.exp(btot[..., 0] + m_prev - m_new)[..., None, None] * c_prev
             + jnp.einsum("bhsd,bhsv,bhs->bhdv", k, v, wkv))
    n_new = (jnp.exp(btot[..., 0] + m_prev - m_new)[..., None] * n_prev
             + jnp.einsum("bhsd,bhs->bhd", k, wkv))
    return h_out, (c_new, n_new, m_new)


def _mlstm_sequence(q, k, v, lf, li, state, chunk: int):
    """Chunkwise scan. q,k,v: (B,H,S,hd); returns (h (B,H,S,hd), state)."""
    b_, h_, s, hd = q.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    nc = s // chunk

    def split(x):
        return x.reshape(x.shape[:2] + (nc, chunk) + x.shape[3:]) \
                .transpose(2, 0, 1, 3, *range(4, x.ndim + 1))

    qs, ks_, vs = split(q), split(k), split(v)
    lfs = lf.reshape(b_, h_, nc, chunk).transpose(2, 0, 1, 3)
    lis = li.reshape(b_, h_, nc, chunk).transpose(2, 0, 1, 3)

    def body(carry, xs):
        qc, kc, vc, lfc, lic = xs
        h_out, new = _mlstm_chunk(qc, kc, vc, lfc, lic, carry)
        return new, h_out

    body = jax.checkpoint(body)
    state, hs = jax.lax.scan(body, state, (qs, ks_, vs, lfs, lis))
    hs = hs.transpose(1, 2, 0, 3, 4).reshape(b_, h_, s, hd)
    return hs, state


def mlstm_block_apply(p, cfg: ArchConfig, x, *, cache: MLSTMCache | None):
    """x: (B, S, D). Returns (out, new_cache)."""
    b, s, d = x.shape
    di = 2 * d
    h = cfg.n_heads
    hd = di // h
    cdt = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cdt)
    up = xc @ p["w_up"].astype(cdt)
    gate = xc @ p["w_gate"].astype(cdt)
    up = constrain(up, ("batch", None, "ffn"))
    # causal conv (width 4) on the cell branch
    w = p["conv_w"].shape[0]
    if cache is not None:
        xp = jnp.concatenate([cache.conv.astype(up.dtype), up], axis=1)
        new_conv = xp[:, -(w - 1):]
    else:
        xp = jnp.pad(up, ((0, 0), (w - 1, 0), (0, 0)))
        new_conv = None
    conv = jnp.zeros_like(up, dtype=jnp.float32)
    for j in range(w):
        conv = conv + xp[:, j: j + s].astype(jnp.float32) \
            * p["conv_w"][j].astype(jnp.float32)
    conv = jax.nn.silu(conv + p["conv_b"].astype(jnp.float32)).astype(cdt)

    def heads(t):
        return t.reshape(b, s, h, hd).transpose(0, 2, 1, 3)  # (B,H,S,hd)

    q = heads(conv @ p["wq"].astype(cdt)).astype(jnp.float32)
    k = heads(conv @ p["wk"].astype(cdt)).astype(jnp.float32) * hd ** -0.5
    v = heads(up @ p["wv"].astype(cdt)).astype(jnp.float32)
    if_ = conv.astype(jnp.float32) @ p["w_if"].astype(jnp.float32) \
        + p["b_if"]
    li = if_[..., :h].transpose(0, 2, 1)                 # log input gate ĩ
    lf = jax.nn.log_sigmoid(if_[..., h:]).transpose(0, 2, 1)

    if cache is None:
        state = (jnp.zeros((b, h, hd, hd), jnp.float32),
                 jnp.zeros((b, h, hd), jnp.float32),
                 jnp.full((b, h), -1e30, jnp.float32))
        hs, _ = _mlstm_sequence(q, k, v, lf, li, state, _CHUNK)
        new_cache = None
    else:
        state = (cache.c, cache.n, cache.m)
        if s == 1:
            hs, state = _mlstm_chunk(q, k, v, lf, li, state)
        else:
            hs, state = _mlstm_sequence(q, k, v, lf, li, state, _CHUNK)
        new_cache = MLSTMCache(c=state[0], n=state[1], m=state[2],
                               conv=new_conv)
    hs = hs.transpose(0, 2, 1, 3).reshape(b, s, di)
    hs = nn.rmsnorm_apply({"scale": p["norm_scale"]}, hs.astype(cdt))
    out = (hs * jax.nn.silu(gate)) @ p["w_down"].astype(cdt)
    return out.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


class SLSTMCache(NamedTuple):
    h: jax.Array  # (B, D)
    c: jax.Array  # (B, D)
    n: jax.Array  # (B, D)
    m: jax.Array  # (B, D)


def slstm_init(rng, cfg: ArchConfig, dtype):
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    ks = jax.random.split(rng, 4)
    # recurrent matrices are block-diagonal per head: (H, hd, hd) per gate
    return {
        "w_in": nn.normal_init(ks[0], (d, 4 * d), std=d ** -0.5, dtype=dtype),
        "b_in": jnp.concatenate(
            [jnp.zeros((d,)), jnp.zeros((d,)), 3.0 * jnp.ones((d,)),
             jnp.zeros((d,))]).astype(jnp.float32),
        "r": nn.normal_init(ks[1], (4, h, hd, hd), std=hd ** -0.5,
                            dtype=dtype),
        "norm_scale": jnp.ones((d,), dtype),
        "w_up": nn.normal_init(ks[2], (d, 2 * d), std=d ** -0.5, dtype=dtype),
        "w_down": nn.normal_init(ks[3], (d, d), std=d ** -0.5, dtype=dtype),
    }


def init_slstm_cache(batch: int, cfg: ArchConfig) -> SLSTMCache:
    d = cfg.d_model
    return SLSTMCache(h=jnp.zeros((batch, d), jnp.float32),
                      c=jnp.zeros((batch, d), jnp.float32),
                      n=jnp.zeros((batch, d), jnp.float32),
                      m=jnp.full((batch, d), -1e30, jnp.float32))


def _slstm_step(p, cfg: ArchConfig, wx_t, state: SLSTMCache) -> tuple:
    """wx_t: (B, 4D) precomputed input projection for one timestep."""
    d = cfg.d_model
    h_heads = cfg.n_heads
    hd = d // h_heads
    hprev = state.h.reshape(-1, h_heads, hd)
    r = p["r"].astype(jnp.float32)
    rec = jnp.einsum("bhd,ghde->gbhe", hprev, r)  # (4, B, H, hd)
    rec = rec.reshape(4, -1, d)
    pre = wx_t.astype(jnp.float32) + p["b_in"] \
        + jnp.concatenate([rec[0], rec[1], rec[2], rec[3]], axis=-1)
    z, i_, f_, o_ = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z)
    o = jax.nn.sigmoid(o_)
    lf = jax.nn.log_sigmoid(f_)
    m_new = jnp.maximum(lf + state.m, i_)
    iexp = jnp.exp(i_ - m_new)
    fexp = jnp.exp(lf + state.m - m_new)
    c_new = fexp * state.c + iexp * z
    n_new = jnp.maximum(fexp * state.n + iexp, 1e-6)
    h_new = o * c_new / n_new
    return SLSTMCache(h=h_new, c=c_new, n=n_new, m=m_new), h_new


def slstm_block_apply(p, cfg: ArchConfig, x, *, cache: SLSTMCache | None):
    """x: (B, S, D). Sequential scan over time (sLSTM is not parallel)."""
    b, s, d = x.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    wx = x.astype(cdt) @ p["w_in"].astype(cdt)        # (B,S,4D)
    state = cache if cache is not None else init_slstm_cache(b, cfg)
    if s == 1:
        state, h_new = _slstm_step(p, cfg, wx[:, 0], state)
        hs = h_new[:, None, :]
    else:
        def body(st, wx_t):
            st, h_new = _slstm_step(p, cfg, wx_t, st)
            return st, h_new

        state, hs = jax.lax.scan(body, state, wx.transpose(1, 0, 2))
        hs = hs.transpose(1, 0, 2)
    hs = nn.rmsnorm_apply({"scale": p["norm_scale"]}, hs.astype(cdt))
    up = hs @ p["w_up"].astype(cdt)
    g, u = jnp.split(up, 2, axis=-1)
    out = (nn.gelu(g) * u) @ p["w_down"].astype(cdt)
    new_cache = state if cache is not None else None
    return out.astype(x.dtype), new_cache
