"""Loss functions. Cross-entropy is chunked over the sequence so the
(B, S, vocab) logits tensor is never materialized — essential for the
256k-vocab architectures at 4k×256 batch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _chunk_xent(hidden_c, targets_c, mask_c, head, bias=None):
    """hidden_c: (B, C, D); targets_c: (B, C) int; mask_c: (B, C) float.
    head: (D, V). Returns (sum_loss, sum_correct, sum_mask)."""
    logits = hidden_c.astype(jnp.float32) @ head.astype(jnp.float32)
    if bias is not None:
        logits = logits + bias
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets_c[..., None],
                              axis=-1)[..., 0]
    nll = (lse - tgt) * mask_c
    correct = (jnp.argmax(logits, axis=-1) == targets_c) * mask_c
    return jnp.sum(nll), jnp.sum(correct), jnp.sum(mask_c)


def chunked_causal_xent(hidden, targets, mask, head, *, chunk: int = 512):
    """Mean next-token cross-entropy with seq-chunked logits.

    hidden: (B, S, D); targets/mask: (B, S). head: (D, V).
    Returns (loss, accuracy).
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    nc = s // chunk

    if nc == 1:
        tot, cor, cnt = _chunk_xent(hidden, targets, mask, head)
        cnt = jnp.maximum(cnt, 1.0)
        return tot / cnt, cor / cnt

    hs = hidden.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    ts = targets.reshape(b, nc, chunk).transpose(1, 0, 2)
    ms = mask.reshape(b, nc, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        tot, cor, cnt = carry
        h_c, t_c, m_c = xs
        a, b_, c = jax.checkpoint(_chunk_xent)(h_c, t_c, m_c, head)
        return (tot + a, cor + b_, cnt + c), None

    (tot, cor, cnt), _ = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros(()), jnp.zeros(())), (hs, ts, ms))
    cnt = jnp.maximum(cnt, 1.0)
    return tot / cnt, cor / cnt


def multihead_codebook_xent(hidden, targets, mask, heads, *, chunk: int = 512):
    """MusicGen-style loss over K codebook heads.

    hidden: (B, S, D); targets: (B, K, S); mask: (B, S); heads: (K, D, V).
    Mean over codebooks of per-codebook xent.
    """
    k = heads.shape[0]
    losses, accs = [], []
    for j in range(k):
        l, a = chunked_causal_xent(hidden, targets[:, j], mask, heads[j],
                                   chunk=chunk)
        losses.append(l)
        accs.append(a)
    return (jnp.mean(jnp.stack(losses)), jnp.mean(jnp.stack(accs)))
