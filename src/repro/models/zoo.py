"""Zoo models behind the federated ``Classifier`` interface.

The federated engine consumes ``Classifier(name, init, apply)`` and vmaps
``apply`` over a client axis; the zoo (decoder / MoE / xLSTM stacks in
``models/decoder.py``) speaks token batches. This adapter bridges the two:
float feature vectors are discretized into a token sequence (one token per
feature, sigmoid-binned into the vocab), run through ``run_segments`` in
train mode, and the last position's logits — tied-embedding head restricted
to the first ``n_classes`` vocab columns — are the classification output.

``sharding.api.constrain`` is the identity without an installed context, so
the same apply runs unsharded inside the federated vmap on CPU tests and
sharded under a launcher-installed mesh.

These are NOT meant to be federated densely: wrap them with
:func:`repro.models.lora.lora_classifier` (spec v7 requires ``lora_rank>=1``
for zoo models) so clients train/ship only the adapter subtree.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import nn
from repro.models.config import ArchConfig, MoEConfig, Segment
from repro.models.decoder import lm_heads, model_init, run_segments
from repro.models.simple import Classifier

ZOO_KINDS = ("decoder", "moe", "xlstm")


def zoo_arch_config(kind: str, *, width: int = 4, n_layers: int = 2,
                    vocab: int = 64) -> ArchConfig:
    """Tiny-but-real ArchConfig per zoo kind; ``d_model = 8 * width`` so the
    spec's existing ``width`` knob scales the stack (width 4 → d_model 32
    smoke configs, width 32 → d_model 256, ≈1.4M params)."""
    d = 8 * width
    common = dict(n_heads=2, n_kv_heads=2, head_dim=d // 2, vocab=vocab,
                  compute_dtype="float32", remat=False)
    if kind == "decoder":
        return ArchConfig(
            name=f"fed-decoder-{d}", arch_type="dense", d_model=d, d_ff=2 * d,
            segments=(Segment(n_layers, ("attn",)),), **common)
    if kind == "moe":
        # group_size >= any batch*seq we see -> a single dispatch group, so
        # token counts never need to divide the group size
        return ArchConfig(
            name=f"fed-moe-{d}", arch_type="moe", d_model=d, d_ff=2 * d,
            segments=(Segment(n_layers, ("attn",)),), ffn_kind="moe",
            moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=d,
                          capacity_factor=2.0, group_size=65536),
            **common)
    if kind == "xlstm":
        return ArchConfig(
            name=f"fed-xlstm-{d}", arch_type="ssm", d_model=d, d_ff=2 * d,
            segments=(Segment(n_layers, ("mlstm",)),), ffn_kind="none",
            **common)
    raise ValueError(f"unknown zoo kind {kind!r}; expected one of {ZOO_KINDS}")


def make_zoo_classifier(kind: str, *, input_shape, n_classes: int,
                        width: int = 4, n_layers: int = 2,
                        vocab: int = 64) -> Classifier:
    vocab = max(vocab, n_classes)
    cfg = zoo_arch_config(kind, width=width, n_layers=n_layers, vocab=vocab)

    def tokens_of(x):
        f = x.reshape(x.shape[0], -1).astype(jnp.float32)
        bins = jnp.floor(_sigmoid(f) * cfg.vocab)
        return jnp.clip(bins, 0, cfg.vocab - 1).astype(jnp.int32)

    def init(rng):
        return model_init(rng, cfg)

    def apply(p, x):
        toks = tokens_of(x)
        h = nn.embedding_apply(p["embed"], toks)
        positions = jnp.arange(toks.shape[1], dtype=jnp.int32)
        h, _, _ = run_segments(p, cfg, h.astype(jnp.float32), positions,
                               None, mode="train")
        h = nn.rmsnorm_apply(p["final_norm"], h)
        heads = lm_heads(p, cfg).astype(jnp.float32)
        logits = h[:, -1].astype(jnp.float32) @ heads
        return logits[:, :n_classes]

    return Classifier(f"zoo-{kind}", init, apply)


def _sigmoid(x):
    return 1.0 / (1.0 + jnp.exp(-x))
