"""Jit-able train / prefill / serve step factories for the decoder models.

These are the functions the launcher lowers in the multi-pod dry-run and the
federated engine calls for client-local training.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import decoder
from repro.models.config import ArchConfig
from repro.optim.optimizers import Optimizer
from repro.utils.pytree import PyTree


def init_train_state(rng, cfg: ArchConfig, optimizer: Optimizer) -> PyTree:
    params = decoder.model_init(rng, cfg)
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def cast_for_compute(params: PyTree, cfg: ArchConfig) -> PyTree:
    """bf16 forward copy of the f32 master weights, made ONCE before the
    layer scan so FSDP all-gathers move bf16, not f32 (§Perf). Routers
    stay f32 (routing logits are precision-sensitive)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    from repro.utils.pytree import tree_map_with_path

    def one(path, leaf):
        name = path.split("/")[-1]
        if leaf.dtype == jnp.float32 and leaf.ndim >= 1 \
                and name not in ("router", "lam", "b_a", "b_x", "b_if",
                                 "b_in"):
            return leaf.astype(cdt)
        return leaf

    return tree_map_with_path(one, params)


def make_train_step(cfg: ArchConfig, optimizer: Optimizer,
                    lr_schedule: Callable) -> Callable:
    def train_step(state: PyTree, batch: dict) -> tuple[PyTree, dict]:
        def loss_fn(params):
            return decoder.loss_and_metrics(
                cast_for_compute(params, cfg), cfg, batch)

        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        lr = lr_schedule(state["step"])
        params, opt = optimizer.update(state["params"], grads,
                                       state["opt"], lr)
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        metrics = dict(metrics, lr=lr,
                       grad_norm=_global_norm(grads))
        return new_state, metrics

    return train_step


def _global_norm(tree: PyTree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
             for l in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def make_prefill_step(cfg: ArchConfig, *, capacity: int,
                      force_window: int = 0) -> Callable:
    def prefill_step(params: PyTree, batch: dict):
        caches, logits = decoder.prefill(params, cfg, batch,
                                         capacity=capacity,
                                         force_window=force_window)
        return caches, logits

    return prefill_step


def make_decode_step(cfg: ArchConfig, *, force_window: int = 0) -> Callable:
    def serve_step(params: PyTree, caches: PyTree, tokens, t):
        logits, new_caches = decoder.decode_step(
            params, cfg, tokens, t, caches, force_window=force_window)
        return logits, new_caches

    return serve_step
