"""Architecture configuration schema.

An :class:`ArchConfig` fully describes one decoder-only model in the zoo.
Layers are organized into *segments*: a segment is a super-block pattern of
block types repeated ``repeat`` times. Uniform models are one segment with a
single-type pattern (ideal ``lax.scan``); Griffin-style hybrids repeat a
(rec, rec, attn) super-block; tiny mixed models may use repeat=1 segments
(Python loop). This keeps every lowered HLO O(one super-block) while keeping
per-layer FLOPs exact (no lax.switch branch padding).

Block types
-----------
``attn``   global causal attention (GQA, RoPE, optional QK-norm)
``swa``    sliding-window causal attention
``mla``    multi-head latent attention (DeepSeek/MiniCPM3 style)
``mrope``  global attention with multimodal RoPE sections (Qwen2-VL)
``rglru``  Griffin RG-LRU recurrent block (temporal conv + gated LRU)
``slstm``  xLSTM scalar-memory LSTM block
``mlstm``  xLSTM matrix-memory LSTM block

FFN kinds: ``swiglu`` | ``moe`` | ``none`` (x-LSTM blocks carry their own
up/down projections).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

ATTENTION_KINDS = ("attn", "swa", "mla", "mrope")
RECURRENT_KINDS = ("rglru", "slstm", "mlstm")
BLOCK_KINDS = ATTENTION_KINDS + RECURRENT_KINDS


@dataclass(frozen=True)
class Segment:
    repeat: int
    pattern: tuple[str, ...]

    def __post_init__(self):
        for b in self.pattern:
            if b not in BLOCK_KINDS:
                raise ValueError(f"unknown block kind {b!r}")

    @property
    def n_layers(self) -> int:
        return self.repeat * len(self.pattern)


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    group_size: int = 256          # GShard dispatch group size (tokens)
    aux_loss_coef: float = 0.01
    router_dtype: str = "float32"
    expert_parallel: bool = False  # False: TP on d_ff; True: EP + all-to-all


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_dim: int
    qk_rope_dim: int
    v_head_dim: int
    absorb: bool = True  # weight-absorbed decode (latent-space attention)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                      # dense|moe|audio|vlm|hybrid|ssm
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    segments: tuple[Segment, ...]
    ffn_kind: str = "swiglu"            # swiglu | moe | none
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    # attention details
    qk_norm: bool = False
    sliding_window: int = 0             # window for 'swa' blocks
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, ...] = ()
    # recurrent details
    rg_conv_width: int = 4
    rg_d_rnn: int = 0                   # 0 -> d_model
    # embeddings / heads
    n_codebooks: int = 0                # musicgen: EnCodec codebook streams
    n_vision_tokens: int = 0            # qwen2-vl: stub patch-embed prefix
    tie_embeddings: bool = True
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # KV-cache storage dtype for serving. fp8 halves decode-cache HBM —
    # needed for the MHA archs whose 32k×128 caches exceed v5e HBM
    # (beyond-paper optimization; §Perf).
    kv_cache_dtype: str = "bfloat16"
    # serve-path Pallas kernels (flash attention / RG-LRU scan). Forward
    # only (no custom VJP), so training always uses the jnp path; on
    # non-TPU backends the kernels run through the Pallas interpreter.
    use_pallas: bool = False
    # long-context serving: if >0, serve_step for the long_500k shape uses
    # this sliding window (sub-quadratic carve-out for full-attention archs;
    # recorded as a deviation in DESIGN.md §4).
    long_context_window: int = 0
    # loss / memory knobs
    loss_chunk: int = 512
    attn_q_chunk: int = 512
    attn_k_chunk: int = 1024
    remat: bool = True
    citation: str = ""

    def __post_init__(self):
        if self.ffn_kind == "moe" and self.moe is None:
            raise ValueError(f"{self.name}: ffn_kind=moe requires MoEConfig")
        if any("mla" in s.pattern for s in self.segments) and self.mla is None:
            raise ValueError(f"{self.name}: mla blocks require MLAConfig")
        if self.n_heads % max(1, self.n_kv_heads):
            raise ValueError(f"{self.name}: n_heads must divide by n_kv_heads")

    @property
    def n_layers(self) -> int:
        return sum(s.n_layers for s in self.segments)

    @property
    def d_rnn(self) -> int:
        return self.rg_d_rnn or self.d_model

    def block_kinds(self) -> tuple[str, ...]:
        out: list[str] = []
        for s in self.segments:
            for b in s.pattern:
                if b not in out:
                    out.append(b)
        return tuple(out)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: ≤2 layers-worth of segments, d_model≤256,
        ≤4 experts — runs a real fwd/train step on CPU."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        ratio = max(1, self.n_heads // max(1, self.n_kv_heads))
        n_kv = max(1, n_heads // ratio)
        head_dim = min(self.head_dim, 32)
        # 2 layers keeping one of each distinct kind from the original
        kinds = self.block_kinds()
        if len(kinds) > 1:
            segs = (Segment(repeat=1, pattern=kinds[:2]),)
        else:
            segs = (Segment(repeat=2, pattern=(kinds[0],)),)
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(2, self.moe.top_k),
                d_ff_expert=64, n_shared_experts=min(1, self.moe.n_shared_experts),
                group_size=16)
        mla = None
        if self.mla is not None:
            mla = MLAConfig(q_lora_rank=48, kv_lora_rank=32, qk_nope_dim=16,
                            qk_rope_dim=16, v_head_dim=16,
                            absorb=self.mla.absorb)
            head_dim = 32
        mrope = self.mrope_sections
        if mrope:
            half = head_dim // 2
            q = half // 4
            mrope = (half - 2 * q, q, q)
        return self.replace(
            d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
            head_dim=head_dim, d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            mrope_sections=mrope,
            vocab=min(self.vocab, 512), segments=segs, moe=moe, mla=mla,
            sliding_window=min(self.sliding_window, 8) if self.sliding_window else 0,
            long_context_window=min(self.long_context_window, 8)
            if self.long_context_window else 0,
            rg_d_rnn=min(self.d_rnn, 256) if self.rg_d_rnn else 0,
            n_vision_tokens=min(self.n_vision_tokens, 4),
            kv_cache_dtype="bfloat16",   # fp8 is a full-scale-serving knob
            loss_chunk=16, attn_q_chunk=8, attn_k_chunk=8)


@dataclass(frozen=True)
class InputShape:
    """One of the four assigned workload shapes."""
    name: str
    seq_len: int
    global_batch: int
    mode: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
