"""Attention blocks: GQA / sliding-window / M-RoPE / MLA, with a
memory-bounded blockwise (online-softmax) formulation.

Un-fused ``softmax(QKᵀ)V`` materializes an (Sq × Sk) score tensor per head —
at the assigned shapes (4k train, 32k prefill) that is tens of GB per device,
so the framework's reference attention is *blockwise*: a ``lax.scan`` over
key/value chunks carrying the online-softmax state ``(m, l, acc)``. This is
the same algorithm the Pallas flash kernel (:mod:`repro.kernels.flash_attention`)
implements at the VMEM-tile level; XLA sees only chunk-sized intermediates.

Numerical convention for masking: masked logits are set to a finite
``_MASK_VALUE`` (−1e30) and the running max starts there, so fully-masked
chunks (e.g. out-of-window blocks processed before the first in-window block)
contribute weight that is exactly flushed by the next real block's
renormalization — no NaNs, no ±inf arithmetic.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import nn
from repro.models.config import ArchConfig, MLAConfig
from repro.sharding.api import constrain

_MASK_VALUE = -1.0e30


# ---------------------------------------------------------------------------
# mask / position helpers
# ---------------------------------------------------------------------------


def _band_mask(q_pos, k_pos, window: int, k_valid=None):
    """(Sq, Sk) bool mask: causal ∧ in-window ∧ key-slot-valid."""
    m = k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    m &= k_pos[None, :] >= 0  # negative positions mark empty cache slots
    if k_valid is not None:
        m &= k_valid[None, :]
    return m


class AttnCache(NamedTuple):
    """Decode-time KV cache (ring buffer when windowed)."""
    k: jax.Array    # (B, C, Kv, hd)
    v: jax.Array    # (B, C, Kv, hd)
    pos: jax.Array  # (C,) absolute position held by each slot; -1 = empty
    idx: jax.Array  # () next write offset (monotonic token counter)


def init_attn_cache(batch: int, capacity: int, n_kv: int, head_dim: int,
                    dtype=jnp.bfloat16) -> AttnCache:
    return AttnCache(
        k=jnp.zeros((batch, capacity, n_kv, head_dim), dtype),
        v=jnp.zeros((batch, capacity, n_kv, head_dim), dtype),
        pos=jnp.full((capacity,), -1, jnp.int32),
        idx=jnp.zeros((), jnp.int32),
    )


def cache_write(cache: AttnCache, k_new, v_new, positions) -> AttnCache:
    """Write S_new tokens at ring slots (idx + arange) % capacity.

    The 1-token decode write uses dynamic_update_slice — a scatter here
    makes GSPMD replicate/re-shard the whole cache every step (was the
    entire decode collective term, §Perf D1)."""
    cap = cache.pos.shape[0]
    s_new = k_new.shape[1]
    if s_new == 1:
        slot = (cache.idx % cap).astype(jnp.int32)
        k = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k_new.astype(cache.k.dtype), slot, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v_new.astype(cache.v.dtype), slot, axis=1)
        pos = jax.lax.dynamic_update_slice(
            cache.pos, positions.astype(jnp.int32), (slot,))
        return AttnCache(k=k, v=v, pos=pos, idx=cache.idx + 1)
    slots = (cache.idx + jnp.arange(s_new, dtype=jnp.int32)) % cap
    k = cache.k.at[:, slots].set(k_new.astype(cache.k.dtype))
    v = cache.v.at[:, slots].set(v_new.astype(cache.v.dtype))
    pos = cache.pos.at[slots].set(positions.astype(jnp.int32))
    return AttnCache(k=k, v=v, pos=pos, idx=cache.idx + s_new)


# ---------------------------------------------------------------------------
# blockwise attention core
# ---------------------------------------------------------------------------


def blockwise_attention(q, k, v, q_pos, k_pos, *, window: int = 0,
                        k_chunk: int = 1024, scale: float | None = None,
                        logit_softcap: float = 0.0):
    """Online-softmax attention.

    q: (B, Sq, H, hd); k, v: (B, Sk, Kv, hd); H = Kv·G (GQA).
    q_pos: (Sq,), k_pos: (Sk,) absolute positions (−1 = invalid slot).
    Returns (B, Sq, H, hd) in q.dtype.
    """
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = scale if scale is not None else hd ** -0.5
    # keep q/k in their (bf16) dtype and accumulate the dot in f32 — the
    # MXU path; casting q/k to f32 first doubles HBM + gather traffic.
    # Heads stay FLAT (GQA K/V broadcast inside the chunk, fused by XLA):
    # a (KV, G) head split cannot express 16-way sharding when KV < 16,
    # which forced GSPMD to replicate the online-softmax carry (§Perf).
    qg = q * jnp.asarray(scale, q.dtype)             # (B,Sq,H,hd)

    def expand(t):   # (B,C,KV,hd) -> (B,C,H,hd)
        if g == 1:
            return t
        return jnp.repeat(t, g, axis=2)

    def chunk_scores(ks, kp):
        s = jnp.einsum("bqhd,bchd->bhqc", qg, expand(ks),
                       preferred_element_type=jnp.float32)
        if logit_softcap > 0.0:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        mask = _band_mask(q_pos, kp, window)  # (Sq, C)
        s = jnp.where(mask[None, None, :, :], s, _MASK_VALUE)
        return s

    if sk <= k_chunk:
        s = chunk_scores(k, k_pos)                   # (B,H,Sq,C)
        m = jnp.max(s, axis=-1, keepdims=True)
        m = jnp.maximum(m, _MASK_VALUE)
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        out = jnp.einsum("bhqc,bchd->bhqd", p.astype(v.dtype), expand(v),
                         preferred_element_type=jnp.float32)
        out = out / jnp.maximum(l, 1e-30)
        return out.transpose(0, 2, 1, 3).astype(q.dtype)

    while sk % k_chunk:  # largest divisor ≤ requested chunk
        k_chunk -= 1
    n_chunks = sk // k_chunk
    k_r = k.reshape(b, n_chunks, k_chunk, kv, hd)
    v_r = v.reshape(b, n_chunks, k_chunk, kv, hd)
    kp_r = k_pos.reshape(n_chunks, k_chunk)

    def body(carry, xs):
        m, l, acc = carry
        ks, vs, kp = xs
        s = chunk_scores(ks, kp)                     # (B,H,Sq,C)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))  # (B,H,Sq)
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqc,bchd->bhqd", p.astype(vs.dtype), expand(vs),
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    if _REMAT_CHUNKS:
        body = jax.checkpoint(body)
    # constrain the carry to q's sharding — an unconstrained carry makes
    # GSPMD replicate it and re-gather q every chunk (§Perf iteration 3)
    m0 = constrain(jnp.full((b, h, sq), _MASK_VALUE, jnp.float32),
                   ("batch", "heads", "qseq"))
    l0 = constrain(jnp.zeros((b, h, sq), jnp.float32),
                   ("batch", "heads", "qseq"))
    acc0 = constrain(jnp.zeros((b, h, sq, hd), jnp.float32),
                     ("batch", "heads", "qseq", None))
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (k_r.transpose(1, 0, 2, 3, 4), v_r.transpose(1, 0, 2, 3, 4), kp_r))
    out = acc / jnp.maximum(l, 1e-30)[..., None]     # (B,H,Sq,hd)
    out = out.transpose(0, 2, 1, 3)
    return out.astype(q.dtype)


# recompute chunk scores in the backward pass (flash-like memory); module
# flag so tests can disable it when probing gradients chunk-by-chunk.
_REMAT_CHUNKS = True


# ---------------------------------------------------------------------------
# GQA attention block (covers 'attn', 'swa', 'mrope')
# ---------------------------------------------------------------------------


def gqa_init(rng, cfg: ArchConfig, dtype):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 4)
    p = {
        "wq": nn.normal_init(ks[0], (d, h * hd), std=d ** -0.5, dtype=dtype),
        "wk": nn.normal_init(ks[1], (d, kv * hd), std=d ** -0.5, dtype=dtype),
        "wv": nn.normal_init(ks[2], (d, kv * hd), std=d ** -0.5, dtype=dtype),
        "wo": nn.normal_init(ks[3], (h * hd, d), std=(h * hd) ** -0.5,
                             dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = nn.rmsnorm_init(hd, dtype)
        p["k_norm"] = nn.rmsnorm_init(hd, dtype)
    return p


def _rope_for(cfg: ArchConfig, positions, pos3=None):
    """cos/sin for given positions; M-RoPE when cfg.mrope_sections set."""
    if cfg.mrope_sections:
        assert pos3 is not None, "mrope needs (3,B,S) positions"
        return nn.mrope_cos_sin(pos3, cfg.head_dim, cfg.mrope_sections,
                                cfg.rope_theta)
    cos, sin = nn.rope_cos_sin(positions[None, :], cfg.head_dim,
                               cfg.rope_theta)
    return cos, sin  # (1, S, hd/2) broadcasting over batch


def gqa_apply(p, cfg: ArchConfig, x, *, positions, window: int,
              cache: AttnCache | None, pos3=None):
    """x: (B, S, D). Returns (out, new_cache)."""
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cdt = jnp.dtype(cfg.compute_dtype)
    xq = x.astype(cdt)
    q = (xq @ p["wq"].astype(cdt)).reshape(b, s, h, hd)
    k = (xq @ p["wk"].astype(cdt)).reshape(b, s, kv, hd)
    v = (xq @ p["wv"].astype(cdt)).reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = nn.rmsnorm_apply(p["q_norm"], q)
        k = nn.rmsnorm_apply(p["k_norm"], k)
    cos, sin = _rope_for(cfg, positions, pos3)
    q = nn.apply_rope(q, cos, sin)
    k = nn.apply_rope(k, cos, sin)
    q = constrain(q, ("batch", "qseq", "heads", "kv_head_dim"))
    k = constrain(k, ("batch", None, "kv_heads", "kv_head_dim"))
    v = constrain(v, ("batch", None, "kv_heads", "kv_head_dim"))

    if cache is None:
        out = blockwise_attention(
            q, k, v, positions, positions, window=window,
            k_chunk=min(cfg.attn_k_chunk, s), scale=hd ** -0.5)
        new_cache = None
    else:
        cache = cache_write(cache, k, v, positions)
        cap = cache.k.shape[1]
        out = blockwise_attention(
            q, cache.k.astype(cdt), cache.v.astype(cdt),
            positions, cache.pos, window=window,
            k_chunk=cap if s == 1 else min(cfg.attn_k_chunk, cap),
            scale=hd ** -0.5)
        new_cache = cache
    out = constrain(out, ("batch", None, "heads", None))
    y = out.reshape(b, s, h * hd) @ p["wo"].astype(cdt)
    return y.astype(x.dtype), new_cache


def gqa_prefill_cache(p, cfg: ArchConfig, x, *, positions, window: int,
                      capacity: int, pos3=None):
    """Prefill: attention over the full prompt + build the decode cache.

    The cache keeps only the last ``capacity`` prompt tokens (ring-buffer
    semantics: token at position p lives in slot p % capacity), so windowed
    caches stay window-sized even for 32k prompts.
    """
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cdt = jnp.dtype(cfg.compute_dtype)
    xq = x.astype(cdt)
    q = (xq @ p["wq"].astype(cdt)).reshape(b, s, h, hd)
    k = (xq @ p["wk"].astype(cdt)).reshape(b, s, kv, hd)
    v = (xq @ p["wv"].astype(cdt)).reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = nn.rmsnorm_apply(p["q_norm"], q)
        k = nn.rmsnorm_apply(p["k_norm"], k)
    cos, sin = _rope_for(cfg, positions, pos3)
    q = nn.apply_rope(q, cos, sin)
    k = nn.apply_rope(k, cos, sin)
    q = constrain(q, ("batch", "qseq", "heads", "kv_head_dim"))
    k = constrain(k, ("batch", None, "kv_heads", "kv_head_dim"))
    v = constrain(v, ("batch", None, "kv_heads", "kv_head_dim"))
    if cfg.use_pallas and s % 128 == 0 and (window % 128 == 0):
        # prefill is forward-only and positions are contiguous — the
        # Pallas flash kernel applies directly (interpret mode off-TPU)
        from repro.kernels import ops as kops
        out = kops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True, window=window
        ).transpose(0, 2, 1, 3)
    else:
        out = blockwise_attention(q, k, v, positions, positions,
                                  window=window,
                                  k_chunk=min(cfg.attn_k_chunk, s),
                                  scale=hd ** -0.5)
    out = constrain(out, ("batch", None, "heads", None))
    y = out.reshape(b, s, h * hd) @ p["wo"].astype(cdt)
    # build the decode cache from the tail of the prompt
    tail = min(s, capacity)
    cache = init_attn_cache(b, capacity, kv, hd,
                            dtype=jnp.dtype(cfg.kv_cache_dtype))
    cache = cache._replace(idx=jnp.asarray(s - tail, jnp.int32))
    cache = cache_write(cache, k[:, s - tail:], v[:, s - tail:],
                        positions[s - tail:])
    return y.astype(x.dtype), cache


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2 / MiniCPM3)
# ---------------------------------------------------------------------------


class MLACache(NamedTuple):
    ckv: jax.Array    # (B, C, d_c) compressed latent (already RMS-normed)
    krope: jax.Array  # (B, C, rope_dim) shared roped key
    pos: jax.Array    # (C,)
    idx: jax.Array    # ()


def init_mla_cache(batch: int, capacity: int, mla: MLAConfig,
                   dtype=jnp.bfloat16) -> MLACache:
    return MLACache(
        ckv=jnp.zeros((batch, capacity, mla.kv_lora_rank), dtype),
        krope=jnp.zeros((batch, capacity, mla.qk_rope_dim), dtype),
        pos=jnp.full((capacity,), -1, jnp.int32),
        idx=jnp.zeros((), jnp.int32),
    )


def _mla_cache_write(cache: MLACache, ckv, k_rope, positions) -> MLACache:
    """Ring write; 1-token decode uses dynamic_update_slice (a scatter
    forces GSPMD to re-shard the whole latent cache per step — §Perf D1)."""
    cap = cache.pos.shape[0]
    s = ckv.shape[1]
    if s == 1:
        slot = (cache.idx % cap).astype(jnp.int32)
        return MLACache(
            ckv=jax.lax.dynamic_update_slice_in_dim(
                cache.ckv, ckv.astype(cache.ckv.dtype), slot, axis=1),
            krope=jax.lax.dynamic_update_slice_in_dim(
                cache.krope, k_rope.astype(cache.krope.dtype), slot,
                axis=1),
            pos=jax.lax.dynamic_update_slice(
                cache.pos, positions.astype(jnp.int32), (slot,)),
            idx=cache.idx + 1)
    slots = (cache.idx + jnp.arange(s, dtype=jnp.int32)) % cap
    return MLACache(
        ckv=cache.ckv.at[:, slots].set(ckv.astype(cache.ckv.dtype)),
        krope=cache.krope.at[:, slots].set(
            k_rope.astype(cache.krope.dtype)),
        pos=cache.pos.at[slots].set(positions.astype(jnp.int32)),
        idx=cache.idx + s)


def mla_init(rng, cfg: ArchConfig, dtype):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(rng, 7)
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    p = {
        "wq_a": nn.normal_init(ks[0], (d, m.q_lora_rank), std=d ** -0.5,
                               dtype=dtype),
        "q_norm": nn.rmsnorm_init(m.q_lora_rank, dtype),
        "wq_b": nn.normal_init(ks[1], (m.q_lora_rank, h * qk_dim),
                               std=m.q_lora_rank ** -0.5, dtype=dtype),
        "wkv_a": nn.normal_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_dim),
                                std=d ** -0.5, dtype=dtype),
        "kv_norm": nn.rmsnorm_init(m.kv_lora_rank, dtype),
        "wk_b": nn.normal_init(ks[3], (m.kv_lora_rank, h * m.qk_nope_dim),
                               std=m.kv_lora_rank ** -0.5, dtype=dtype),
        "wv_b": nn.normal_init(ks[4], (m.kv_lora_rank, h * m.v_head_dim),
                               std=m.kv_lora_rank ** -0.5, dtype=dtype),
        "wo": nn.normal_init(ks[5], (h * m.v_head_dim, d),
                             std=(h * m.v_head_dim) ** -0.5, dtype=dtype),
    }
    return p


def _mla_qkv(p, cfg: ArchConfig, x, positions):
    """Shared projections. Returns q_nope, q_rope, ckv_n, k_rope."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    cdt = jnp.dtype(cfg.compute_dtype)
    xq = x.astype(cdt)
    qa = nn.rmsnorm_apply(p["q_norm"], xq @ p["wq_a"].astype(cdt))
    q = (qa @ p["wq_b"].astype(cdt)).reshape(b, s, h,
                                             m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]
    kv_a = xq @ p["wkv_a"].astype(cdt)
    ckv = nn.rmsnorm_apply(p["kv_norm"], kv_a[..., : m.kv_lora_rank])
    k_rope = kv_a[..., m.kv_lora_rank:]
    cos, sin = nn.rope_cos_sin(positions[None, :], m.qk_rope_dim,
                               cfg.rope_theta)
    q_rope = nn.apply_rope(q_rope, cos, sin)
    k_rope = nn.apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    return q_nope, q_rope, ckv, k_rope


def mla_apply(p, cfg: ArchConfig, x, *, positions,
              cache: MLACache | None, window: int = 0):
    """MLA attention. Training/prefill uses the naive expanded form;
    decode uses the weight-absorbed latent form when ``cfg.mla.absorb``."""
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    cdt = jnp.dtype(cfg.compute_dtype)
    q_nope, q_rope, ckv, k_rope = _mla_qkv(p, cfg, x, positions)
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5

    if cache is not None and m.absorb and s == 1:
        # ---- absorbed decode: attend in latent space ------------------
        cache = _mla_cache_write(cache, ckv, k_rope, positions)
        wk_b = p["wk_b"].astype(cdt).reshape(m.kv_lora_rank, h, m.qk_nope_dim)
        # q_eff[h] = q_nope[h] @ wk_b[:,h,:]^T  -> latent-dim query
        q_lat = jnp.einsum("bshn,chn->bshc", q_nope, wk_b)
        ckv_c = cache.ckv.astype(jnp.float32)
        s_lat = jnp.einsum("bshc,btc->bhst", q_lat.astype(jnp.float32),
                           ckv_c)
        s_rope = jnp.einsum("bshr,btr->bhst", q_rope.astype(jnp.float32),
                            cache.krope.astype(jnp.float32))
        logits = (s_lat + s_rope) * scale
        mask = _band_mask(positions, cache.pos, window)
        logits = jnp.where(mask[None, None, :, :], logits, _MASK_VALUE)
        probs = jax.nn.softmax(logits, axis=-1)
        o_lat = jnp.einsum("bhst,btc->bshc", probs, ckv_c)  # (B,S,H,d_c)
        wv_b = p["wv_b"].astype(cdt).reshape(m.kv_lora_rank, h, m.v_head_dim)
        out = jnp.einsum("bshc,chv->bshv", o_lat.astype(cdt), wv_b)
        y = out.reshape(b, s, h * m.v_head_dim) @ p["wo"].astype(cdt)
        return y.astype(x.dtype), cache

    # ---- naive expanded form (train / prefill) ------------------------
    wk_b = p["wk_b"].astype(cdt).reshape(m.kv_lora_rank, h, m.qk_nope_dim)
    wv_b = p["wv_b"].astype(cdt).reshape(m.kv_lora_rank, h, m.v_head_dim)
    if cache is not None:
        cache = _mla_cache_write(cache, ckv, k_rope, positions)
        ckv_all = cache.ckv.astype(cdt)
        krope_all = cache.krope.astype(cdt)
        k_pos = cache.pos
    else:
        ckv_all, krope_all, k_pos = ckv, k_rope, positions
    k_nope = jnp.einsum("btc,chn->bthn", ckv_all, wk_b)
    v_full = jnp.einsum("btc,chv->bthv", ckv_all, wv_b)
    # pad v to qk dim so we can reuse blockwise_attention, then slice back
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope_all[:, :, None, :],
                                  k_nope.shape[:3] + (m.qk_rope_dim,))],
        axis=-1)
    v_pad = jnp.pad(v_full, ((0, 0), (0, 0), (0, 0),
                             (0, qk_dim - m.v_head_dim)))
    # same attention sharding policy as the GQA path (§Perf B1/B3):
    # context-parallel q when heads don't divide TP, K/V replicated
    q_full = constrain(q_full, ("batch", "qseq", "heads", None))
    k_full = constrain(k_full, ("batch", None, "kv_heads", None))
    v_pad = constrain(v_pad, ("batch", None, "kv_heads", None))
    sk = k_full.shape[1]
    chunk = min(cfg.attn_k_chunk, sk)
    out = blockwise_attention(q_full, k_full, v_pad, positions, k_pos,
                              window=window, k_chunk=chunk, scale=scale)
    out = out[..., : m.v_head_dim]
    y = out.reshape(b, s, h * m.v_head_dim) @ p["wo"].astype(cdt)
    return y.astype(x.dtype), cache
