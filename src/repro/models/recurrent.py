"""Griffin / RecurrentGemma RG-LRU residual block (arXiv:2402.19427).

Block structure (temporal-mixing half of a Griffin recurrent layer):

    x ─ rmsnorm ─┬─ linear → GeLU ────────────────────────┐
                 └─ linear → conv1d(w=4) → RG-LRU ─ ⊙ ────┴→ linear → out

RG-LRU recurrence (per channel):
    r_t = σ(W_a ξ_t + b_a)            (recurrence gate)
    i_t = σ(W_x ξ_t + b_x)            (input gate)
    a_t = a^(c·r_t),  a = σ(Λ)        (diagonal decay, c = 8)
    h_t = a_t ⊙ h_{t−1} + √(1 − a_t²) ⊙ (i_t ⊙ ξ_t)

Training/prefill evaluates the linear recurrence with
``jax.lax.associative_scan`` (TPU-friendly log-depth scan; the Pallas kernel
in :mod:`repro.kernels.rglru_scan` is the blocked VMEM version of the same
operator). Decode is the O(1) single step.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import nn
from repro.models.config import ArchConfig
from repro.sharding.api import constrain

_C = 8.0
_MAX_SQRT_GRADIENT = 1000.0


class RGLRUCache(NamedTuple):
    h: jax.Array       # (B, d_rnn) recurrent state (float32)
    conv: jax.Array    # (B, w-1, d_rnn) trailing conv inputs


def init_rglru_cache(batch: int, cfg: ArchConfig) -> RGLRUCache:
    return RGLRUCache(
        h=jnp.zeros((batch, cfg.d_rnn), jnp.float32),
        conv=jnp.zeros((batch, cfg.rg_conv_width - 1, cfg.d_rnn),
                       jnp.dtype(cfg.compute_dtype)),
    )


def rglru_init(rng, cfg: ArchConfig, dtype):
    d, dr, w = cfg.d_model, cfg.d_rnn, cfg.rg_conv_width
    ks = jax.random.split(rng, 6)
    # Λ init so that a = σ(Λ)^c ∈ [0.9, 0.999] (Griffin appendix)
    u = jax.random.uniform(ks[4], (dr,), minval=0.9 ** 2, maxval=0.999 ** 2)
    lam = jnp.log(u ** (1 / _C) / (1 - u ** (1 / _C))).astype(jnp.float32)
    return {
        "w_gate_branch": nn.normal_init(ks[0], (d, dr), std=d ** -0.5,
                                        dtype=dtype),
        "w_rnn_branch": nn.normal_init(ks[1], (d, dr), std=d ** -0.5,
                                       dtype=dtype),
        "conv_w": nn.normal_init(ks[2], (w, dr), std=w ** -0.5, dtype=dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        "w_a": nn.normal_init(ks[3], (dr, dr), std=dr ** -0.5, dtype=dtype),
        "b_a": jnp.zeros((dr,), jnp.float32),
        "w_x": nn.normal_init(ks[5], (dr, dr), std=dr ** -0.5, dtype=dtype),
        "b_x": jnp.zeros((dr,), jnp.float32),
        "lam": lam,
        "w_out": nn.normal_init(
            jax.random.fold_in(rng, 7), (dr, d), std=dr ** -0.5, dtype=dtype),
    }


def _sqrt_bounded_derivative(x):
    """sqrt with clipped derivative (Griffin's numerics trick)."""
    @jax.custom_gradient
    def f(v):
        s = jnp.sqrt(v)

        def grad(g):
            return (g * jnp.clip(0.5 / jnp.maximum(s, 1e-6),
                                 None, _MAX_SQRT_GRADIENT),)
        return s, grad
    return f(x)


def rglru_gates(p, xi):
    """Gate computations shared by scan and step. xi: (..., d_rnn)."""
    x32 = xi.astype(jnp.float32)
    r = jax.nn.sigmoid(x32 @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(x32 @ p["w_x"].astype(jnp.float32) + p["b_x"])
    log_a = -_C * r * jax.nn.softplus(-p["lam"])  # log σ(Λ)^(c·r) — stable
    a = jnp.exp(log_a)
    gated_x = i * x32
    multiplier = _sqrt_bounded_derivative(
        jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12, None))
    return a, multiplier * gated_x


_SCAN_CHUNK = 512


def rglru_scan(p, xi, h0):
    """Linear recurrence over the sequence — chunked associative scan.

    A monolithic ``associative_scan`` over S=4096+ materializes log₂(S)
    (B,S,D) f32 intermediates for the backward pass (23 GB/device for
    recurrentgemma-9b train_4k — §Perf); chunking to 512 with a scanned
    carry keeps the working set O(chunk) at identical math.

    xi: (B, S, d_rnn), h0: (B, d_rnn). Returns (hs (B,S,dr), h_last).
    """
    a, b = rglru_gates(p, xi)  # both (B, S, dr) float32

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    bsz, s, dr = a.shape
    chunk = min(_SCAN_CHUNK, s)
    while s % chunk:
        chunk -= 1
    n_chunks = s // chunk
    if n_chunks == 1:
        b = b.at[:, 0].add(a[:, 0] * h0)
        _, hs = jax.lax.associative_scan(combine, (a, b), axis=1)
        return hs, hs[:, -1]

    a_r = a.reshape(bsz, n_chunks, chunk, dr).transpose(1, 0, 2, 3)
    b_r = b.reshape(bsz, n_chunks, chunk, dr).transpose(1, 0, 2, 3)

    def body(h, ab):
        ac, bc = ab
        bc = bc.at[:, 0].add(ac[:, 0] * h)
        _, hs_c = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        return hs_c[:, -1], hs_c

    body = jax.checkpoint(body)
    h_last, hs = jax.lax.scan(body, h0, (a_r, b_r))
    hs = hs.transpose(1, 0, 2, 3).reshape(bsz, s, dr)
    return hs, h_last


def rglru_step(p, xi, h):
    """One decode step. xi: (B, 1, d_rnn), h: (B, d_rnn)."""
    a, b = rglru_gates(p, xi)
    h_new = a[:, 0] * h + b[:, 0]
    return h_new[:, None, :], h_new


def causal_conv1d(p, x, conv_state=None):
    """Depthwise causal conv width w. x: (B,S,dr). Returns (y, new_state)."""
    w = p["conv_w"].shape[0]
    if conv_state is not None:
        xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    else:
        xp = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    y = jnp.zeros_like(x, dtype=jnp.float32)
    s = x.shape[1]
    for j in range(w):
        y = y + xp[:, j: j + s].astype(jnp.float32) \
            * p["conv_w"][j].astype(jnp.float32)
    y = y + p["conv_b"].astype(jnp.float32)
    new_state = xp[:, -(w - 1):] if w > 1 else xp[:, :0]
    return y.astype(x.dtype), new_state


def rglru_block_apply(p, cfg: ArchConfig, x, *, cache: RGLRUCache | None):
    """Full Griffin recurrent block. x: (B, S, D)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cdt)
    gate = nn.gelu(xc @ p["w_gate_branch"].astype(cdt))
    xi = xc @ p["w_rnn_branch"].astype(cdt)
    xi = constrain(xi, ("batch", None, "rnn"))
    if cache is None:
        xi, _ = causal_conv1d(p, xi)
        hs, _ = rglru_scan(p, xi, jnp.zeros(
            (x.shape[0], cfg.d_rnn), jnp.float32))
        new_cache = None
    else:
        xi, conv_state = causal_conv1d(p, xi, conv_state=cache.conv)
        if x.shape[1] == 1:
            hs, h_last = rglru_step(p, xi, cache.h)
        elif cfg.use_pallas:
            # prefill is forward-only: run the recurrence through the
            # Pallas kernel (VMEM-blocked; interpret mode off-TPU)
            from repro.kernels import ops as kops
            a, b = rglru_gates(p, xi)
            hs = kops.rglru_scan(a, b, cache.h)
            h_last = hs[:, -1]
        else:
            hs, h_last = rglru_scan(p, xi, cache.h)
        new_cache = RGLRUCache(h=h_last, conv=conv_state)
    out = (hs.astype(cdt) * gate) @ p["w_out"].astype(cdt)
    return out.astype(x.dtype), new_cache


def rglru_prefill_cache(p, cfg: ArchConfig, x):
    """Prefill returning the final recurrent + conv state."""
    b = x.shape[0]
    cache = init_rglru_cache(b, cfg)
    return rglru_block_apply(p, cfg, x, cache=cache)
