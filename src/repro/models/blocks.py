"""Per-layer block: pre-norm temporal mixer + pre-norm FFN, dispatched on
block kind. One function pair (init/apply) covers all seven block kinds so
the decoder can stack heterogeneous patterns uniformly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import nn
from repro.models import moe as moe_mod
from repro.models import recurrent as rec
from repro.models import xlstm as xl
from repro.models.config import ArchConfig
from repro.sharding.api import constrain

_ATTN_KINDS = ("attn", "swa", "mrope")
_SELF_CONTAINED = ("slstm", "mlstm")  # no separate FFN half


def window_for(cfg: ArchConfig, kind: str, force_window: int = 0) -> int:
    if force_window > 0 and kind in _ATTN_KINDS + ("mla",):
        if kind == "swa" and cfg.sliding_window:
            return min(cfg.sliding_window, force_window)
        return force_window
    if kind == "swa":
        return cfg.sliding_window
    return 0


def ffn_init(rng, cfg: ArchConfig, dtype):
    if cfg.ffn_kind == "moe":
        return moe_mod.moe_init(rng, cfg, dtype)
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 3)
    return {
        "w_gate": nn.normal_init(ks[0], (d, f), std=d ** -0.5, dtype=dtype),
        "w_up": nn.normal_init(ks[1], (d, f), std=d ** -0.5, dtype=dtype),
        "w_down": nn.normal_init(ks[2], (f, d), std=f ** -0.5, dtype=dtype),
    }


def ffn_apply(p, cfg: ArchConfig, x):
    if cfg.ffn_kind == "moe":
        return moe_mod.moe_apply(p, cfg, x)
    cdt = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cdt)
    h = nn.swiglu(xc @ p["w_gate"].astype(cdt), xc @ p["w_up"].astype(cdt))
    h = constrain(h, ("batch", None, "ffn"))
    out = h @ p["w_down"].astype(cdt)
    return out.astype(x.dtype), jnp.zeros((), jnp.float32)


def block_init(rng, cfg: ArchConfig, kind: str, dtype):
    k1, k2, k3 = jax.random.split(rng, 3)
    p: dict = {"norm1": nn.rmsnorm_init(cfg.d_model, dtype)}
    if kind in _ATTN_KINDS:
        p["mixer"] = attn.gqa_init(k1, cfg, dtype)
    elif kind == "mla":
        p["mixer"] = attn.mla_init(k1, cfg, dtype)
    elif kind == "rglru":
        p["mixer"] = rec.rglru_init(k1, cfg, dtype)
    elif kind == "mlstm":
        p["mixer"] = xl.mlstm_init(k1, cfg, dtype)
    elif kind == "slstm":
        p["mixer"] = xl.slstm_init(k1, cfg, dtype)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    if kind not in _SELF_CONTAINED and cfg.ffn_kind != "none":
        p["norm2"] = nn.rmsnorm_init(cfg.d_model, dtype)
        p["ffn"] = ffn_init(k2, cfg, dtype)
    del k3
    return p


def init_block_cache(cfg: ArchConfig, kind: str, batch: int, capacity: int,
                     force_window: int = 0):
    w = window_for(cfg, kind, force_window)
    cap = min(capacity, w) if w else capacity
    kvdt = jnp.dtype(cfg.kv_cache_dtype)
    if kind in _ATTN_KINDS:
        return attn.init_attn_cache(batch, cap, cfg.n_kv_heads, cfg.head_dim,
                                    dtype=kvdt)
    if kind == "mla":
        return attn.init_mla_cache(batch, cap, cfg.mla, dtype=kvdt)
    if kind == "rglru":
        return rec.init_rglru_cache(batch, cfg)
    if kind == "mlstm":
        return xl.init_mlstm_cache(batch, cfg)
    if kind == "slstm":
        return xl.init_slstm_cache(batch, cfg)
    raise ValueError(kind)


def block_apply(p, cfg: ArchConfig, kind: str, x, *, positions, pos3=None,
                cache=None, force_window: int = 0):
    """Returns (x_out, new_cache, aux_loss)."""
    w = window_for(cfg, kind, force_window)
    h = nn.rmsnorm_apply(p["norm1"], x)
    if kind in _ATTN_KINDS:
        mix, new_cache = attn.gqa_apply(
            p["mixer"], cfg, h, positions=positions, window=w, cache=cache,
            pos3=pos3 if kind == "mrope" else None)
    elif kind == "mla":
        mix, new_cache = attn.mla_apply(p["mixer"], cfg, h,
                                        positions=positions, cache=cache,
                                        window=w)
    elif kind == "rglru":
        mix, new_cache = rec.rglru_block_apply(p["mixer"], cfg, h,
                                               cache=cache)
    elif kind == "mlstm":
        mix, new_cache = xl.mlstm_block_apply(p["mixer"], cfg, h, cache=cache)
    elif kind == "slstm":
        mix, new_cache = xl.slstm_block_apply(p["mixer"], cfg, h, cache=cache)
    else:
        raise ValueError(kind)
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    if "ffn" in p:
        f, aux = ffn_apply(p["ffn"], cfg, nn.rmsnorm_apply(p["norm2"], x))
        x = x + f
    x = constrain(x, ("batch", "seq", None))
    return x, new_cache, aux


def block_prefill(p, cfg: ArchConfig, kind: str, x, *, positions, pos3=None,
                  capacity: int = 0, force_window: int = 0):
    """Prefill: like apply but builds a fresh decode cache."""
    w = window_for(cfg, kind, force_window)
    b = x.shape[0]
    h = nn.rmsnorm_apply(p["norm1"], x)
    if kind in _ATTN_KINDS:
        cap = min(capacity, w) if w else capacity
        mix, new_cache = attn.gqa_prefill_cache(
            p["mixer"], cfg, h, positions=positions, window=w, capacity=cap,
            pos3=pos3 if kind == "mrope" else None)
    elif kind == "mla":
        cap = min(capacity, w) if w else capacity
        cache = attn.init_mla_cache(b, cap, cfg.mla,
                                    dtype=jnp.dtype(cfg.kv_cache_dtype))
        mix, new_cache = attn.mla_apply(p["mixer"], cfg, h,
                                        positions=positions, cache=cache,
                                        window=w)
    elif kind == "rglru":
        mix, new_cache = rec.rglru_prefill_cache(p["mixer"], cfg, h)
    elif kind == "mlstm":
        cache = xl.init_mlstm_cache(b, cfg)
        mix, new_cache = xl.mlstm_block_apply(p["mixer"], cfg, h, cache=cache)
    elif kind == "slstm":
        cache = xl.init_slstm_cache(b, cfg)
        mix, new_cache = xl.slstm_block_apply(p["mixer"], cfg, h, cache=cache)
    else:
        raise ValueError(kind)
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    if "ffn" in p:
        f, aux = ffn_apply(p["ffn"], cfg, nn.rmsnorm_apply(p["norm2"], x))
        x = x + f
    x = constrain(x, ("batch", "seq", None))
    return x, new_cache, aux
