"""LoRA adapters over the model zoo — the federated trainable subtree.

``lora_classifier`` wraps any :class:`~repro.models.simple.Classifier` so
that its *trainable* parameter tree contains only rank-r adapter factors
(plus, optionally, the small non-adapted leaves): the base weights are
materialized once from a fixed rng at wrap time and closed over. Because the
federated engine only ever sees ``model.init``/``model.apply``, every
executor, the int8 :class:`~repro.core.history_store.HistoryStore` and the
CC estimation replay automatically operate on the O(r·d) adapter subtree
instead of the O(P) dense tree — no masking inside ``core/rounds.py``.

Adapters live in a *flat* dict keyed by the '/'-joined path of the adapted
leaf in the base tree (list indices become string segments, matching
``tree_map_with_path``), so the trainable tree is plain nested dicts even
when the base tree holds lists of scanned segments::

    {"lora": {"segments/0/0/mixer/wq": {"lora_a": A, "lora_b": B}, ...},
     "base": {"final_norm/scale": s, ...}}          # freeze_base=False only

The effective weight is ``W + (alpha/r) * A @ B`` contracted over the last
two dims (``einsum("...ir,...ro->...io")``), so stacked leaves — scanned
layer repeats, MoE experts — adapt per leading index. ``B`` is
zero-initialized: the round-0 model is exactly the frozen base.

The leaf names ``lora_a``/``lora_b`` are registered in
``sharding/rules.py::_PARAM_AXES`` so ``params_pspecs`` places the rank dim
on the ``lora`` logical axis (→ ``model`` mesh axis) and, with
``client_axis=True``, the stacked per-client adapters shard over
``clients`` — the 2-D ``("clients", "model")`` federated mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.simple import Classifier

# leaf names eligible for adaptation: every zoo attention/MLP projection,
# plus the dense/conv kernels of the simple models
LORA_TARGETS = ("w", "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")


# ---------------------------------------------------------------------------
# path helpers (mirror tree_map_with_path's '/'-joined naming)
# ---------------------------------------------------------------------------


def _iter_leaves(tree, prefix=()):
    """Yield (path, leaf) depth-first with deterministic (sorted-key) order —
    the same order jax uses when flattening dicts."""
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _iter_leaves(tree[k], prefix + (str(k),))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _iter_leaves(v, prefix + (str(i),))
    else:
        yield "/".join(prefix), tree


def _get(tree, parts):
    node = tree
    for p in parts:
        node = node[int(p)] if isinstance(node, (list, tuple)) else node[p]
    return node


def _set(tree, parts, value):
    """Copy-on-write functional set along ``parts``."""
    head, rest = parts[0], parts[1:]
    if isinstance(tree, dict):
        new = dict(tree)
        new[head] = value if not rest else _set(tree[head], rest, value)
        return new
    i = int(head)
    seq = list(tree)
    seq[i] = value if not rest else _set(seq[i], rest, value)
    return tuple(seq) if isinstance(tree, tuple) else seq


# ---------------------------------------------------------------------------
# adapter construction
# ---------------------------------------------------------------------------


def _target_paths(base_params, targets) -> list[str]:
    return [path for path, leaf in _iter_leaves(base_params)
            if path.split("/")[-1] in targets
            and getattr(leaf, "ndim", 0) >= 2]


def _leaf_rank(leaf, rank) -> int:
    d_in = leaf.shape[-2]
    return d_in if rank == "full" else min(int(rank), d_in)


def _init_a(rng, leaf, r, kind):
    lead, d_in = leaf.shape[:-2], leaf.shape[-2]
    if kind == "identity":
        if r != d_in:
            raise ValueError("init_a='identity' needs rank == d_in "
                             f"(got r={r}, d_in={d_in})")
        return jnp.broadcast_to(jnp.eye(d_in, dtype=jnp.float32),
                                lead + (d_in, d_in))
    std = d_in ** -0.5
    return std * jax.random.normal(rng, lead + (d_in, r), dtype=jnp.float32)


def lora_classifier(base: Classifier, base_rng, rank, *,
                    alpha: float | None = None,
                    freeze_base: bool = True,
                    targets: tuple = LORA_TARGETS,
                    train_a: bool = True,
                    init_a: str = "normal") -> Classifier:
    """Wrap ``base`` so only LoRA factors (and, with ``freeze_base=False``,
    the non-adapted leaves) are trainable.

    rank: positive int, or ``"full"`` for per-leaf rank = d_in (with
        ``init_a="identity"``/``train_a=False``/``alpha=None`` this makes the
        wrapped model's SGD trajectory reproduce the dense path exactly).
    alpha: LoRA scale numerator; effective scale is ``alpha / r`` per leaf
        (``None`` → scale 1.0).
    train_a: with ``False`` the A factors are drawn once at wrap time and
        frozen; only B (and base leaves) remain trainable.
    """
    if rank != "full" and (not isinstance(rank, int) or rank < 1):
        raise ValueError(f"rank must be a positive int or 'full', got {rank!r}")
    if init_a not in ("normal", "identity"):
        raise ValueError(f"unknown init_a {init_a!r}")

    base_params = base.init(base_rng)
    paths = _target_paths(base_params, targets)
    if not paths:
        raise ValueError(f"no adaptable leaves named {targets} in "
                         f"{base.name!r}")
    leaves = {p: _get(base_params, p.split("/")) for p in paths}
    ranks = {p: _leaf_rank(leaves[p], rank) for p in paths}
    scales = {p: (1.0 if alpha is None else float(alpha) / ranks[p])
              for p in paths}
    frozen_paths = frozenset(paths)

    frozen_a = None
    if not train_a:
        a_rng = jax.random.fold_in(base_rng, 1)
        frozen_a = {p: _init_a(jax.random.fold_in(a_rng, i), leaves[p],
                               ranks[p], init_a)
                    for i, p in enumerate(paths)}

    def init(rng):
        adapters = {}
        for i, p in enumerate(paths):
            leaf = leaves[p]
            r = ranks[p]
            d_out = leaf.shape[-1]
            ab = {"lora_b": jnp.zeros(leaf.shape[:-2] + (r, d_out),
                                      dtype=jnp.float32)}
            if train_a:
                ab["lora_a"] = _init_a(jax.random.fold_in(rng, i), leaf,
                                       r, init_a)
            adapters[p] = ab
        out = {"lora": adapters}
        if not freeze_base:
            out["base"] = {p: l for p, l in _iter_leaves(base_params)
                           if p not in frozen_paths}
        return out

    def apply(p, x):
        eff = base_params
        for path, leaf in p.get("base", {}).items():
            eff = _set(eff, path.split("/"), leaf)
        for path, ab in p["lora"].items():
            a = ab["lora_a"] if train_a else frozen_a[path]
            w = _get(eff, path.split("/"))
            delta = jnp.einsum("...ir,...ro->...io", a, ab["lora_b"])
            eff = _set(eff, path.split("/"),
                       w + (scales[path] * delta).astype(w.dtype))
        return base.apply(eff, x)

    return Classifier(f"lora[{base.name}]", init, apply)


# ---------------------------------------------------------------------------
# reporting
# ---------------------------------------------------------------------------


def lora_report(base_params, trainable_params) -> dict:
    """Dense-vs-adapter size accounting: ``p_trainable`` is what the
    federated engine trains and the HistoryStore remembers per client,
    ``p_dense`` is the frozen base the adapters ride on."""
    from repro.utils.pytree import tree_bytes, tree_count_params

    p_dense = tree_count_params(base_params)
    p_trainable = tree_count_params(trainable_params)
    return {"p_dense": p_dense,
            "p_trainable": p_trainable,
            "trainable_bytes": tree_bytes(trainable_params),
            "trainable_frac": p_trainable / max(p_dense, 1)}
