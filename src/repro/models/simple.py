"""The paper's experiment models (§VI-A): a 3-layer MLP (FMNIST), a
2-conv + 3-fc CNN (CIFAR-10) and ResNet-18 with GroupNorm (CIFAR-100) —
re-implemented functionally so the federated engine can vmap them over a
client axis. Width/variant knobs let the synthetic-data reproductions run
within the CPU budget.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import nn


class Classifier(NamedTuple):
    name: str
    init: Callable          # rng -> params
    apply: Callable         # params, x -> logits


# ---------------------------------------------------------------------------
# MLP (paper: 3 fully-connected layers for FMNIST)
# ---------------------------------------------------------------------------


def make_mlp(input_dim: int, n_classes: int, hidden: int = 64) -> Classifier:
    def init(rng):
        k1, k2, k3 = jax.random.split(rng, 3)
        return {
            "fc1": nn.dense_init(k1, input_dim, hidden),
            "fc2": nn.dense_init(k2, hidden, hidden),
            "fc3": nn.dense_init(k3, hidden, n_classes),
        }

    def apply(p, x):
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(nn.dense_apply(p["fc1"], x))
        x = jax.nn.relu(nn.dense_apply(p["fc2"], x))
        return nn.dense_apply(p["fc3"], x)

    return Classifier("mlp", init, apply)


# ---------------------------------------------------------------------------
# CNN (paper: two conv-pool layers + three fc layers for CIFAR-10)
# ---------------------------------------------------------------------------


def make_cnn(hw: int, channels: int, n_classes: int,
             width: int = 16) -> Classifier:
    flat = (hw // 4) * (hw // 4) * (2 * width)

    def init(rng):
        ks = jax.random.split(rng, 5)
        return {
            "conv1": nn.conv2d_init(ks[0], channels, width, 3),
            "conv2": nn.conv2d_init(ks[1], width, 2 * width, 3),
            "fc1": nn.dense_init(ks[2], flat, 4 * width),
            "fc2": nn.dense_init(ks[3], 4 * width, 2 * width),
            "fc3": nn.dense_init(ks[4], 2 * width, n_classes),
        }

    def apply(p, x):
        x = jax.nn.relu(nn.conv2d_apply(p["conv1"], x))
        x = nn.max_pool(x, 2)
        x = jax.nn.relu(nn.conv2d_apply(p["conv2"], x))
        x = nn.max_pool(x, 2)
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(nn.dense_apply(p["fc1"], x))
        x = jax.nn.relu(nn.dense_apply(p["fc2"], x))
        return nn.dense_apply(p["fc3"], x)

    return Classifier("cnn", init, apply)


# ---------------------------------------------------------------------------
# ResNet-18 with GroupNorm (paper: CIFAR-100); `width` scales channels
# ---------------------------------------------------------------------------


def _basic_block_init(rng, c_in, c_out, stride):
    ks = jax.random.split(rng, 3)
    p = {
        "conv1": nn.conv2d_init(ks[0], c_in, c_out, 3, bias=False),
        "gn1": nn.groupnorm_init(c_out),
        "conv2": nn.conv2d_init(ks[1], c_out, c_out, 3, bias=False),
        "gn2": nn.groupnorm_init(c_out),
    }
    if stride != 1 or c_in != c_out:
        p["proj"] = nn.conv2d_init(ks[2], c_in, c_out, 1, bias=False)
        p["gn_proj"] = nn.groupnorm_init(c_out)
    return p


def _basic_block_apply(p, x, stride, groups):
    y = nn.conv2d_apply(p["conv1"], x, stride=stride)
    y = jax.nn.relu(nn.groupnorm_apply(p["gn1"], y, groups))
    y = nn.conv2d_apply(p["conv2"], y)
    y = nn.groupnorm_apply(p["gn2"], y, groups)
    if "proj" in p:
        x = nn.groupnorm_apply(
            p["gn_proj"], nn.conv2d_apply(p["proj"], x, stride=stride),
            groups)
    return jax.nn.relu(x + y)


def make_resnet18(channels: int, n_classes: int, width: int = 16,
                  groups: int = 8) -> Classifier:
    stage_channels = [width, 2 * width, 4 * width, 8 * width]
    strides = [1, 2, 2, 2]

    def init(rng):
        ks = jax.random.split(rng, 10)
        p = {"stem": nn.conv2d_init(ks[0], channels, width, 3, bias=False),
             "gn_stem": nn.groupnorm_init(width)}
        c_in = width
        i = 1
        for s, (c, st) in enumerate(zip(stage_channels, strides)):
            p[f"s{s}b0"] = _basic_block_init(ks[i], c_in, c, st)
            p[f"s{s}b1"] = _basic_block_init(ks[i + 1], c, c, 1)
            c_in = c
            i += 2
        p["fc"] = nn.dense_init(ks[9], stage_channels[-1], n_classes)
        return p

    def apply(p, x):
        x = jax.nn.relu(nn.groupnorm_apply(
            p["gn_stem"], nn.conv2d_apply(p["stem"], x), groups))
        for s, st in enumerate(strides):
            x = _basic_block_apply(p[f"s{s}b0"], x, st, groups)
            x = _basic_block_apply(p[f"s{s}b1"], x, 1, groups)
        x = nn.avg_pool_global(x)
        return nn.dense_apply(p["fc"], x)

    return Classifier("resnet18gn", init, apply)


# ---------------------------------------------------------------------------
# classification loss / metrics
# ---------------------------------------------------------------------------


def xent_loss(model: Classifier, params, xb, yb) -> jax.Array:
    logits = model.apply(params, xb).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, yb[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def accuracy(model: Classifier, params, xb, yb) -> jax.Array:
    logits = model.apply(params, xb)
    return jnp.mean((jnp.argmax(logits, -1) == yb).astype(jnp.float32))


def make_classifier(kind: str, *, input_shape, n_classes: int,
                    width: int = 16) -> Classifier:
    if kind == "mlp":
        dim = 1
        for d in input_shape:
            dim *= d
        return make_mlp(dim, n_classes, hidden=4 * width)
    if kind == "cnn":
        hw, _, ch = (input_shape + (1,))[:3] if len(input_shape) >= 2 \
            else (input_shape[0], input_shape[0], 1)
        return make_cnn(hw, ch, n_classes, width=width)
    if kind == "resnet18":
        ch = input_shape[-1] if len(input_shape) == 3 else 1
        return make_resnet18(ch, n_classes, width=width)
    raise ValueError(f"unknown classifier {kind!r}")
