"""Functional neural-network primitives (no flax/haiku dependency).

Every layer is a pair of pure functions:
  ``<layer>_init(rng, ...) -> params``  and  ``<layer>_apply(params, x, ...)``.
Params are plain nested dicts of jnp arrays so the federated engine can treat
every model uniformly as a pytree vector space.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def lecun_normal(rng, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis] if in_axis is not None else shape[0]
    std = 1.0 / math.sqrt(max(1, fan_in))
    return (std * jax.random.normal(rng, shape)).astype(dtype)


def he_normal(rng, shape, fan_in: int, dtype=jnp.float32):
    std = math.sqrt(2.0 / max(1, fan_in))
    return (std * jax.random.normal(rng, shape)).astype(dtype)


def normal_init(rng, shape, std: float = 0.02, dtype=jnp.float32):
    return (std * jax.random.normal(rng, shape)).astype(dtype)


# ---------------------------------------------------------------------------
# dense / embedding
# ---------------------------------------------------------------------------


def dense_init(rng, d_in: int, d_out: int, *, bias: bool = True,
               std: float | None = None, dtype=jnp.float32):
    wkey, _ = jax.random.split(rng)
    if std is None:
        w = lecun_normal(wkey, (d_in, d_out), dtype=dtype)
    else:
        w = normal_init(wkey, (d_in, d_out), std=std, dtype=dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def dense_apply(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def embedding_init(rng, vocab: int, d: int, *, std: float = 0.02,
                   dtype=jnp.float32):
    return {"table": normal_init(rng, (vocab, d), std=std, dtype=dtype)}


def embedding_apply(p, ids):
    return jnp.take(p["table"], ids, axis=0)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm_apply(p, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype),
            "bias": jnp.zeros((d,), dtype=dtype)}


def layernorm_apply(p, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(dt)


def groupnorm_init(c: int, dtype=jnp.float32):
    return {"scale": jnp.ones((c,), dtype=dtype),
            "bias": jnp.zeros((c,), dtype=dtype)}


def groupnorm_apply(p, x, n_groups: int = 32, eps: float = 1e-5):
    """GroupNorm over NHWC inputs (used by ResNet-18(GN), paper §VI-A)."""
    n, h, w, c = x.shape
    g = min(n_groups, c)
    while c % g != 0:  # keep group count valid for small channel dims
        g -= 1
    x32 = x.astype(jnp.float32).reshape(n, h, w, g, c // g)
    mu = jnp.mean(x32, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(x32, axis=(1, 2, 4), keepdims=True)
    y = ((x32 - mu) * jax.lax.rsqrt(var + eps)).reshape(n, h, w, c)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# conv / pooling (paper's CNN + ResNet-18)
# ---------------------------------------------------------------------------


def conv2d_init(rng, c_in: int, c_out: int, k: int, *, bias: bool = True,
                dtype=jnp.float32):
    w = he_normal(rng, (k, k, c_in, c_out), fan_in=k * k * c_in, dtype=dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((c_out,), dtype=dtype)
    return p


def conv2d_apply(p, x, stride: int = 1, padding: str = "SAME"):
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if "b" in p:
        y = y + p["b"]
    return y


def max_pool(x, k: int = 2, stride: int | None = None):
    stride = stride or k
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, stride, stride, 1), "VALID")


def avg_pool_global(x):
    return jnp.mean(x, axis=(1, 2))


# ---------------------------------------------------------------------------
# rotary position embeddings (standard + M-RoPE sections)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def rope_cos_sin(positions: jax.Array, head_dim: int,
                 theta: float = 10000.0):
    """positions: (..., S) int -> cos/sin of shape (..., S, head_dim//2)."""
    freqs = rope_frequencies(head_dim, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate pairs (x_even, x_odd). x: (B, S, H, hd); cos/sin: (B, S, hd//2)."""
    dt = x.dtype
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    r1 = x1 * c - x2 * s
    r2 = x2 * c + x1 * s
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(dt)


def mrope_cos_sin(positions_3: jax.Array, head_dim: int,
                  sections: Sequence[int], theta: float = 10000.0):
    """Multimodal RoPE (Qwen2-VL, arXiv:2409.12191).

    positions_3: (3, B, S) — temporal / height / width position ids.
    sections: half-dim split, e.g. (16, 12, 12) summing to head_dim//2.
    Returns cos/sin of shape (B, S, head_dim//2) assembled per-section from
    the corresponding position row.
    """
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    freqs = rope_frequencies(head_dim, theta)  # (hd//2,)
    cos_parts, sin_parts = [], []
    off = 0
    for row, sec in enumerate(sections):
        f = freqs[off:off + sec]
        ang = positions_3[row][..., None].astype(jnp.float32) * f
        cos_parts.append(jnp.cos(ang))
        sin_parts.append(jnp.sin(ang))
        off += sec
    return jnp.concatenate(cos_parts, -1), jnp.concatenate(sin_parts, -1)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True)
