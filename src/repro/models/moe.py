"""Mixture-of-Experts FFN — TPU-native grouped one-hot dispatch (GShard).

Routing math follows the assigned MoE cards (OLMoE 64e/top-8, Mixtral
8e/top-2, Moonlight 64e/top-6 + shared expert). Tokens are processed in
groups of ``group_size``; each expert has per-group capacity
``C = ceil(group_size · top_k · capacity_factor / E)``. Dispatch/combine are
dense one-hot einsums (MXU-friendly; no scatter), so total dispatch memory is
``tokens · group_size · top_k · cf`` — linear in group size, chosen small.

Two sharding regimes (the §Perf comparison):
* **ETP** (default): expert weights sharded on d_ff over the ``model`` axis;
  every device holds a slice of all experts; no all-to-all.
* **EP** (``expert_parallel=True``): experts sharded over ``model``; dispatch
  requires an all-to-all of (groups, E, C, D) blocks, expressed here via
  sharding constraints that force XLA to insert the collective.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import nn
from repro.models.config import ArchConfig
from repro.sharding.api import constrain


def moe_init(rng, cfg: ArchConfig, dtype):
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.n_experts
    ks = jax.random.split(rng, 5)
    p = {
        "router": nn.normal_init(ks[0], (d, e), std=d ** -0.5, dtype=jnp.float32),
        "w_gate": nn.normal_init(ks[1], (e, d, f), std=d ** -0.5, dtype=dtype),
        "w_up": nn.normal_init(ks[2], (e, d, f), std=d ** -0.5, dtype=dtype),
        "w_down": nn.normal_init(ks[3], (e, f, d), std=f ** -0.5, dtype=dtype),
    }
    if m.n_shared_experts:
        fs = f * m.n_shared_experts
        sk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": nn.normal_init(sk[0], (d, fs), std=d ** -0.5, dtype=dtype),
            "w_up": nn.normal_init(sk[1], (d, fs), std=d ** -0.5, dtype=dtype),
            "w_down": nn.normal_init(sk[2], (fs, d), std=fs ** -0.5, dtype=dtype),
        }
    return p


def expert_capacity(group_size: int, n_experts: int, top_k: int,
                    capacity_factor: float) -> int:
    c = math.ceil(group_size * top_k * capacity_factor / n_experts)
    return max(4, int(c))


def router_topk(logits: jax.Array, top_k: int):
    """Top-k routing with renormalized probabilities.

    logits: (G, S, E) float32. Returns (weights, sel) where sel: (G,S,k)
    expert ids and weights: (G,S,k) normalized gate values.
    """
    probs = jax.nn.softmax(logits, axis=-1)
    weights, sel = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    return weights, sel


def load_balance_loss(logits: jax.Array, sel: jax.Array, n_experts: int):
    """Switch/GShard aux loss: E · Σ_e f_e · P_e."""
    probs = jax.nn.softmax(logits, axis=-1)          # (G,S,E)
    pe = jnp.mean(probs, axis=(0, 1))                # (E,)
    onehot = jax.nn.one_hot(sel, n_experts)          # (G,S,k,E)
    fe = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))
    return n_experts * jnp.sum(fe * pe)


def moe_apply(p, cfg: ArchConfig, x):
    """x: (B, S, D) -> (out, aux_loss)."""
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.n_experts, m.top_k
    cdt = jnp.dtype(cfg.compute_dtype)
    tokens = b * s
    g = min(m.group_size, tokens)
    while tokens % g:
        g -= 1
    n_groups = tokens // g
    cap = expert_capacity(g, e, k, m.capacity_factor)

    xt = x.reshape(n_groups, g, d)
    # the (B,S,D)->(G,g,D) reshape fuses the batch and seq shardings; GSPMD
    # gives up and replicates unless we re-constrain the group axis
    xt = constrain(xt, ("moe_groups", None, None))
    logits = (xt.astype(jnp.float32)
              @ p["router"].astype(jnp.float32))     # (G,g,E)
    weights, sel = router_topk(logits, k)
    aux = load_balance_loss(logits, sel, e)

    # position of each (token, choice) within its expert's capacity buffer;
    # cumulative count over the flattened (token, choice) order implements
    # first-come-first-served capacity assignment (GShard).
    onehot = jax.nn.one_hot(sel, e, dtype=jnp.int32)          # (G,g,k,E)
    flat = onehot.reshape(n_groups, g * k, e)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat           # (G,g*k,E)
    pos_in_expert = pos_in_expert.reshape(n_groups, g, k, e)
    within_cap = pos_in_expert < cap
    # accumulate dispatch/combine per routing choice to keep the largest
    # intermediate at (G, g, E, C) rather than (G, g, k, E, C)
    disp_f = jnp.zeros((n_groups, g, e, cap), cdt)
    comb = jnp.zeros((n_groups, g, e, cap), cdt)
    for j in range(k):
        oh_j = onehot[:, :, j].astype(cdt)                   # (G,g,E)
        slot_j = (oh_j[..., None]
                  * within_cap[:, :, j, :, None].astype(cdt)
                  * jax.nn.one_hot(pos_in_expert[:, :, j], cap, dtype=cdt))
        disp_f = disp_f + slot_j
        comb = comb + weights[:, :, j, None, None].astype(cdt) * slot_j
    disp_f = constrain(disp_f, ("moe_groups", None, "experts", None))
    comb = constrain(comb, ("moe_groups", None, "experts", None))

    # expert inputs: (G, E, C, D)
    ein = jnp.einsum("gtec,gtd->gecd", disp_f, xt.astype(cdt))
    ein = constrain(ein, ("moe_groups", "experts", None, None))
    wg = p["w_gate"].astype(cdt)
    wu = p["w_up"].astype(cdt)
    wd = p["w_down"].astype(cdt)
    hidden = nn.swiglu(jnp.einsum("gecd,edf->gecf", ein, wg),
                       jnp.einsum("gecd,edf->gecf", ein, wu))
    hidden = constrain(hidden, ("moe_groups", "experts", None, "ffn"))
    eout = jnp.einsum("gecf,efd->gecd", hidden, wd)
    eout = constrain(eout, ("moe_groups", "experts", None, None))
    out = jnp.einsum("gtec,gecd->gtd", comb, eout)
    out = constrain(out, ("moe_groups", None, None))
    out = out.reshape(b, s, d)

    if m.n_shared_experts:
        sp = p["shared"]
        xs = x.astype(cdt)
        sh = nn.swiglu(xs @ sp["w_gate"].astype(cdt),
                       xs @ sp["w_up"].astype(cdt)) @ sp["w_down"].astype(cdt)
        out = out + sh
    return out.astype(x.dtype), aux
