"""Phi-3-mini-3.8B — dense RoPE+SwiGLU decoder [arXiv:2404.14219].

Pool line: 32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.
"""
from repro.models.config import ArchConfig, Segment

CONFIG = ArchConfig(
    name="phi3-mini-3.8b",
    arch_type="dense",
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab=32064,
    segments=(Segment(repeat=32, pattern=("attn",)),),
    rope_theta=10000.0,
    tie_embeddings=False,
    long_context_window=8192,
    kv_cache_dtype="float8_e4m3fn",   # 32k x 128 MHA cache exceeds HBM in bf16
    citation="arXiv:2404.14219 (Phi-3 Technical Report)",
)
