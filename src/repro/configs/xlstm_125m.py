"""xLSTM-125M — alternating sLSTM / mLSTM blocks [arXiv:2405.04517].

Pool line: 12L d_model=768 4H (GQA kv=4) d_ff=0 vocab=50304 — sLSTM +
mLSTM blocks. d_ff=0: the blocks are self-contained (mLSTM carries pf=2
up/down projections, sLSTM a pf≈4/3 gated MLP). Recurrent → long_500k is
natively O(1)-state.
"""
from repro.models.config import ArchConfig, Segment

CONFIG = ArchConfig(
    name="xlstm-125m",
    arch_type="ssm",
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab=50304,
    segments=(Segment(repeat=6, pattern=("mlstm", "slstm")),),
    ffn_kind="none",
    tie_embeddings=True,
    citation="arXiv:2405.04517 (xLSTM: Extended Long Short-Term Memory)",
)
