"""Moonlight-16B-A3B (moonshot-v1-16b-a3b) — DeepSeek-style fine-grained
MoE [hf:moonshotai/Moonlight-16B-A3B].

Pool line: 48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840,
MoE 64e top-6. The pool tags it [dense] but specifies the MoE — we
implement the MoE per the model card (deviation #5 in DESIGN.md), with
2 shared experts of the same 1408 width (DeepSeek-V3-style). The card's
first-layer-dense detail is dropped (all layers MoE) — noted in DESIGN.md.
"""
from repro.models.config import ArchConfig, MoEConfig, Segment

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    arch_type="moe",
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=163840,
    segments=(Segment(repeat=48, pattern=("attn",)),),
    ffn_kind="moe",
    # expert-parallel: 64 fine-grained experts shard over the model axis
    # (4/chip); beats ETP 2.5× on the train roofline — §Perf
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                  n_shared_experts=2, expert_parallel=True),
    rope_theta=50000.0,
    tie_embeddings=False,
    long_context_window=8192,
    kv_cache_dtype="float8_e4m3fn",   # 32k x 128 MHA cache exceeds HBM in bf16
    citation="hf:moonshotai/Moonlight-16B-A3B (Kimi/Moonlight card)",
)
