"""MusicGen-large — decoder-only LM over EnCodec tokens [arXiv:2306.05284].

Pool line: 48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048.
4 EnCodec codebook streams; embeddings are summed per codebook and the
model carries 4 parallel LM heads (delay-pattern bookkeeping lives in the
data pipeline). The EnCodec codec itself is the allowed frontend stub.
"""
from repro.models.config import ArchConfig, Segment

CONFIG = ArchConfig(
    name="musicgen-large",
    arch_type="audio",
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=2048,
    segments=(Segment(repeat=48, pattern=("attn",)),),
    n_codebooks=4,
    rope_theta=10000.0,
    tie_embeddings=False,
    long_context_window=8192,
    kv_cache_dtype="float8_e4m3fn",   # 32k x 128 MHA cache exceeds HBM in bf16
    citation="arXiv:2306.05284 (Simple and Controllable Music Generation)",
)
