"""Mixtral-8x22B — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088].

Pool line: 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768,
MoE 8e top-2, SWA. Window 4096 per the Mistral design the pool line tags.
SWA makes long_500k natively sub-quadratic (no carve-out needed).
"""
from repro.models.config import ArchConfig, MoEConfig, Segment

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    arch_type="moe",
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=32768,
    segments=(Segment(repeat=56, pattern=("swa",)),),
    ffn_kind="moe",
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384),
    sliding_window=4096,
    rope_theta=1000000.0,
    tie_embeddings=False,
    citation="arXiv:2401.04088 (Mixtral of Experts)",
)
