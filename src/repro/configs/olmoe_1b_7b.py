"""OLMoE-1B-7B — 64-expert top-8 MoE decoder [arXiv:2409.02060].

Pool line: 16L d_model=2048 16H (GQA kv=16) d_ff=1024 vocab=50304,
MoE 64e top-8. d_ff is the per-expert FFN width. OLMoE uses QK-norm.
"""
from repro.models.config import ArchConfig, MoEConfig, Segment

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    arch_type="moe",
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab=50304,
    segments=(Segment(repeat=16, pattern=("attn",)),),
    ffn_kind="moe",
    # expert-parallel: 64 fine-grained experts shard over the model axis
    # (4/chip); beats ETP 2.2× on the train roofline — §Perf
    moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024,
                  expert_parallel=True),
    qk_norm=True,
    rope_theta=10000.0,
    tie_embeddings=False,
    long_context_window=8192,   # sub-quadratic carve-out for long_500k
    citation="arXiv:2409.02060 (OLMoE: Open Mixture-of-Experts Language Models)",
)
