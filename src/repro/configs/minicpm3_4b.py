"""MiniCPM3-4B — dense decoder with MLA [hf:openbmb/MiniCPM3-4B].

Pool line: 62L d_model=2560 40H (GQA kv=40) d_ff=6400 vocab=73448 — MLA.
MLA dims follow the model card: q_lora_rank=768, kv_lora_rank=256,
qk_nope=64, qk_rope=32, v_head_dim=64 (head_dim = nope+rope = 96).
"""
from repro.models.config import ArchConfig, MLAConfig, Segment

CONFIG = ArchConfig(
    name="minicpm3-4b",
    arch_type="dense",
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=96,                 # qk_nope + qk_rope
    d_ff=6400,
    vocab=73448,
    segments=(Segment(repeat=62, pattern=("mla",)),),
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_dim=64,
                  qk_rope_dim=32, v_head_dim=64, absorb=True),
    rope_theta=10000.0,
    tie_embeddings=True,
    long_context_window=8192,
    citation="hf:openbmb/MiniCPM3-4B (MLA per DeepSeek-V2, arXiv:2405.04434)",
)
