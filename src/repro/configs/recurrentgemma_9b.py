"""RecurrentGemma-9B — Griffin hybrid: RG-LRU + local attention, 2:1
[arXiv:2402.19427].

Pool line: 38L d_model=4096 16H (GQA kv=1) d_ff=12288 vocab=256000 —
RG-LRU + local attn, 1:2 (one attention layer per two recurrent).
38 = 12×(rec,rec,attn) + (rec,rec). Local attention window 2048 per the
model card; lru_width = d_model. Natively sub-quadratic → long_500k runs
without a carve-out.
"""
from repro.models.config import ArchConfig, Segment

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    segments=(
        Segment(repeat=12, pattern=("rglru", "rglru", "swa")),
        Segment(repeat=1, pattern=("rglru", "rglru")),
    ),
    sliding_window=2048,
    rg_conv_width=4,
    rg_d_rnn=4096,
    rope_theta=10000.0,
    tie_embeddings=True,
    citation="arXiv:2402.19427 (Griffin / RecurrentGemma)",
)
