"""Qwen2-VL-7B — VLM decoder with M-RoPE [arXiv:2409.12191].

Pool line: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064 —
M-RoPE, dynamic resolution. mrope half-dim sections (16, 24, 24) sum to
head_dim//2 = 64 (temporal/height/width), matching the model card.
The ViT vision tower is the allowed frontend stub: ``input_specs`` supplies
precomputed patch embeddings + 3-row position ids.
"""
from repro.models.config import ArchConfig, Segment

CONFIG = ArchConfig(
    name="qwen2-vl-7b",
    arch_type="vlm",
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab=152064,
    segments=(Segment(repeat=28, pattern=("mrope",)),),
    mrope_sections=(16, 24, 24),
    n_vision_tokens=256,
    rope_theta=1000000.0,
    tie_embeddings=False,
    long_context_window=8192,
    citation="arXiv:2409.12191 (Qwen2-VL)",
)
