"""Qwen3-1.7B — dense GQA decoder with QK-norm [hf:Qwen/Qwen3-8B family].

Pool line: 28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936 —
qk_norm, GQA.
"""
from repro.models.config import ArchConfig, Segment

CONFIG = ArchConfig(
    name="qwen3-1.7b",
    arch_type="dense",
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab=151936,
    segments=(Segment(repeat=28, pattern=("attn",)),),
    qk_norm=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
    long_context_window=8192,
    citation="hf:Qwen/Qwen3-8B (Qwen3 family card)",
)
