"""Architecture registry + ShapeDtypeStruct input specs.

``get_config(name)`` returns the exact assigned configuration (full scale —
only the dry-run touches these); ``get_config(name, reduced=True)`` returns
the CPU-runnable smoke variant of the same family.

``input_specs(cfg, shape)`` builds weak-type-correct
:class:`jax.ShapeDtypeStruct` stand-ins for every input of the step the
shape exercises (train → ``train_step`` batch, prefill → prompt batch,
decode → (tokens, t, caches)). No device memory is allocated.
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.models.config import INPUT_SHAPES, ArchConfig, InputShape

_MODULES = {
    "olmoe-1b-7b": "olmoe_1b_7b",
    "minicpm3-4b": "minicpm3_4b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "mixtral-8x22b": "mixtral_8x22b",
    "musicgen-large": "musicgen_large",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "qwen3-1.7b": "qwen3_1_7b",
    "xlstm-125m": "xlstm_125m",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
}

ARCH_NAMES: tuple[str, ...] = tuple(_MODULES)


def get_config(name: str, *, reduced: bool = False) -> ArchConfig:
    if name not in _MODULES:
        raise ValueError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    cfg = mod.CONFIG
    return cfg.reduced() if reduced else cfg


def all_configs() -> dict[str, ArchConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}


# ---------------------------------------------------------------------------
# shape applicability (DESIGN.md §Arch-applicability)
# ---------------------------------------------------------------------------


def is_subquadratic(cfg: ArchConfig) -> bool:
    """True if every attention block is windowed or recurrent."""
    kinds = cfg.block_kinds()
    full_attn = [k for k in kinds if k in ("attn", "mla", "mrope")]
    return not full_attn


def decode_window(cfg: ArchConfig, shape: InputShape) -> int:
    """force_window for the given decode shape (0 = arch-native)."""
    if shape.name == "long_500k" and not is_subquadratic(cfg):
        if cfg.long_context_window <= 0:
            raise ValueError(
                f"{cfg.name}: long_500k needs sub-quadratic attention; set "
                "long_context_window for full-attention archs")
        return cfg.long_context_window
    return 0


def shape_supported(cfg: ArchConfig, shape: InputShape) -> bool:
    """All 40 pairs lower; full-attention archs use the sliding-window
    carve-out for long_500k (cfg.long_context_window)."""
    if shape.name == "long_500k" and not is_subquadratic(cfg):
        return cfg.long_context_window > 0
    return True


# ---------------------------------------------------------------------------
# ShapeDtypeStruct input specs
# ---------------------------------------------------------------------------


def _token_spec(cfg: ArchConfig, batch: int, seq: int):
    if cfg.n_codebooks:
        return jax.ShapeDtypeStruct((batch, cfg.n_codebooks, seq), jnp.int32)
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


def batch_specs(cfg: ArchConfig, batch: int, seq: int) -> dict:
    """Spec for one train/prefill batch dict (the modality stubs included)."""
    specs = {"tokens": _token_spec(cfg, batch, seq)}
    if cfg.n_vision_tokens:
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_vision_tokens, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))
        specs["pos3"] = jax.ShapeDtypeStruct((3, batch, seq), jnp.int32)
    elif cfg.mrope_sections:
        specs["pos3"] = jax.ShapeDtypeStruct((3, batch, seq), jnp.int32)
    return specs


def cache_specs(cfg: ArchConfig, batch: int, capacity: int,
                force_window: int = 0):
    """Decode-cache pytree as ShapeDtypeStructs (via eval_shape)."""
    from repro.models import decoder

    return jax.eval_shape(
        lambda: decoder.init_caches(cfg, batch, capacity,
                                    force_window=force_window))


def input_specs(cfg: ArchConfig, shape: InputShape | str) -> dict:
    """All inputs of the step this shape lowers, as ShapeDtypeStructs.

    train / prefill → ``{"batch": {...}}``;
    decode          → ``{"tokens", "t", "caches"}`` (1 new token vs a
    ``seq_len``-token KV cache, ring-buffered down to the window for
    windowed attention).
    """
    if isinstance(shape, str):
        shape = INPUT_SHAPES[shape]
    b, s = shape.global_batch, shape.seq_len
    if shape.mode in ("train", "prefill"):
        return {"batch": batch_specs(cfg, b, s)}
    fw = decode_window(cfg, shape)
    capacity = s
    tok = (jax.ShapeDtypeStruct((b, cfg.n_codebooks, 1), jnp.int32)
           if cfg.n_codebooks else jax.ShapeDtypeStruct((b, 1), jnp.int32))
    return {
        "tokens": tok,
        "t": jax.ShapeDtypeStruct((), jnp.int32),
        "caches": cache_specs(cfg, b, capacity, force_window=fw),
    }
