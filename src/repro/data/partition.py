"""Federated data partitioning following the paper's construction.

The paper (§VI-A) controls data heterogeneity with γ ∈ [0, 1] — "the
proportion of IID data across clients", following FedCos [39]:

* γ = 1  → IID: every client draws uniformly from all classes.
* γ = 0  → "totally non-IID": each client holds shards of a label-sorted
  pool, so each client sees only ~(n_classes / N) classes.
* 0<γ<1 → a γ-fraction of every client's samples comes from the IID pool,
  the rest from its label-sorted shard. The paper's "90% non-IID" means
  γ = 0.1 (10% IID share).

Also implements the cross-device assignment of Table II (each client gets
exactly ``classes_per_client`` classes) and the paper's compute-budget law
p_i = (1/2)^⌊β·i/N⌋ (§VI-A).
"""
from __future__ import annotations

import numpy as np

from repro.data.synthetic import Dataset


def partition_gamma(ds: Dataset, n_clients: int, gamma: float,
                    seed: int = 0) -> list[np.ndarray]:
    """Return per-client index arrays under the γ-heterogeneity scheme."""
    if not 0.0 <= gamma <= 1.0:
        raise ValueError(f"gamma must be in [0,1], got {gamma}")
    rng = np.random.default_rng(seed)
    n = len(ds)
    perm = rng.permutation(n)
    n_iid = int(round(gamma * n))
    iid_pool, sorted_pool = perm[:n_iid], perm[n_iid:]
    # label-sort the non-IID pool, then deal contiguous shards to clients
    sorted_pool = sorted_pool[np.argsort(ds.y[sorted_pool], kind="stable")]
    iid_split = np.array_split(iid_pool, n_clients)
    shard_split = np.array_split(sorted_pool, n_clients)
    out = []
    for i in range(n_clients):
        idx = np.concatenate([iid_split[i], shard_split[i]])
        rng.shuffle(idx)
        out.append(idx)
    return out


def partition_classes(ds: Dataset, n_clients: int, classes_per_client: int,
                      seed: int = 0) -> list[np.ndarray]:
    """Table-II style: each client holds ``classes_per_client`` classes.

    Each class's samples are spread evenly over the clients that own it
    ("each class of data is spread evenly among 10 clients" for N=100,
    2 classes/client, 10 classes).
    """
    rng = np.random.default_rng(seed)
    n_classes = ds.n_classes
    # assign class slots round-robin over a shuffled client order so every
    # class is owned by the same number of clients
    slots = np.repeat(np.arange(n_classes),
                      n_clients * classes_per_client // n_classes)
    rng.shuffle(slots)
    client_classes = slots.reshape(n_clients, classes_per_client)
    per_class_members: dict[int, list[int]] = {c: [] for c in range(n_classes)}
    for i in range(n_clients):
        for c in client_classes[i]:
            per_class_members[int(c)].append(i)
    out: list[list[int]] = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        members = per_class_members[c]
        if not members:
            continue
        idx = np.where(ds.y == c)[0]
        rng.shuffle(idx)
        for part, m in zip(np.array_split(idx, len(members)), members):
            out[m].extend(part.tolist())
    return [np.array(sorted(ix), dtype=np.int64) for ix in out]


def budget_law(n_clients: int, beta: int) -> np.ndarray:
    """The paper's heterogeneous budget: p_i = (1/2)^⌊β·i/N⌋ (§VI-A).

    β levels; clients are equally divided into groups with
    p ∈ {1, 1/2, 1/4, ...}. r ≈ 1 − 1/β clients are constrained.
    """
    i = np.arange(n_clients)
    return (0.5 ** np.floor(beta * i / n_clients)).astype(np.float64)


def two_group_budget(n_clients: int, r: float, w: int) -> np.ndarray:
    """§VI-E grid construction: (1−r)·N clients have p=1, r·N have p=1/W."""
    p = np.ones(n_clients)
    n_constrained = int(round(r * n_clients))
    if n_constrained:
        p[-n_constrained:] = 1.0 / max(1, w)
    return p


def skewed_budget_assignment(ds: Dataset, n_clients: int,
                             classes_per_client: int, beta: int,
                             skew: str = "random", seed: int = 0
                             ) -> tuple[list[np.ndarray], np.ndarray]:
    """Appendix-D constructions coupling data classes with budgets.

    skew = 'random'   → Table II (budgets assigned at random),
    skew = 'high'     → Table IV (clients sharing a class share a budget),
    skew = 'moderate' → Table V (10% follow 'high', rest 'random').
    """
    rng = np.random.default_rng(seed)
    parts = partition_classes(ds, n_clients, classes_per_client, seed=seed)
    base = budget_law(n_clients, beta)
    if skew == "random":
        p = rng.permutation(base)
    elif skew == "high":
        # sort clients by their dominant class so budget levels align with
        # class ownership (each class lives at a single budget level)
        dom = np.array([np.bincount(ds.y[ix], minlength=ds.n_classes).argmax()
                        if len(ix) else 0 for ix in parts])
        order = np.argsort(dom, kind="stable")
        p = np.empty(n_clients)
        p[order] = base
    elif skew == "moderate":
        p = rng.permutation(base)
        k = max(1, n_clients // 10)
        dom = np.array([np.bincount(ds.y[ix], minlength=ds.n_classes).argmax()
                        if len(ix) else 0 for ix in parts])
        order = np.argsort(dom, kind="stable")[:k]
        p[order] = np.sort(base)[:k]
    else:
        raise ValueError(f"unknown skew {skew!r}")
    return parts, p
