"""Stacked per-client datasets for the vectorized-client engine.

The CC-FedAvg engine vmaps local training over a leading client axis, so
client datasets are materialized as dense arrays ``(N, n_i_max, ...)`` with a
validity count per client. Batch sampling inside jit draws uniform indices
modulo each client's true size (unbiased within each client's local data —
Assumption 2).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import Dataset


@dataclass(frozen=True)
class FederatedData:
    x: jax.Array        # (N, M, ...) padded client features
    y: jax.Array        # (N, M) padded client labels
    sizes: jax.Array    # (N,) true per-client sample counts
    n_classes: int

    @property
    def n_clients(self) -> int:
        return self.x.shape[0]

    def client_batch(self, key: jax.Array, batch_size: int):
        """Sample one batch per client: returns (N, B, ...), (N, B)."""
        keys = jax.random.split(key, self.n_clients)

        def one(k, cx, cy, sz):
            idx = jax.random.randint(k, (batch_size,), 0, 2 ** 30) % sz
            return cx[idx], cy[idx]

        return jax.vmap(one)(keys, self.x, self.y, self.sizes)


@dataclass(frozen=True)
class CohortSampler:
    """Per-round cohorts for cross-device federations with N ≫ devices.

    The vectorized executors materialize every client's state, but a round
    only needs the sampled participants on device: the sharded executor
    gathers the cohort's history rows, runs the round ``shard_map``'ed over
    the client mesh, and scatters the updated rows back. Sampling is
    uniform without replacement and *absolute-round keyed* — round ``t``
    always draws the same cohort for a given seed, so resumed sessions see
    identical cohorts regardless of where they restart (the same contract
    the plan masks follow).

    ``cohort_size == n_clients`` degenerates to full participation
    (``indices_for(t) == arange(N)``), which is how the sharded executor
    stays numerically interchangeable with the others.
    """

    n_clients: int
    cohort_size: int
    seed: int = 0

    def __post_init__(self):
        if not 1 <= self.cohort_size <= self.n_clients:
            raise ValueError(
                f"cohort_size must be in [1, {self.n_clients}], "
                f"got {self.cohort_size}")

    def indices_for(self, t: int) -> np.ndarray:
        """Sorted participant ids for round ``t`` (deterministic in seed)."""
        if self.cohort_size == self.n_clients:
            return np.arange(self.n_clients)
        rng = np.random.default_rng((self.seed, t))
        return np.sort(rng.choice(self.n_clients, size=self.cohort_size,
                                  replace=False))

    def indices(self, rounds: int, start: int = 0) -> np.ndarray:
        """(rounds, cohort_size) int32 cohort table for rounds
        ``start .. start+rounds``."""
        return np.stack([self.indices_for(start + t)
                         for t in range(rounds)]).astype(np.int32)


def build_federated(ds: Dataset, parts: list[np.ndarray]) -> FederatedData:
    n_clients = len(parts)
    m = max(len(p) for p in parts)
    feat_shape = ds.x.shape[1:]
    x = np.zeros((n_clients, m) + feat_shape, np.float32)
    y = np.zeros((n_clients, m), np.int32)
    sizes = np.zeros((n_clients,), np.int32)
    for i, idx in enumerate(parts):
        k = len(idx)
        sizes[i] = max(1, k)
        if k:
            x[i, :k] = ds.x[idx]
            y[i, :k] = ds.y[idx]
            # cycle-pad so modulo indexing stays uniform over real samples
            reps = int(np.ceil(m / k))
            x[i, k:] = np.tile(ds.x[idx],
                               (reps,) + (1,) * (ds.x.ndim - 1))[: m - k]
            y[i, k:] = np.tile(ds.y[idx], reps)[: m - k]
    return FederatedData(jnp.asarray(x), jnp.asarray(y),
                         jnp.asarray(sizes), ds.n_classes)
