"""Stacked per-client datasets for the vectorized-client engine.

The CC-FedAvg engine vmaps local training over a leading client axis, so
client datasets are materialized as dense arrays ``(N, n_i_max, ...)`` with a
validity count per client. Batch sampling inside jit draws uniform indices
modulo each client's true size (unbiased within each client's local data —
Assumption 2).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import Dataset


@dataclass(frozen=True)
class FederatedData:
    x: jax.Array        # (N, M, ...) padded client features
    y: jax.Array        # (N, M) padded client labels
    sizes: jax.Array    # (N,) true per-client sample counts
    n_classes: int

    @property
    def n_clients(self) -> int:
        return self.x.shape[0]

    def client_batch(self, key: jax.Array, batch_size: int):
        """Sample one batch per client: returns (N, B, ...), (N, B)."""
        keys = jax.random.split(key, self.n_clients)

        def one(k, cx, cy, sz):
            idx = jax.random.randint(k, (batch_size,), 0, 2 ** 30) % sz
            return cx[idx], cy[idx]

        return jax.vmap(one)(keys, self.x, self.y, self.sizes)


def build_federated(ds: Dataset, parts: list[np.ndarray]) -> FederatedData:
    n_clients = len(parts)
    m = max(len(p) for p in parts)
    feat_shape = ds.x.shape[1:]
    x = np.zeros((n_clients, m) + feat_shape, np.float32)
    y = np.zeros((n_clients, m), np.int32)
    sizes = np.zeros((n_clients,), np.int32)
    for i, idx in enumerate(parts):
        k = len(idx)
        sizes[i] = max(1, k)
        if k:
            x[i, :k] = ds.x[idx]
            y[i, :k] = ds.y[idx]
            # cycle-pad so modulo indexing stays uniform over real samples
            reps = int(np.ceil(m / k))
            x[i, k:] = np.tile(ds.x[idx],
                               (reps,) + (1,) * (ds.x.ndim - 1))[: m - k]
            y[i, k:] = np.tile(ds.y[idx], reps)[: m - k]
    return FederatedData(jnp.asarray(x), jnp.asarray(y),
                         jnp.asarray(sizes), ds.n_classes)
