"""Synthetic classification datasets standing in for CIFAR-10 / FMNIST.

The container is offline, so the paper's image datasets are unavailable.
These generators produce tasks with the properties the paper's experiments
rely on: many classes, learnable-but-nontrivial decision boundaries, and
enough samples to partition non-IID across clients (see
:mod:`repro.data.partition`).

Two families:

* ``gaussian_mixture`` — class-conditional Gaussians on a hypersphere with
  per-class multi-modal clusters (an FMNIST/MLP stand-in).
* ``teacher_net`` — labels produced by a frozen random MLP teacher over
  uniform inputs (a harder CIFAR/CNN stand-in with non-linear boundaries).
* ``image_mixture`` — gaussian_mixture reshaped to (H, W, C) images with
  class-dependent spatial structure so conv models have signal.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Dataset:
    x: np.ndarray  # (n, ...) float32
    y: np.ndarray  # (n,) int32
    n_classes: int

    def __len__(self) -> int:
        return self.x.shape[0]

    def subset(self, idx: np.ndarray) -> "Dataset":
        return Dataset(self.x[idx], self.y[idx], self.n_classes)


def gaussian_mixture(rng: np.random.Generator, *, n: int = 4096,
                     n_classes: int = 10, dim: int = 32,
                     modes_per_class: int = 2, noise: float = 0.9) -> Dataset:
    centers = rng.normal(size=(n_classes, modes_per_class, dim))
    centers /= np.linalg.norm(centers, axis=-1, keepdims=True) / 2.2
    y = rng.integers(0, n_classes, size=n)
    mode = rng.integers(0, modes_per_class, size=n)
    x = centers[y, mode] + noise * rng.normal(size=(n, dim))
    return Dataset(x.astype(np.float32), y.astype(np.int32), n_classes)


def teacher_net(rng: np.random.Generator, *, n: int = 4096,
                n_classes: int = 10, dim: int = 32,
                hidden: int = 64) -> Dataset:
    w1 = rng.normal(size=(dim, hidden)) / np.sqrt(dim)
    w2 = rng.normal(size=(hidden, n_classes)) / np.sqrt(hidden)
    x = rng.uniform(-2, 2, size=(n, dim))
    logits = np.tanh(x @ w1) @ w2
    y = np.argmax(logits + 0.1 * rng.normal(size=logits.shape), axis=-1)
    return Dataset(x.astype(np.float32), y.astype(np.int32), n_classes)


def image_mixture(rng: np.random.Generator, *, n: int = 2048,
                  n_classes: int = 10, hw: int = 8, channels: int = 1,
                  noise: float = 0.8) -> Dataset:
    """Images with class-dependent low-frequency spatial patterns."""
    dim = hw * hw * channels
    base = gaussian_mixture(rng, n=n, n_classes=n_classes, dim=dim,
                            noise=noise)
    x = base.x.reshape(n, hw, hw, channels)
    # add a class-dependent smooth gradient so conv filters have structure
    yy, xx = np.meshgrid(np.linspace(-1, 1, hw), np.linspace(-1, 1, hw),
                         indexing="ij")
    for c in range(n_classes):
        phase = 2 * np.pi * c / n_classes
        pattern = np.cos(2 * yy + phase) + np.sin(2 * xx + phase)
        x[base.y == c] += 0.7 * pattern[None, :, :, None]
    return Dataset(x.astype(np.float32), base.y, n_classes)


def train_test_split(ds: Dataset, test_frac: float = 0.2,
                     seed: int = 0) -> tuple[Dataset, Dataset]:
    """Split one generated dataset so train/test share the generative model."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(ds))
    n_test = int(round(test_frac * len(ds)))
    return ds.subset(perm[n_test:]), ds.subset(perm[:n_test])


def make_dataset(kind: str, seed: int = 0, **kw) -> Dataset:
    rng = np.random.default_rng(seed)
    if kind == "gaussian":
        return gaussian_mixture(rng, **kw)
    if kind == "teacher":
        return teacher_net(rng, **kw)
    if kind == "image":
        return image_mixture(rng, **kw)
    raise ValueError(f"unknown dataset kind {kind!r}")


def batch_iterator(rng_key: jax.Array, x: jax.Array, y: jax.Array,
                   batch_size: int):
    """Infinite shuffled batch sampler as a pure function of a JAX key.

    Returns ``sample(key) -> (xb, yb)`` suitable for use inside jit/vmap
    (uniform with-replacement sampling — matches the unbiased-gradient
    Assumption 2 of the paper).
    """
    n = x.shape[0]

    def sample(key):
        idx = jax.random.randint(key, (batch_size,), 0, n)
        return x[idx], y[idx]

    del rng_key
    return sample


def token_lm_dataset(rng: np.random.Generator, *, n_seq: int, seq_len: int,
                     vocab: int, order: int = 2) -> Dataset:
    """Synthetic Markov-chain token streams for LM training examples."""
    trans = rng.dirichlet(0.1 * np.ones(vocab), size=(vocab,))
    seqs = np.empty((n_seq, seq_len), np.int32)
    state = rng.integers(0, vocab, size=n_seq)
    for t in range(seq_len):
        seqs[:, t] = state
        nxt = np.array([rng.choice(vocab, p=trans[s]) for s in state])
        state = nxt
    del order
    return Dataset(seqs, np.zeros((n_seq,), np.int32), vocab)
