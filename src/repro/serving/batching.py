"""Continuous-batching serving loop (production serving substrate).

The decode dry-run shapes prove one `serve_step` lowers at scale; this
module turns it into an actual server: a slot-based scheduler that admits
requests into a fixed-size decode batch, steps ALL active slots with one
jitted vmapped `decode_step` per token (the vLLM-style inner loop, shaped
like the decode_32k workload), retires finished sequences, and back-fills
free slots from the queue.

Design notes:
  * each slot owns a single-sequence cache pytree (so per-slot ring
    positions / write indices stay independent); the jitted step stacks
    them on a leading slot axis and vmaps `decode_step` — the compiled
    program has the fixed (n_slots, …) decode batch shape the dry-run
    shards over the mesh, and never recompiles;
  * prefill happens per-request at admission, producing the slot's cache;
  * empty slots decode padding tokens against their stale cache and are
    simply ignored by the scheduler.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.models import decoder
from repro.models.config import ArchConfig
from repro.utils.pytree import PyTree, tree_stack, tree_unstack


@dataclass
class Request:
    uid: int
    prompt: jnp.ndarray            # (S,) int32
    max_new_tokens: int
    generated: list[int] = field(default_factory=list)
    done: bool = False


class BatchingServer:
    def __init__(self, cfg: ArchConfig, params: PyTree, *, n_slots: int = 4,
                 capacity: int = 256):
        if cfg.n_codebooks:
            raise NotImplementedError("codebook archs: use per-stream "
                                      "decoding")
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.capacity = capacity
        self.slot_caches = [decoder.init_caches(cfg, 1, capacity)
                            for _ in range(n_slots)]
        self.pos = [0] * n_slots
        self.active: list[Request | None] = [None] * n_slots
        self.queue: list[Request] = []

        def step(params, stacked_caches, tokens, t_vec):
            def one(cache, tok, t):
                logits, new_cache = decoder.decode_step(
                    params, cfg, tok[None], t, cache)
                return logits[0, 0], new_cache

            return jax.vmap(one)(stacked_caches, tokens, t_vec)

        self._step = jax.jit(step)
        self._prefill = jax.jit(
            lambda params, batch: decoder.prefill(params, cfg, batch,
                                                  capacity=capacity))

    # -- queue management ----------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.n_slots):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            caches, logits = self._prefill(
                self.params, {"tokens": req.prompt[None]})
            req.generated.append(int(jnp.argmax(logits[0, -1])))
            self.slot_caches[slot] = caches
            self.pos[slot] = int(req.prompt.shape[-1])
            self.active[slot] = req

    # -- the serving loop ------------------------------------------------

    def step(self) -> int:
        """Admit + decode one token for every active slot. Returns the
        number of active requests after the step."""
        self._admit()
        if not any(r is not None for r in self.active):
            return 0
        tokens = jnp.asarray(
            [[r.generated[-1] if r else 0] for r in self.active],
            jnp.int32)
        t_vec = jnp.asarray(self.pos, jnp.int32)
        stacked = tree_stack(self.slot_caches)
        logits, new_stacked = self._step(self.params, stacked, tokens,
                                         t_vec)
        self.slot_caches = tree_unstack(new_stacked)
        nxt = jax.device_get(jnp.argmax(logits, axis=-1))
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[slot] += 1
            req.generated.append(int(nxt[slot]))
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                self.active[slot] = None
        return sum(r is not None for r in self.active)

    def run(self, max_steps: int = 1000) -> None:
        for _ in range(max_steps):
            self.step()
            if not self.queue and all(r is None for r in self.active):
                break
