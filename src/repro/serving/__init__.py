from repro.serving.batching import BatchingServer, Request  # noqa: F401
