"""Pluggable uplink-channel models for the federated aggregation path.

The paper treats aggregation as an *exact* masked mean — every executor
in :mod:`repro.core.rounds` reproduces that bit-for-bit.  The 6G
edge-AI scenario instead uploads client deltas over an analog
**over-the-air computation** (AirComp) channel: clients transmit
simultaneously, the medium superimposes their signals, and the server
receives the sum plus additive white Gaussian noise, optionally through
per-client Rayleigh fading gains.  This module models that uplink as a
pure function of a dedicated PRNG stream so it can be dropped in front
of any ``strategy.aggregate`` / ``strategy.merge_stale`` call:

* :meth:`UplinkChannel.fade` — per-client amplitude gains applied to the
  stacked uploads *before* aggregation.  Gains are drawn for the **full
  federation** keyed only on ``(seed, tag, round)`` and indexed by
  absolute client ids, so a sharded cohort or an edge shard sees exactly
  the gains the flat executor would — cross-executor equivalence is by
  construction, not by luck.
* :meth:`UplinkChannel.corrupt` — AWGN on the aggregated signal.  For a
  linear aggregate, noise-on-the-superposition and noise-on-the-mean
  differ only by the (deterministic) denominator, so corrupting the
  aggregated tree is equivalent to corrupting the superposed sum with a
  rescaled variance; doing it post-aggregation makes the channel
  executor-agnostic (and post-``psum`` the draw is replicated across
  shards because the key does not depend on the shard).

PRNG-stream isolation
---------------------
Channel keys fold a dedicated salt (``_CHANNEL_SALT``) and a per-hop tag
into ``PRNGKey(seed)`` before the round counter, so they can never
collide with the training streams (``rounds._round_keys`` splits the
carried key; :func:`repro.system.devices.stateless_uniform` folds the
raw ``(seed, round, client)`` path; the latency stream salts with
``_LATENCY_SALT``).  ``kind="noiseless"`` short-circuits to the input —
and executors skip the channel entirely when
:func:`uplink_channel` returns ``None`` — so the default configuration
is trace-identical to the pre-channel code, keeping every pinned
bit-for-bit test untouched.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.utils.pytree import PyTree

#: registered channel kinds, in spec/CLI order
CHANNEL_KINDS = ("noiseless", "aircomp")

#: dedicated fold-in salt of the channel PRNG stream (cf. devices.py's
#: ``_LATENCY_SALT = 9176``); never used by any training key derivation
_CHANNEL_SALT = 7415

# per-hop tags: each uplink tier draws its own independent realization
TAG_UPLINK = 1   #: flat / scan / fused / sharded client→server uplink
TAG_C2E = 2      #: hierarchical client→edge tier
TAG_E2S = 3      #: hierarchical edge→server tier
TAG_MERGE = 4    #: async merge-time uplink (keyed on the merge round)


@dataclasses.dataclass(frozen=True)
class UplinkChannel:
    """One uplink realization model; hashable, safe as a jit static."""

    kind: str = "noiseless"
    #: receive SNR in dB relative to the rms of the aggregated signal
    snr_db: float = 20.0
    #: draw per-client Rayleigh amplitude gains (unit mean power)
    fading: bool = False
    #: base seed of the dedicated channel stream
    seed: int = 0

    def __post_init__(self):
        if self.kind not in CHANNEL_KINDS:
            raise ValueError(f"unknown channel kind {self.kind!r}; "
                             f"expected one of {CHANNEL_KINDS}")

    # -- key derivation ---------------------------------------------------
    def _key(self, rnd, tag: int, sub=0):
        k = jax.random.fold_in(jax.random.PRNGKey(self.seed), _CHANNEL_SALT)
        k = jax.random.fold_in(k, tag)
        k = jax.random.fold_in(k, rnd)
        return jax.random.fold_in(k, sub)

    # -- fading -----------------------------------------------------------
    def gains(self, rnd, client_ids, n_total: int, tag: int, sub=0):
        """``(len(client_ids),)`` Rayleigh amplitude gains, E[h²] = 1.

        Drawn for all ``n_total`` clients keyed only on
        ``(seed, tag, round, sub)`` and indexed by absolute client ids,
        so any cohort/shard slicing sees consistent per-client gains.
        """
        z = jax.random.normal(self._key(rnd, tag, sub), (2, n_total))
        h = jnp.sqrt((z[0] ** 2 + z[1] ** 2) / 2.0)
        return h[client_ids]

    def fade(self, tree: PyTree, rnd, client_ids, n_total: int, tag: int,
             sub=0) -> PyTree:
        """Scale stacked per-client uploads by this round's fading gains.

        Identity (the input object itself) when noiseless or fading is
        off — callers may rely on that for bit-exactness.
        """
        if self.kind == "noiseless" or not self.fading:
            return tree
        g = self.gains(rnd, client_ids, n_total, tag, sub)
        return jax.tree.map(
            lambda x: g.reshape((-1,) + (1,) * (x.ndim - 1))
            .astype(x.dtype) * x, tree)

    # -- additive noise ---------------------------------------------------
    def corrupt(self, tree: PyTree, rnd, tag: int, sub=0) -> PyTree:
        """Add AWGN at ``snr_db`` below the tree's global rms.

        ``sigma = rms(tree) · 10^(−snr_db/20)`` — i.e. the noise *power*
        is ``10^(−snr_db/10)`` of the signal power, the standard receive
        -SNR convention.  Identity when noiseless.
        """
        if self.kind == "noiseless":
            return tree
        leaves, treedef = jax.tree.flatten(tree)
        total = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                    for x in leaves)
        count = max(sum(x.size for x in leaves), 1)
        sigma = jnp.sqrt(total / count) * 10.0 ** (-self.snr_db / 20.0)
        key = self._key(rnd, tag, sub)
        out = [x + (sigma * jax.random.normal(jax.random.fold_in(key, i),
                                              x.shape)).astype(x.dtype)
               for i, x in enumerate(leaves)]
        return jax.tree.unflatten(treedef, out)


def uplink_channel(fed) -> Optional[UplinkChannel]:
    """The :class:`UplinkChannel` of a FedConfig, or ``None`` if noiseless.

    Executors guard every channel call with ``if channel is not None`` —
    returning ``None`` here (rather than a no-op channel object) keeps
    the noiseless trace literally identical to the pre-channel code.
    """
    if fed.channel == "noiseless":
        return None
    return UplinkChannel(kind=fed.channel, snr_db=fed.channel_snr_db,
                         fading=fed.channel_fading, seed=fed.seed)
