"""Pluggable local-model estimation strategies (paper §III + extensions).

Each strategy is a small frozen dataclass with four hooks the round
executor (:mod:`repro.core.rounds`) calls in order:

* ``estimate(state, ctx)``        — Δ̂_t^i for clients that skip training,
* ``agg_mask(ctx)``               — which clients enter the aggregation,
* ``aggregate(delta_i, aggf, ctx)`` — Eq. 3 (masked mean by default;
  FedNova normalizes by local-step counts),
* ``update_history(state, ctx, trained_delta, local, est)`` — how the
  per-client Δ / stale-model history rolls forward.

Strategies register by name via :func:`register`; ``FedConfig.strategy``
resolves through :func:`get_strategy`, so adding a new budget-adaptation
scheme (the surveys arXiv:2307.09182 / arXiv:2002.10610 catalogue dozens)
is a ~30-line estimator class here — the engine never changes.

Paper §III ↔ registry names:

    ==============================  ==========
    paper                           registry
    ==============================  ==========
    FedAvg (full participation)     ``fedavg``
    FedAvg (dropout baseline)       ``dropout``
    Strategy 1 (server skips)       ``s1``
    Strategy 2 (stale local model)  ``s2``
    Strategy 3 / CC-FedAvg          ``cc``
    CC-FedAvg(c), Eq. 4             ``ccc``
    FedNova baseline [32]           ``fednova``
    decayed-Δ replay (extension)    ``cc_decay``
    FedProx [prox term] (ext.)      ``fedprox``
    FedDyn [dynamic reg.] (ext.)    ``feddyn``
    ==============================  ==========

``fedprox``/``feddyn`` change the LOCAL objective rather than the
estimate: :meth:`Strategy.configure` binds their μ/α from the FedConfig,
:meth:`Strategy.prox_coeff` adds a proximal pull toward the broadcast
model inside every SGD step, and FedDyn additionally carries a
per-client dual (gradient-correction) state as an extra history key —
threaded through the same ``gather_history``/``scatter_history``/
checkpoint machinery as the Δ history.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.utils.pytree import (PyTree, tree_broadcast_clients,
                                tree_masked_mean, tree_zeros_like)


def masked_select(mask: jax.Array, a: PyTree, b: PyTree) -> PyTree:
    """Leafwise select with an (N,) client mask broadcast to (N, ...) leaves."""
    def sel(x, y):
        m = mask.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.where(m, x, y)
    return jax.tree.map(sel, a, b)


class FusedEpilogue(NamedTuple):
    """Per-strategy coefficients that specialize the fused round kernels.

    The fused Pallas kernels (:mod:`repro.kernels.cc_delta_update` /
    ``cc_delta_update_q8``) compute, per client row i over flat (N, P)
    parameters:

        est_i   = e_replay_i · Δ_{t−1}^i  (+ e_stale_i · stale_i)
        d_i     = train_i ? (x_K^i − x_t) : est_i
        x_{t+1} = x_t + (Σ_i agg_w_i · d_i / denom) · post_scale
        Δ_t^i   = upd_i ? (x_K^i − x_t) : store_scale_i · Δ_{t−1}^i

    which is exactly the tree-ops round of :func:`repro.core.rounds.
    _cohort_round` whenever the strategy's ``estimate`` is an affine
    combination of the stored Δ and the stale-model delta — true for every
    registered strategy. All members are traced values computed from the
    round masks OUTSIDE the kernel (O(N) work), so one kernel covers the
    whole registry.
    """
    agg_w: jax.Array        # (N,) f32 — per-client aggregation weight
    e_replay: jax.Array     # (N,) f32 — estimate coefficient on stored Δ
    e_stale: jax.Array      # (N,) f32 — estimate coefficient on stale Δ
    store_scale: jax.Array  # (N,) f32 — Δ history decay for non-updating rows
    denom: jax.Array        # () f32 — aggregation denominator
    post_scale: jax.Array   # () f32 — post-mean rescale (FedNova coeff)


@dataclass(frozen=True)
class RoundCtx:
    """Everything a strategy may condition on inside one round.

    All array members are traced values (safe under jit/scan); scalars that
    must stay static (``tau``) are Python ints baked at trace time.
    """
    sel_mask: jax.Array      # (N,) bool — server selection S_t
    train_mask: jax.Array    # (N,) bool — performs real local training
    k_active: jax.Array      # (N,) int32 — local steps actually run
    round: jax.Array         # () int32 — current round t
    tau: int                 # CC-FedAvg(c) switch round
    stale_delta: PyTree      # x_{t-1,K}^i − x_t re-expressed as a delta
    trained_delta: PyTree    # x_K^i − x_t from this round's local training
    #: mesh axis the client dimension is shard_map'ed over (sharded
    #: executor); None everywhere else. Aggregations must reduce across it.
    axis_name: str | None = None
    #: per-client energy reserve at decision time (budget-policy engine);
    #: None when the round runs from precomputed masks without a device
    #: simulator. Strategies may condition estimation/weighting on it.
    energy: jax.Array | None = None
    #: per-client edge-aggregator ids under a two-tier topology
    #: (:mod:`repro.core.hierarchy`); None in flat runs. A strategy may
    #: condition estimation/weighting on which gateway a client hangs off.
    edge_id: jax.Array | None = None


@dataclass(frozen=True)
class Strategy:
    """Base strategy: train-only aggregation, standard history roll."""

    #: registry key; subclasses set it via their ``name`` field default
    name: str = ""
    #: the fused Pallas round kernels implement this strategy's round via a
    #: :class:`FusedEpilogue` — every strategy whose estimate is an affine
    #: combination of stored Δ and the stale-model delta qualifies (all
    #: registered ones); custom strategies with richer estimates must opt
    #: out and take the tree-ops path
    fused_capable: bool = False
    #: the strategy's estimate reads the stale-model history (prev_local);
    #: fused runs must then feed the kernel a stale-delta input, and the
    #: int8-compressed carry must keep the f32 prev_local tree
    needs_stale: bool = False

    # ---- hooks ----------------------------------------------------------

    def configure(self, fed) -> "Strategy":
        """Bind per-run hyperparameters from a FedConfig (called by
        ``FedConfig.resolve``). The default returns the registered
        instance itself — plugins resolve to exactly the object that was
        registered; strategies with spec-level knobs (fedprox's μ,
        feddyn's α) override with a ``dataclasses.replace``."""
        return self

    def prox_coeff(self) -> float:
        """μ of a proximal term μ/2·‖w − x_t‖² added to the local
        objective. A static Python float: 0.0 (the default) leaves the
        local-training trace literally unchanged."""
        return 0.0

    def local_dual(self, state: PyTree) -> PyTree | None:
        """Per-client dual / gradient-correction rows subtracted from the
        local gradient every step (FedDyn), or ``None`` for strategies
        without one — executors skip the term entirely on ``None``, so
        the default trace is unchanged."""
        return None

    def estimate(self, state: PyTree, ctx: RoundCtx) -> PyTree:
        """Δ̂_t^i for skipping clients. Default: contribute nothing (the
        agg_mask below drops skippers anyway)."""
        return tree_zeros_like(ctx.trained_delta)

    def agg_mask(self, ctx: RoundCtx) -> jax.Array:
        """Which clients the server averages. Default: only real trainers
        (Strategy 1 / FedAvg-family semantics)."""
        return ctx.sel_mask & ctx.train_mask

    def aggregate(self, delta_i: PyTree, aggf: jax.Array,
                  ctx: RoundCtx) -> PyTree:
        """Eq. 3: unweighted masked mean over the client axis (reduced
        across shards when the client axis is shard_map'ed)."""
        return tree_masked_mean(delta_i, aggf, axis_name=ctx.axis_name)

    def merge_stale(self, delta_i: PyTree, aggf: jax.Array,
                    staleness: jax.Array, decay_w: jax.Array,
                    ctx: RoundCtx) -> PyTree:
        """FedBuff-style staleness-decayed merge of a buffered cohort
        (the async executor's aggregation hook).

        ``staleness`` is the per-client rounds-since-pull counter of each
        buffered arrival and ``decay_w`` the schedule's weights ``w(s)``
        (``γ^s`` by default — see
        :func:`repro.core.async_rounds.staleness_weights`). The default
        folds the decay into the aggregation weights, so at ``s = 0`` the
        weights are exactly 1.0 and ``merge_stale ≡ aggregate``
        bit-for-bit — the collapse-to-synchronous guarantee the executor
        matrix pins. Strategies with richer staleness handling (e.g.
        staleness-dependent estimates) may override."""
        return self.aggregate(delta_i, aggf * decay_w, ctx)

    def fused_epilogue(self, ctx: RoundCtx) -> FusedEpilogue:
        """Coefficients the fused kernels run this strategy with. The base
        implementation is the FedAvg family (train-only aggregation, zero
        estimate, verbatim history): the masked mean's denominator matches
        :func:`repro.utils.pytree.tree_masked_mean` exactly."""
        aggf = self.agg_mask(ctx).astype(jnp.float32)
        n = aggf.shape[0]
        one = jnp.ones((n,), jnp.float32)
        return FusedEpilogue(
            agg_w=aggf,
            e_replay=self._replay_coeff(ctx),
            e_stale=self._stale_coeff(ctx),
            store_scale=one,
            denom=jnp.maximum(jnp.sum(aggf), 1e-12),
            post_scale=jnp.ones((), jnp.float32))

    def _replay_coeff(self, ctx: RoundCtx) -> jax.Array:
        """Estimate coefficient on the stored Δ (0 = contribute nothing)."""
        return jnp.zeros((ctx.sel_mask.shape[0],), jnp.float32)

    def _stale_coeff(self, ctx: RoundCtx) -> jax.Array:
        """Estimate coefficient on the stale-model delta."""
        return jnp.zeros((ctx.sel_mask.shape[0],), jnp.float32)

    def update_history(self, state: PyTree, ctx: RoundCtx,
                       trained_delta: PyTree, local: PyTree,
                       est: PyTree) -> tuple[PyTree, PyTree]:
        """Roll (deltas, prev_local) forward; overwrite only clients that
        actually trained this round (Alg. 1 lines 20-21)."""
        upd = ctx.sel_mask & ctx.train_mask
        deltas = masked_select(upd, trained_delta, state["deltas"])
        prev_local = masked_select(upd, local, state["prev_local"])
        return deltas, prev_local

    def pod_estimate(self, deltas: PyTree) -> PyTree:
        """Estimate from stored Δ only — the pod-level (LLM-scale) engine
        keeps no stale-model history, so only replay-style strategies
        support it."""
        raise NotImplementedError(
            f"strategy {self.name!r} has no pod-level estimate "
            "(needs per-client history beyond stored deltas)")

    # ---- cohort gather/scatter (sharded executor) -----------------------

    #: per-client state rows a cohort round reads and writes; strategies
    #: that keep extra history extend this tuple and the hooks below
    history_keys: tuple[str, ...] = ("deltas", "prev_local", "trained_ever")

    def extra_history_keys(self) -> tuple[str, ...]:
        """History keys beyond the base (deltas, prev_local, trained_ever)
        triple — the rows :meth:`init_extra_history` creates and
        :meth:`update_extra_history` rolls (e.g. feddyn's ``dual``)."""
        return tuple(k for k in self.history_keys
                     if k not in ("deltas", "prev_local", "trained_ever"))

    def init_extra_history(self, params: PyTree, n_clients: int) -> dict:
        """Fresh per-client rows for :meth:`extra_history_keys`; merged
        into the federated state by ``init_fed_state``."""
        return {}

    def update_extra_history(self, state: PyTree, ctx: RoundCtx,
                             trained_delta: PyTree, local: PyTree,
                             est: PyTree) -> dict:
        """Roll the extra history keys forward — the companion of
        :meth:`update_history`, which keeps its (deltas, prev_local)
        2-tuple contract. Must be mask-idempotent: rows outside
        ``sel ∧ train`` come back bit-unchanged."""
        return {}

    def gather_history(self, state: PyTree, idx: jax.Array) -> PyTree:
        """Pull the cohort's rows out of the full-N per-client history —
        the sharded executor moves only the active clients' state onto the
        client mesh each round."""
        take = functools.partial(jnp.take, indices=idx, axis=0)
        return {k: jax.tree.map(take, state[k]) for k in self.history_keys
                if k in state}

    def scatter_history(self, state: PyTree, idx: jax.Array,
                        updated: PyTree) -> PyTree:
        """Write a cohort round's updated history rows back into the
        full-N state (non-members keep their rows untouched)."""
        def put(full, rows):
            return full.at[idx].set(rows)
        return {k: jax.tree.map(put, state[k], updated[k])
                for k in self.history_keys if k in state}


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Strategy] = {}


def register(strategy: Strategy) -> Strategy:
    """Register a strategy instance under its ``name`` (last wins)."""
    if not strategy.name:
        raise ValueError("strategy must have a non-empty name")
    _REGISTRY[strategy.name] = strategy
    return strategy


def get_strategy(name: str) -> Strategy:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown strategy {name!r}; available: "
            f"{', '.join(available_strategies())}") from None


def available_strategies() -> tuple[str, ...]:
    """Registered names in registration order (paper order first)."""
    return tuple(_REGISTRY)


# ---------------------------------------------------------------------------
# paper §III strategies
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FedAvg(Strategy):
    """FedAvg(full): everyone the plan says trains, trains; skippers are
    simply absent from the round (plans decide selection)."""
    name: str = "fedavg"
    fused_capable: bool = True


@dataclass(frozen=True)
class FedAvgDropout(Strategy):
    """FedAvg under an energy quota — the *plan* removes a client once its
    budget is spent; round semantics are plain FedAvg."""
    name: str = "dropout"
    fused_capable: bool = True


@dataclass(frozen=True)
class SkipRounds(Strategy):
    """Strategy 1: skipping clients upload nothing; the server averages
    only received models."""
    name: str = "s1"
    fused_capable: bool = True


@dataclass(frozen=True)
class StaleModel(Strategy):
    """Strategy 2: a skipping client returns its stale local model
    x_{t-1,K}^i, i.e. contributes x_{t-1,K}^i − x_t as its delta."""
    name: str = "s2"
    fused_capable: bool = True
    needs_stale: bool = True

    def estimate(self, state, ctx):
        return ctx.stale_delta

    def agg_mask(self, ctx):
        return ctx.sel_mask

    def _stale_coeff(self, ctx):
        return jnp.ones((ctx.sel_mask.shape[0],), jnp.float32)


@dataclass(frozen=True)
class CCFedAvg(Strategy):
    """Strategy 3 / CC-FedAvg: replay the stored Δ_{t−1}^i verbatim
    (Alg. 1 line 15). This is exactly what the fused Pallas kernel
    (:mod:`repro.kernels.cc_delta_update`) computes in one HBM pass."""
    name: str = "cc"
    fused_capable: bool = True

    def estimate(self, state, ctx):
        return state["deltas"]

    def agg_mask(self, ctx):
        return ctx.sel_mask

    def _replay_coeff(self, ctx):
        return jnp.ones((ctx.sel_mask.shape[0],), jnp.float32)

    def pod_estimate(self, deltas):
        return deltas


@dataclass(frozen=True)
class CCFedAvgC(Strategy):
    """CC-FedAvg(c), Eq. 4: Strategy 3 before round τ, Strategy 2 after."""
    name: str = "ccc"
    fused_capable: bool = True
    needs_stale: bool = True

    def estimate(self, state, ctx):
        use_s3 = ctx.round < ctx.tau
        return jax.tree.map(lambda a, b: jnp.where(use_s3, a, b),
                            state["deltas"], ctx.stale_delta)

    def agg_mask(self, ctx):
        return ctx.sel_mask

    def _replay_coeff(self, ctx):
        n = ctx.sel_mask.shape[0]
        return jnp.where(ctx.round < ctx.tau, jnp.ones((n,), jnp.float32),
                         jnp.zeros((n,), jnp.float32))

    def _stale_coeff(self, ctx):
        return 1.0 - self._replay_coeff(ctx)


@dataclass(frozen=True)
class FedNova(Strategy):
    """FedNova [32]: the budget is spent as fewer local iterations every
    round; aggregation normalizes each Δ by its step count, then rescales
    by the mean step count so uniform budgets reduce to FedAvg exactly."""
    name: str = "fednova"
    fused_capable: bool = True

    def fused_epilogue(self, ctx):
        # fold the per-client 1/k_i normalization into the aggregation
        # weight and the mean-step-count rescale into post_scale — the
        # kernel's Σ (aggf/ka)·d / denom · coeff equals the tree-ops
        # coeff · masked_mean(d/ka) to within one rounding
        aggf = self.agg_mask(ctx).astype(jnp.float32)
        ka = jnp.maximum(ctx.k_active.astype(jnp.float32), 1.0)
        num, den = jnp.sum(aggf * ka), jnp.sum(aggf)
        base = super().fused_epilogue(ctx)
        return base._replace(agg_w=aggf / ka,
                             post_scale=num / jnp.maximum(den, 1e-9))

    def aggregate(self, delta_i, aggf, ctx):
        ka = jnp.maximum(ctx.k_active.astype(jnp.float32), 1.0)
        d_norm = jax.tree.map(
            lambda x: x / ka.reshape((-1,) + (1,) * (x.ndim - 1)), delta_i)
        num, den = jnp.sum(aggf * ka), jnp.sum(aggf)
        if ctx.axis_name is not None:      # reduce step counts across shards
            num = jax.lax.psum(num, ctx.axis_name)
            den = jax.lax.psum(den, ctx.axis_name)
        coeff = num / jnp.maximum(den, 1e-9)
        return jax.tree.map(
            lambda x: coeff * x,
            tree_masked_mean(d_norm, aggf, axis_name=ctx.axis_name))


# ---------------------------------------------------------------------------
# extensions beyond the paper
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CCDecay(Strategy):
    """Decayed-Δ replay: a skipping client contributes γ·Δ_{t−1}^i and
    stores the decayed value, so consecutive skips contribute γ, γ², …
    times the last real update — the replayed momentum fades instead of
    being trusted forever (CC-FedAvg is the γ=1 limit)."""
    name: str = "cc_decay"
    fused_capable: bool = True
    gamma: float = 0.9

    def estimate(self, state, ctx):
        return jax.tree.map(lambda d: self.gamma * d, state["deltas"])

    def agg_mask(self, ctx):
        return ctx.sel_mask

    def _replay_coeff(self, ctx):
        n = ctx.sel_mask.shape[0]
        return jnp.full((n,), self.gamma, jnp.float32)

    def fused_epilogue(self, ctx):
        # skipping clients store the decayed estimate γ·Δ, not Δ itself
        base = super().fused_epilogue(ctx)
        skipped = ctx.sel_mask & ~ctx.train_mask
        return base._replace(
            store_scale=jnp.where(skipped, self.gamma, 1.0
                                  ).astype(jnp.float32))

    def update_history(self, state, ctx, trained_delta, local, est):
        upd = ctx.sel_mask & ctx.train_mask
        skipped = ctx.sel_mask & ~ctx.train_mask
        deltas = masked_select(upd, trained_delta,
                               masked_select(skipped, est, state["deltas"]))
        prev_local = masked_select(upd, local, state["prev_local"])
        return deltas, prev_local

    def pod_estimate(self, deltas):
        return jax.tree.map(lambda d: self.gamma * d, deltas)


@dataclass(frozen=True)
class FedProx(Strategy):
    """FedProx: the local objective gains a proximal term
    μ/2·‖w − x_t‖² pulling each client back toward the broadcast model,
    i.e. every local SGD step adds μ(w − x_t) to the gradient. Server
    aggregation is plain FedAvg (train-only masked mean), so μ = 0 — the
    registered default until :meth:`configure` binds ``fed.prox_mu`` —
    IS FedAvg bit-for-bit."""
    name: str = "fedprox"
    fused_capable: bool = True
    mu: float = 0.0

    def configure(self, fed):
        if fed.prox_mu == self.mu:
            return self
        return dataclasses.replace(self, mu=fed.prox_mu)

    def prox_coeff(self):
        return self.mu


@dataclass(frozen=True)
class FedDyn(Strategy):
    """FedDyn: dynamic regularization with a per-client dual state h_i.

    Each local step descends ∇F_i(w) + α(w − x_t) − h_i; after a client's
    trained round the dual rolls h_i ← h_i − α·(x_K^i − x_t), so the
    linear term asymptotically cancels client drift. The dual rows ride
    the history machinery as the extra key ``dual`` (stacked like the Δ
    history: gathered/scattered by cohort rounds, checkpointed with the
    state). α = 0 — the registered default until :meth:`configure` binds
    ``fed.feddyn_alpha`` — is FedAvg bit-for-bit: both gradient terms
    and the dual roll switch off at the Python level."""
    name: str = "feddyn"
    fused_capable: bool = True
    history_keys: tuple[str, ...] = ("deltas", "prev_local",
                                     "trained_ever", "dual")
    alpha: float = 0.0

    def configure(self, fed):
        if fed.feddyn_alpha == self.alpha:
            return self
        return dataclasses.replace(self, alpha=fed.feddyn_alpha)

    def prox_coeff(self):
        # FedDyn's quadratic penalty is exactly a proximal pull with μ = α
        return self.alpha

    def local_dual(self, state):
        if self.alpha == 0.0:
            return None
        return state["dual"]

    def init_extra_history(self, params, n_clients):
        return {"dual": tree_broadcast_clients(tree_zeros_like(params),
                                               n_clients)}

    def update_extra_history(self, state, ctx, trained_delta, local, est):
        if "dual" not in state:
            # a legacy state initialized without this strategy carries no
            # dual rows — behave as plain FedAvg and keep the carry stable
            return {}
        if self.alpha == 0.0:
            return {"dual": state["dual"]}
        upd = ctx.sel_mask & ctx.train_mask
        rolled = jax.tree.map(lambda h, d: h - self.alpha * d,
                              state["dual"], trained_delta)
        return {"dual": masked_select(upd, rolled, state["dual"])}


for _s in (FedAvg(), FedAvgDropout(), SkipRounds(), StaleModel(),
           CCFedAvg(), CCFedAvgC(), FedNova(), CCDecay(), FedProx(),
           FedDyn()):
    register(_s)
