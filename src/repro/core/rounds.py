"""Round executors for the vectorized-client federation.

Six ways to run the same round semantics, all built from one traceable
cohort-round core (:func:`_cohort_round` and the shared training/masking
helpers) so they are numerically interchangeable:

Two decision modes feed every executor:

* **mask mode** (the seed-era contract) — the caller passes precomputed
  ``sel``/``train`` masks per round;
* **policy mode** (:func:`make_policy_round_body` and friends) — a
  :class:`repro.core.budget.BudgetPolicy` decides ``train`` *inside the
  trace* from simulated device state (:mod:`repro.system.devices`), whose
  energy/load/ledger rows advance in the round carry. Eval-free spans stay
  a single ``lax.scan``; the sharded executor decides per-shard on the
  gathered device rows. ``PrecompiledPolicy`` makes mask mode a special
  case, bit-for-bit (pinned in ``tests/test_executor_matrix.py``).

* :func:`make_round_fn` — one jitted round (the classic per-round API);
* :func:`make_span_runner` — ``jax.lax.scan`` over a stacked (C, N) chunk
  of plan masks, so an eval-free span of C rounds executes as ONE jitted
  program instead of C separate dispatches (the dominant cost at small
  model sizes is host→device round-trips, not FLOPs — see
  ``benchmarks/round_loop.py``);
* :func:`make_sharded_span_runner` — the scan span with every round's
  cohort ``shard_map``'ed over a ``("clients",)`` mesh: each round gathers
  only the sampled participants' history rows
  (:class:`repro.data.federated.CohortSampler`), splits them across
  devices, reduces the aggregation with ``lax.psum`` and scatters the
  updated rows back — N ≫ devices cross-device cohorts;
* ``fused=True`` — route the train-or-estimate + masked-mean + global
  update through the single-HBM-pass Pallas kernel
  (:func:`repro.kernels.ops.cc_delta_update`) on flat (N, P) parameters;
  interpret mode on CPU, Mosaic on TPU. Only strategies whose estimate is
  a verbatim Δ replay (``fused_capable``) qualify;
* :func:`make_hierarchical_span_runner` — the two-tier client→edge→server
  executor: clients train against their edge aggregator's model
  (:class:`repro.core.hierarchy.EdgeTopology`), edges run ``edge_period``
  rounds of masked intra-edge aggregation, and the server folds the edge
  models back every period. Edges — and their member clients — shard over
  the ``("edges",)`` mesh axis (:func:`repro.launch.mesh.make_edge_mesh`):
  intra-edge rounds are entirely shard-local, only the sync rounds
  all-gather the uploads. A single edge, or ``edge_period=1``, collapses
  to flat FedAvg bit-for-bit, so the flat executors are its oracle;
* :mod:`repro.core.async_rounds` — the staleness-tolerant buffered-async
  executor: clients pull/deliver on a precomputed arrival schedule
  (:func:`repro.system.devices.simulate_arrivals`), updates merge every
  K arrivals with staleness-decayed weights through
  ``Strategy.merge_stale``, and the Δ history can ride the sharded int8
  :class:`repro.core.history_store.HistoryStore`. Zero latency + K = 1
  collapses to the scan executor bit-for-bit, so it too is
  differential-testable against the flat oracle.

Strategy semantics themselves live in :mod:`repro.core.strategies`; this
module never branches on a strategy name.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import (TAG_C2E, TAG_E2S, TAG_UPLINK,
                                uplink_channel)
from repro.core.strategies import (RoundCtx, Strategy, get_strategy,
                                   masked_select)
from repro.data.federated import FederatedData
from repro.models.simple import Classifier, xent_loss
from repro.utils.pytree import (
    PyTree,
    tree_add,
    tree_broadcast_clients,
    tree_index,
    tree_ravel,
    tree_ravel_clients,
    tree_stack,
    tree_sub,
    tree_where,
    tree_zeros_like,
)

_FUSED_PAD = 512               # flat params padded to a tile-friendly multiple

#: every registered round executor — the Session dispatch table and the
#: spec/CLI ``choices`` derive from this tuple, so adding an executor here
#: (plus its Session branch) makes it reachable everywhere at once
EXECUTORS = ("scan", "python", "sharded", "hierarchical", "async")

#: Δ-history wire/storage formats accepted by ``FedConfig.compress``
COMPRESS_KINDS = ("none", "int8")

#: mesh axis name the sharded executor splits the client dimension over
CLIENT_AXIS = "clients"

#: mesh axis name the hierarchical executor splits edge aggregators over
EDGE_AXIS = "edges"

#: the mask-mode federated state keys (policy mode adds policy/device/ledger)
_BASE_KEYS = ("params", "deltas", "prev_local", "trained_ever", "round",
              "key")


@dataclass(frozen=True)
class FedConfig:
    strategy: str = "cc"
    variant: str = "client"        # Alg.1 client | Alg.2 server | Alg.3 mixed
    local_steps: int = 5           # K
    batch_size: int = 32
    lr: float = 0.05
    tau: int = 100                 # CC-FedAvg(c) switch round
    seed: int = 0
    #: participants sampled per round by the sharded executor
    #: (None = the full federation every round)
    cohort_size: int | None = None
    #: Δ-history wire/storage format: "none" keeps f32, "int8" stores the
    #: (N, P) history quantized per client row (fused executor only)
    compress: str = "none"
    #: μ of fedprox's proximal term (0.0 = plain FedAvg local objective)
    prox_mu: float = 0.0
    #: α of feddyn's dynamic regularizer (0.0 = dual state switched off)
    feddyn_alpha: float = 0.0
    #: uplink model applied to the stacked uploads before aggregation
    #: (:mod:`repro.core.channel`): "noiseless" keeps the exact masked
    #: mean, "aircomp" models analog over-the-air superposition
    channel: str = "noiseless"
    #: aircomp receive SNR in dB relative to the aggregated signal's rms
    channel_snr_db: float = 20.0
    #: draw per-client Rayleigh fading gains on every uplink
    channel_fading: bool = False

    def __post_init__(self):
        from repro.core.channel import CHANNEL_KINDS
        strategy = get_strategy(self.strategy)  # raises on unknown names
        if self.cohort_size is not None and self.cohort_size < 1:
            raise ValueError(
                f"cohort_size must be >= 1, got {self.cohort_size}")
        if self.compress not in COMPRESS_KINDS:
            raise ValueError(
                f"compress must be one of {COMPRESS_KINDS}, got "
                f"{self.compress!r}")
        if self.compress == "int8" and not strategy.fused_capable:
            raise ValueError(
                f"compress='int8' stores the Δ history in int8, which only "
                f"the fused kernel path consumes; strategy "
                f"{self.strategy!r} is not fused-capable — use "
                f"compress='none'")
        if self.channel not in CHANNEL_KINDS:
            raise ValueError(
                f"channel must be one of {CHANNEL_KINDS}, got "
                f"{self.channel!r}")
        if self.prox_mu < 0:
            raise ValueError(f"prox_mu must be >= 0, got {self.prox_mu}")
        if self.feddyn_alpha < 0:
            raise ValueError(
                f"feddyn_alpha must be >= 0, got {self.feddyn_alpha}")

    def resolve(self) -> Strategy:
        """The registered strategy, with this config's hyperparameters
        bound via :meth:`repro.core.strategies.Strategy.configure`."""
        return get_strategy(self.strategy).configure(self)


def _local_train(model: Classifier, params, key, cx, cy, size,
                 k_steps: int, k_active, batch_size: int, lr: float,
                 prox: float = 0.0, dual=None):
    """K local SGD steps on one client (Eq. 2). ``k_active`` ≤ k_steps masks
    steps off for FedNova's reduced-iteration budget.

    ``prox`` > 0 adds FedProx/FedDyn's proximal gradient μ(w − x_t) toward
    the start params; ``dual`` (a params-shaped tree) subtracts FedDyn's
    per-client gradient correction h_i. Both default OFF at the Python
    level, leaving the base trace bit-identical."""
    x0 = params
    def step(carry, k):
        p, key = carry
        key, sk = jax.random.split(key)
        idx = jax.random.randint(sk, (batch_size,), 0, 2 ** 30) % size
        g = jax.grad(lambda q: xent_loss(model, q, cx[idx], cy[idx]))(p)
        if prox:
            g = jax.tree.map(lambda gv, pv, ov: gv + prox * (pv - ov),
                             g, p, x0)
        if dual is not None:
            g = jax.tree.map(lambda gv, hv: gv - hv, g, dual)
        new = jax.tree.map(lambda a, b: a - lr * b, p, g)
        do = k < k_active
        p = jax.tree.map(
            lambda a, b: jnp.where(do, a, b), new, p)
        return (p, key), None

    (params, _), _ = jax.lax.scan(step, (params, key),
                                  jnp.arange(k_steps))
    return params


def init_fed_state(rng, model: Classifier, n_clients: int, *,
                   policy=None, profile=None, topology=None,
                   compress: str = "none", async_cfg=None,
                   needs_stale: bool = True, strategy=None) -> PyTree:
    """Fresh federated state. With ``policy`` + ``profile`` the carry also
    holds the budget-policy rows, the simulated device state and the
    energy/cost ledger (policy mode); without, the seed-era 6-key state.
    With ``topology`` (an :class:`repro.core.hierarchy.EdgeTopology`) the
    carry additionally holds the edge tier's models (``edge_params``, an
    (E,)-stacked params tree initialized to the global model — every edge
    period starts from an exact sync).

    ``compress="int8"`` (fused executor only) stores the (N, P) Δ history
    as a flat tile-padded int8 payload + per-row f32 scales instead of the
    f32 client tree; with ``needs_stale=False`` (every strategy whose
    estimate never reads the stale model) the O(N, P) f32 ``prev_local``
    is dropped from the carry entirely.

    ``async_cfg`` (an :class:`repro.core.async_rounds.AsyncConfig`) adds
    the async executor's FedBuff carry under ``state["async"]`` and, with
    ``history_store="int8"``, swaps the Δ history for the quantized
    :class:`repro.core.history_store.HistoryStore` carry (the async
    analogue of ``compress="int8"``, same prev_local-dropping rule).

    ``strategy`` (a resolved :class:`repro.core.strategies.Strategy`)
    additionally creates the strategy's extra history rows (e.g. feddyn's
    per-client ``dual`` tree); omitted, the state carries only the base
    keys — exactly the pre-extension layout."""
    params = model.init(rng)
    zeros = tree_broadcast_clients(tree_zeros_like(params), n_clients)
    state = {
        "params": params,
        "deltas": zeros,                       # Δ_{t−1}^i  (Strategy 3)
        "prev_local": tree_broadcast_clients(params, n_clients),
        "trained_ever": jnp.zeros((n_clients,), bool),
        "round": jnp.zeros((), jnp.int32),
        "key": rng,
    }
    if strategy is not None:
        state.update(strategy.init_extra_history(params, n_clients))
    if compress not in COMPRESS_KINDS:
        raise ValueError(
            f"compress must be one of {COMPRESS_KINDS}, got {compress!r}")
    if compress == "int8":
        from repro.core.compress import quantize_rows
        flat, _ = tree_ravel(params)
        p_pad = flat.shape[0] + (-flat.shape[0]) % _FUSED_PAD
        # zero deltas quantized: payload 0, the clamp-floor scale — exactly
        # quantize_rows of the zero history, so resume round-trips bit-wise
        payload, scales = quantize_rows(jnp.zeros((n_clients, p_pad)))
        state["deltas"] = {"payload": payload, "scales": scales}
        if not needs_stale:
            del state["prev_local"]
    if (policy is None) != (profile is None):
        raise ValueError("policy mode needs BOTH policy and profile "
                         "(got exactly one)")
    if policy is not None:
        from repro.system.devices import init_device_state, init_ledger
        state["policy"] = policy.init_rows(n_clients)
        state["device"] = init_device_state(profile)
        state["ledger"] = init_ledger(n_clients)
    if topology is not None:
        if topology.n_clients != n_clients:
            raise ValueError(
                f"topology covers {topology.n_clients} clients, state has "
                f"{n_clients}")
        state["edge_params"] = tree_broadcast_clients(params,
                                                      topology.n_edges)
    if async_cfg is not None:
        from repro.core.async_rounds import init_async_carry
        state = init_async_carry(state, params, n_clients, async_cfg,
                                 needs_stale=needs_stale)
    return state


def _round_keys(key, n: int):
    """Split the round key into (next round key, per-client keys).

    Keys are always derived for the FULL federation (``n`` = total clients)
    and cohort members take ``keys[idx]`` — client i sees the same training
    randomness whether it runs in a full round or a sampled cohort, which
    is what makes the sharded executor differential-testable against the
    others.
    """
    ks = jax.random.split(key, n + 1)
    return ks[0], ks[1:]


def _train_clients(model: Classifier, fed: FedConfig, start, keys,
                   cx, cy, sizes, k_active, prox: float = 0.0, dual=None):
    """vmap local training over a client-stacked tree of start params —
    the per-client broadcast of the flat executors, or each client's edge
    aggregator model under a two-tier topology. ``dual`` is an optional
    client-stacked tree of FedDyn correction rows, vmapped alongside."""
    if dual is None:
        return jax.vmap(
            lambda p, k, x, y, sz, ka: _local_train(
                model, p, k, x, y, sz, fed.local_steps, ka,
                fed.batch_size, fed.lr, prox)
        )(start, keys, cx, cy, sizes, k_active)
    return jax.vmap(
        lambda p, k, x, y, sz, ka, h: _local_train(
            model, p, k, x, y, sz, fed.local_steps, ka,
            fed.batch_size, fed.lr, prox, h)
    )(start, keys, cx, cy, sizes, k_active, dual)


def _train_cohort(model: Classifier, fed: FedConfig, params, keys,
                  cx, cy, sizes, k_active, prox: float = 0.0, dual=None):
    """Broadcast the global model and vmap local training over a cohort
    (full federation or gathered participants)."""
    broadcast = tree_broadcast_clients(params, sizes.shape[0])
    local = _train_clients(model, fed, broadcast, keys, cx, cy, sizes,
                           k_active, prox, dual)
    return broadcast, local


def _cohort_round(model: Classifier, fed: FedConfig, strategy: Strategy,
                  params, rnd, hist, cx, cy, sizes, keys,
                  sel_mask, train_mask, k_active, axis_name=None,
                  energy=None, channel=None, client_ids=None,
                  n_total=None):
    """One round over a cohort view of the federation.

    ``hist`` holds the cohort's per-client rows (``deltas`` / ``prev_local``
    / ``trained_ever`` + any strategy extras); every executor wraps this
    one traceable core. With ``axis_name`` set the cohort axis is
    ``shard_map``'ed and aggregation reduces across shards (the
    strategies' ``aggregate`` hooks psum), so the returned global params
    are replicated.

    ``channel`` (an :class:`repro.core.channel.UplinkChannel`, or None
    for the exact noiseless uplink) fades the stacked uploads before
    aggregation — ``client_ids`` are the cohort's absolute ids into the
    ``n_total``-client gain draw — and corrupts the aggregated delta with
    this round's AWGN (post-psum, so the draw is replicated).
    Returns ``(new_params, new_hist)``.
    """
    broadcast, local = _train_cohort(model, fed, params, keys, cx, cy,
                                     sizes, k_active,
                                     prox=strategy.prox_coeff(),
                                     dual=strategy.local_dual(hist))
    trained_delta = tree_sub(local, broadcast)

    # ---- estimation for skipped clients --------------------------
    stale_delta = tree_sub(hist["prev_local"], broadcast)
    stale_delta = masked_select(hist["trained_ever"], stale_delta,
                                tree_zeros_like(stale_delta))
    ctx = RoundCtx(sel_mask=sel_mask, train_mask=train_mask,
                   k_active=k_active, round=rnd, tau=fed.tau,
                   stale_delta=stale_delta, trained_delta=trained_delta,
                   axis_name=axis_name, energy=energy)
    est = strategy.estimate(hist, ctx)
    delta_i = masked_select(train_mask, trained_delta, est)

    # ---- uplink + aggregation (Eq. 3 over Δ) ----------------------
    # fading touches only the aggregated copy of the uploads — history
    # keeps each client's true delta, exactly as a receiver cannot
    # corrupt what the client stores locally
    up = delta_i
    if channel is not None:
        nt = n_total if n_total is not None else sel_mask.shape[0]
        ids = (client_ids if client_ids is not None
               else jnp.arange(nt, dtype=jnp.int32))
        up = channel.fade(up, rnd, ids, nt, TAG_UPLINK)
    aggf = strategy.agg_mask(ctx).astype(jnp.float32)
    delta = strategy.aggregate(up, aggf, ctx)
    if channel is not None:
        delta = channel.corrupt(delta, rnd, TAG_UPLINK)
    new_params = tree_add(params, delta)

    # ---- history updates ------------------------------------------
    upd = sel_mask & train_mask
    deltas, prev_local = strategy.update_history(hist, ctx, trained_delta,
                                                 local, est)
    new_hist = {
        "deltas": deltas,
        "prev_local": prev_local,
        "trained_ever": hist["trained_ever"] | upd,
    }
    new_hist.update(strategy.update_extra_history(hist, ctx, trained_delta,
                                                  local, est))
    return new_params, new_hist


def make_round_body(model: Classifier, data: FederatedData, fed: FedConfig,
                    *, fused: bool = False):
    """The traceable single-round transition ``(state, sel, train, k) →
    state`` that every executor (jit, scan, fused) wraps."""
    strategy = fed.resolve()
    if fused:
        return _make_fused_round_body(model, data, fed, strategy)
    channel = uplink_channel(fed)

    def round_body(state, sel_mask, train_mask, k_active, energy=None):
        key, keys = _round_keys(state["key"], data.n_clients)
        new_params, new_hist = _cohort_round(
            model, fed, strategy, state["params"], state["round"], state,
            data.x, data.y, data.sizes, keys, sel_mask, train_mask,
            k_active, energy=energy, channel=channel)
        return {
            "params": new_params,
            **new_hist,
            "round": state["round"] + 1,
            "key": key,
        }

    return round_body


def _make_fused_round_body(model: Classifier, data: FederatedData,
                           fed: FedConfig, strategy: Strategy):
    """Route the round through the fused Pallas kernel: one HBM pass
    computes Δ_t^i = train ? (x_K^i − x_t) : est_i, the weighted mean and
    the global update over flat (N, P) parameters.

    The strategy specializes the kernel through its
    :meth:`~repro.core.strategies.Strategy.fused_epilogue` coefficients
    (every registry estimate is affine in the stored Δ and the stale-model
    delta), so the whole registry runs fused. With
    ``fed.compress == "int8"`` the Δ history is carried as a flat
    tile-padded int8 payload + per-row scales and the round runs the q8
    kernel; replay-only strategies (``needs_stale=False``) then drop the
    f32 ``prev_local`` carry entirely."""
    from repro.kernels import ops

    if not strategy.fused_capable:
        raise ValueError(
            f"strategy {strategy.name!r} is not fused-capable (its estimate "
            "is not affine in the stored Δ / stale delta); use the "
            "tree-ops path")
    q8 = fed.compress == "int8"
    channel = uplink_channel(fed)
    n = data.n_clients

    def round_body(state, sel_mask, train_mask, k_active, energy=None):
        key, keys = _round_keys(state["key"], data.n_clients)
        broadcast, local = _train_cohort(model, fed, state["params"], keys,
                                         data.x, data.y, data.sizes,
                                         k_active,
                                         prox=strategy.prox_coeff(),
                                         dual=strategy.local_dual(state))
        flat_local, unravel_clients = tree_ravel_clients(local)
        flat_global, unravel = tree_ravel(state["params"])
        p = flat_global.shape[0]
        pad = (-p) % _FUSED_PAD
        if pad:                     # zero-pad: padded lanes stay exactly 0
            flat_local = jnp.pad(flat_local, ((0, 0), (0, pad)))
            flat_global = jnp.pad(flat_global, (0, pad))
        # history semantics: stored Δ only advances for sel∧train clients,
        # so that (not bare train_mask) is the kernel's train input
        upd = sel_mask & train_mask
        ctx = RoundCtx(sel_mask=sel_mask, train_mask=train_mask,
                       k_active=k_active, round=state["round"],
                       tau=fed.tau, stale_delta=None, trained_delta=None,
                       energy=energy)
        ep = strategy.fused_epilogue(ctx)
        if channel is not None and channel.fading:
            # fading scales only each client's aggregated contribution —
            # fold the gains into the kernel's aggregation weights; the
            # stored Δ history stays the client's true delta
            gains = channel.gains(state["round"],
                                  jnp.arange(n, dtype=jnp.int32), n,
                                  TAG_UPLINK)
            ep = ep._replace(agg_w=ep.agg_w * gains)
        stale_flat = None
        if strategy.needs_stale:
            stale = masked_select(
                state["trained_ever"],
                tree_sub(state["prev_local"], broadcast),
                tree_zeros_like(broadcast))
            stale_flat, _ = tree_ravel_clients(stale)
            if pad:
                stale_flat = jnp.pad(stale_flat, ((0, 0), (0, pad)))
        updf = upd.astype(jnp.float32)
        if q8:
            new_payload, new_scales, new_global = ops.cc_delta_update_q8(
                flat_local, state["deltas"]["payload"],
                state["deltas"]["scales"], flat_global, updf, updf,
                ep.agg_w, ep.e_replay, ep.e_stale, ep.store_scale,
                ep.denom, ep.post_scale, stale_flat,
                block=min(65536, p + pad))
            new_deltas = {"payload": new_payload, "scales": new_scales}
        else:
            flat_deltas, _ = tree_ravel_clients(state["deltas"])
            if pad:
                flat_deltas = jnp.pad(flat_deltas, ((0, 0), (0, pad)))
            new_flat, new_global = ops.cc_epilogue_update(
                flat_local, flat_deltas, flat_global, updf, updf,
                ep.agg_w, ep.e_replay, ep.e_stale, ep.store_scale,
                ep.denom, ep.post_scale, stale_flat,
                block=min(65536, p + pad))
            new_deltas = unravel_clients(new_flat[:, :p])
        new_params = unravel(new_global[:p])
        if channel is not None:
            # the kernel already applied the (faded) aggregate; AWGN hits
            # the aggregated delta exactly as in the tree-ops path
            d = channel.corrupt(tree_sub(new_params, state["params"]),
                                state["round"], TAG_UPLINK)
            new_params = tree_add(state["params"], d)
        out = {
            "params": new_params,
            "deltas": new_deltas,
            "trained_ever": state["trained_ever"] | upd,
            "round": state["round"] + 1,
            "key": key,
        }
        if "prev_local" in state:
            out["prev_local"] = masked_select(upd, local,
                                              state["prev_local"])
        if strategy.extra_history_keys():
            out.update(strategy.update_extra_history(
                state, ctx, tree_sub(local, broadcast), local, None))
        return out

    return round_body


def make_round_fn(model: Classifier, data: FederatedData, fed: FedConfig,
                  *, fused: bool = False):
    """One jitted round: ``round_fn(state, sel_mask, train_mask, k_active)``."""
    return jax.jit(make_round_body(model, data, fed, fused=fused))


def make_span_runner(model: Classifier, data: FederatedData, fed: FedConfig,
                     *, fused: bool = False):
    """Scan executor: ``run_span(state, sel_chunk, train_chunk, k_active)``
    advances the federation over a (C, N) chunk of plan masks as one jitted
    ``lax.scan`` — no host sync until the span ends. Recompiles once per
    distinct chunk length C (eval cadence makes C constant in practice)."""
    round_body = make_round_body(model, data, fed, fused=fused)

    @jax.jit
    def run_span(state, sel_chunk, train_chunk, k_active):
        def step(st, masks):
            sel, train = masks
            return round_body(st, sel, train, k_active), None

        state, _ = jax.lax.scan(step, state, (sel_chunk, train_chunk))
        return state

    return run_span


# ---------------------------------------------------------------------------
# policy mode: traced in-loop decisions over simulated device state
# ---------------------------------------------------------------------------


def make_policy_round_body(model: Classifier, data: FederatedData,
                           fed: FedConfig, policy, profile, *,
                           fused: bool = False):
    """The policy-mode round transition ``(state, sel_mask, k_active) →
    state``: the train/estimate decision happens *inside the trace* —
    ``policy.decide`` reads the carried device state, the device simulator
    advances, and the energy ledger accumulates. Wraps the same mask-mode
    round body every executor uses, so round numerics are identical given
    identical decisions."""
    from repro.core.budget import budget_ctx
    from repro.system.devices import advance_devices, update_ledger

    if profile.n_clients != data.n_clients:
        raise ValueError(
            f"device profile covers {profile.n_clients} clients, data has "
            f"{data.n_clients}")
    base = make_round_body(model, data, fed, fused=fused)
    rows = profile.rows()
    ids = jnp.arange(data.n_clients, dtype=jnp.int32)
    # strategy extras (e.g. feddyn's dual rows) ride the base round state
    base_keys = _BASE_KEYS + fed.resolve().extra_history_keys()

    def round_body(state, sel_mask, k_active):
        dev = state["device"]
        ctx = budget_ctx(rows, dev, state["round"], ids, sel_mask,
                         profile.seed)
        train_mask, new_rows = policy.decide(state["policy"], ctx)
        train_mask = train_mask & sel_mask
        # compress="int8" replay strategies carry no prev_local
        base_state = {k: state[k] for k in base_keys if k in state}
        new_base = base(base_state, sel_mask, train_mask, k_active,
                        energy=dev["energy"])
        spent = sel_mask & train_mask
        new_base["policy"] = new_rows
        new_base["device"] = advance_devices(rows, dev, spent,
                                             state["round"], ids,
                                             profile.seed)
        new_base["ledger"] = update_ledger(state["ledger"], rows, sel_mask,
                                           train_mask)
        return new_base

    return round_body


def make_policy_round_fn(model: Classifier, data: FederatedData,
                         fed: FedConfig, policy, profile, *,
                         fused: bool = False):
    """One jitted policy-mode round: ``round_fn(state, sel_mask,
    k_active)``."""
    return jax.jit(make_policy_round_body(model, data, fed, policy, profile,
                                          fused=fused))


def make_policy_span_runner(model: Classifier, data: FederatedData,
                            fed: FedConfig, policy, profile, *,
                            fused: bool = False):
    """Policy-mode scan executor: ``run_span(state, sel_chunk, k_active)``
    advances a (C, N) span of *selection* masks as one jitted ``lax.scan``
    — training decisions, device dynamics and the ledger are all traced, so
    an eval-free span is still a single program with no host sync."""
    round_body = make_policy_round_body(model, data, fed, policy, profile,
                                        fused=fused)

    @jax.jit
    def run_span(state, sel_chunk, k_active):
        def step(st, sel):
            return round_body(st, sel, k_active), None

        state, _ = jax.lax.scan(step, state, sel_chunk)
        return state

    return run_span


def make_sharded_span_runner(model: Classifier, data: FederatedData,
                             fed: FedConfig, *, mesh=None,
                             cohort_size: int | None = None,
                             policy=None, profile=None):
    """Sharded executor: ``run_span(state, sel_chunk, train_chunk, k_active,
    cohort_idx)`` advances the federation over a (C, N) chunk of plan masks
    with each round's cohort ``shard_map``'ed over the ``clients`` mesh axis.

    ``cohort_idx`` is a (C, M) table of participant ids (see
    :class:`repro.data.federated.CohortSampler`; M = ``cohort_size``,
    defaulting to ``fed.cohort_size`` or the full federation). Per round the
    scan body gathers only the cohort's history rows and data shards
    (``strategy.gather_history``), runs the cohort round split across the
    mesh — aggregation reduces with ``lax.psum``, so the new global params
    come back replicated — and scatters the updated rows into the full-N
    state (``strategy.scatter_history``). Non-members are untouched, exactly
    as if their ``sel``/``train`` masks were False.

    ``mesh`` defaults to a 1-D client mesh over the largest device count
    that divides the cohort (:func:`repro.launch.mesh.make_client_mesh`);
    an explicit mesh must divide it.

    With ``policy`` + ``profile`` set (policy mode) the signature drops the
    train chunk — ``run_span(state, sel_chunk, k_active, cohort_idx)`` —
    and each round *decides* per-shard: the cohort's policy rows, device
    rows and profile rows are gathered alongside the history, and the
    decision runs inside ``shard_map`` (every policy op is per-client
    elementwise, so no cross-shard reduction is needed). The device advance
    and ledger update then run over the FULL federation outside the shard
    — off-cohort devices keep harvesting and their load keeps evolving,
    exactly as in a full round where they simply aren't selected. Together
    with decision randomness keyed on absolute client ids, this makes a
    sampled-cohort policy round EQUAL a full policy round whose selection
    mask is zeroed outside the cohort (pinned bit-for-bit in
    ``tests/test_executor_matrix.py``).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec
    from repro.launch.mesh import best_client_shards, make_client_mesh
    from repro.sharding.api import ShardingContext

    if (policy is None) != (profile is None):
        raise ValueError("policy mode needs BOTH policy and profile "
                         "(got exactly one)")
    strategy = fed.resolve()
    n = data.n_clients
    m = cohort_size if cohort_size is not None else (fed.cohort_size or n)
    if not 1 <= m <= n:
        raise ValueError(f"cohort_size must be in [1, {n}], got {m}")
    if mesh is None:
        mesh = make_client_mesh(best_client_shards(m))
    if CLIENT_AXIS not in mesh.axis_names:
        raise ValueError(f"mesh must carry a {CLIENT_AXIS!r} axis, got "
                         f"{mesh.axis_names}")
    shards = dict(zip(mesh.axis_names, mesh.devices.shape))[CLIENT_AXIS]
    if m % shards:
        raise ValueError(
            f"cohort size {m} must divide evenly over the {shards}-way "
            f"{CLIENT_AXIS!r} mesh axis")

    # the logical-axis rules of sharding/api map the cohort dim to the mesh
    ctx_sh = ShardingContext(mesh=mesh, rules={CLIENT_AXIS: [CLIENT_AXIS]})
    cspec = ctx_sh.spec((CLIENT_AXIS,))       # shard leading (cohort) dim
    rspec = PartitionSpec()                   # replicated

    channel = uplink_channel(fed)

    if policy is None:
        def shard_body(params, rnd, hist, keys, cx, cy, sizes, sel, train,
                       ka, ids):
            # ids: this shard's slice of the cohort's ABSOLUTE client ids
            # — fading gains are drawn for the full federation and indexed
            # by them, so a sharded cohort sees exactly the flat gains;
            # the post-aggregate AWGN keys only on (seed, tag, round), so
            # the post-psum draw is replicated across shards
            return _cohort_round(model, fed, strategy, params, rnd, hist,
                                 cx, cy, sizes, keys, sel, train, ka,
                                 axis_name=CLIENT_AXIS, channel=channel,
                                 client_ids=ids, n_total=n)

        cohort_round = shard_map(
            shard_body, mesh=mesh,
            in_specs=(rspec, rspec, cspec, cspec, cspec, cspec, cspec,
                      cspec, cspec, cspec, cspec),
            out_specs=(rspec, cspec))

        @jax.jit
        def run_span(state, sel_chunk, train_chunk, k_active, cohort_idx):
            def step(st, xs):
                sel, train, idx = xs
                key, keys = _round_keys(st["key"], n)
                # at full participation the cohort IS the federation
                # (CohortSampler degenerates to arange — pinned in tests)
                # and the takes/scatters below are identity updates; a
                # dedicated branch that skipped them benchmarked SLOWER
                # than letting XLA see the uniform gather/scatter round
                # (benchmarks/sharded_clients.py), so there is one path
                take = functools.partial(jnp.take, indices=idx, axis=0)
                hist = strategy.gather_history(st, idx)
                new_params, new_hist = cohort_round(
                    st["params"], st["round"], hist, take(keys),
                    take(data.x), take(data.y), take(data.sizes),
                    take(sel), take(train), take(k_active), idx)
                new_state = strategy.scatter_history(st, idx, new_hist)
                new_state.update(params=new_params, round=st["round"] + 1,
                                 key=key)
                return new_state, None

            state, _ = jax.lax.scan(step, state,
                                    (sel_chunk, train_chunk, cohort_idx))
            return state

        return run_span

    # ---- policy mode: decide per-shard on gathered device rows ----------
    from repro.core.budget import budget_ctx
    from repro.system.devices import advance_devices, update_ledger

    if profile.n_clients != n:
        raise ValueError(
            f"device profile covers {profile.n_clients} clients, data has "
            f"{n}")
    prof_rows = profile.rows()
    all_ids = jnp.arange(n, dtype=jnp.int32)

    def shard_body(params, rnd, hist, keys, cx, cy, sizes, sel, ka,
                   pol, dev, prof, ids):
        ctx = budget_ctx(prof, dev, rnd, ids, sel, profile.seed)
        train, new_pol = policy.decide(pol, ctx)
        train = train & sel
        new_params, new_hist = _cohort_round(
            model, fed, strategy, params, rnd, hist, cx, cy, sizes, keys,
            sel, train, ka, axis_name=CLIENT_AXIS, energy=dev["energy"],
            channel=channel, client_ids=ids, n_total=n)
        return new_params, new_hist, new_pol, train

    cohort_round = shard_map(
        shard_body, mesh=mesh,
        in_specs=(rspec, rspec, cspec, cspec, cspec, cspec, cspec, cspec,
                  cspec, cspec, cspec, cspec, cspec),
        out_specs=(rspec, cspec, cspec, cspec))

    @jax.jit
    def run_span(state, sel_chunk, k_active, cohort_idx):
        def step(st, xs):
            sel, idx = xs
            key, keys = _round_keys(st["key"], n)
            # one path for every cohort size — see the mask-mode note above
            take = functools.partial(jnp.take, indices=idx, axis=0)
            hist = strategy.gather_history(st, idx)
            new_params, new_hist, new_pol, train_c = cohort_round(
                st["params"], st["round"], hist, take(keys),
                take(data.x), take(data.y), take(data.sizes),
                take(sel), take(k_active),
                jax.tree.map(take, st["policy"]),
                jax.tree.map(take, st["device"]),
                jax.tree.map(take, prof_rows), idx)
            new_state = strategy.scatter_history(st, idx, new_hist)
            new_state["policy"] = jax.tree.map(
                lambda full, part: full.at[idx].set(part),
                st["policy"], new_pol)
            # off-cohort clients behave exactly as unselected clients
            # of a full round: no training spend, no ledger entry —
            # but their devices keep harvesting and their load keeps
            # evolving
            eff_sel = sel & jnp.zeros((n,), bool).at[idx].set(True)
            train_full = jnp.zeros((n,), bool).at[idx].set(train_c)
            new_state["device"] = advance_devices(
                prof_rows, st["device"], train_full, st["round"], all_ids,
                profile.seed)
            new_state["ledger"] = update_ledger(st["ledger"], prof_rows,
                                                eff_sel, train_full)
            new_state.update(params=new_params, round=st["round"] + 1,
                             key=key)
            return new_state, None

        state, _ = jax.lax.scan(step, state, (sel_chunk, cohort_idx))
        return state

    return run_span


# ---------------------------------------------------------------------------
# hierarchical two-tier executor: client → edge aggregator → server
# ---------------------------------------------------------------------------


def _tree_rows(tree: PyTree, sl) -> PyTree:
    """Slice the leading (client) axis of every leaf."""
    return jax.tree.map(lambda x: x[sl], tree)


def _slice_ctx(ctx: RoundCtx, sl) -> RoundCtx:
    """Restrict a round context to one edge's block of client rows."""
    import dataclasses
    return dataclasses.replace(
        ctx, sel_mask=ctx.sel_mask[sl], train_mask=ctx.train_mask[sl],
        k_active=ctx.k_active[sl],
        stale_delta=_tree_rows(ctx.stale_delta, sl),
        trained_delta=_tree_rows(ctx.trained_delta, sl),
        energy=None if ctx.energy is None else ctx.energy[sl],
        edge_id=None if ctx.edge_id is None else ctx.edge_id[sl])


def make_hierarchical_span_runner(model: Classifier, data: FederatedData,
                                  fed: FedConfig, topo, *, mesh=None,
                                  policy=None, profile=None):
    """Two-tier executor: ``run_span(state, sel_chunk, train_chunk,
    k_active)`` advances a (C, N) span of plan masks through the
    client→edge→server topology ``topo``
    (:class:`repro.core.hierarchy.EdgeTopology`).

    Round semantics (one scan step):

    * every client trains (or estimates) against **its edge aggregator's
      model** — the carry holds an (E,)-stacked ``edge_params`` tree next
      to the server's ``params``;
    * on an intra-edge round (``(t+1) % edge_period != 0``) each edge
      aggregates ONLY its own members — ``strategy.aggregate`` runs on the
      edge's block with the edge-restricted aggregation mask, so
      cc/fednova/s2 estimation semantics hold per edge — and advances its
      edge model; the server sees nothing;
    * on a sync round (every ``edge_period``-th) the final intra-edge
      aggregation is folded into the server merge: client i uploads
      ``y_i = Δ_i + (x_{e(i)} − G)`` (its fresh delta on top of its edge's
      period displacement) and the server takes the flat masked mean of
      the uploads — exactly the aggregation-mass-weighted average of edge
      models (the nested-mean identity of :mod:`repro.core.hierarchy`),
      computed with the SAME primitive the flat executors use. All edges
      then reset to the new global model.

    Collapse guarantees (the oracle for ``tests/test_executor_matrix.py``):
    with ``edge_period == 1`` the edge displacement is exactly zero, so
    the sync round IS a flat round bit-for-bit; with a single edge the
    edge and the server coincide, so every round runs the flat update on
    the edge model and the sync is an identity (the global model stays
    fresh every round).

    ``mesh`` is a 1-D ``("edges",)`` mesh
    (:func:`repro.launch.mesh.make_edge_mesh`; defaults to the largest
    visible device count that divides E). With more than one shard the
    topology must be contiguous-uniform so whole edges land on one device:
    intra-edge rounds then run with ZERO cross-device traffic — each
    edge's block aggregation reads exactly its own rows, making results
    bit-identical across shard counts — and sync rounds ``all_gather`` the
    uploads so every shard computes the identical full-federation merge
    (the gather IS the edge→server uplink).

    With ``policy`` + ``profile`` (policy mode, the Session default) the
    signature drops the train chunk — ``run_span(state, sel_chunk,
    k_active)`` — and the budget policy decides per round from the carried
    device state, exactly as in the flat policy executors; ``BudgetCtx``
    and ``RoundCtx`` carry each client's edge id so policies/strategies
    can condition on the gateway.
    """
    import dataclasses

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec
    from repro.launch.mesh import best_edge_shards, make_edge_mesh

    if (policy is None) != (profile is None):
        raise ValueError("policy mode needs BOTH policy and profile "
                         "(got exactly one)")
    strategy = fed.resolve()
    n = data.n_clients
    if topo.n_clients != n:
        raise ValueError(f"topology covers {topo.n_clients} clients, data "
                         f"has {n}")
    n_edges, period = topo.n_edges, topo.edge_period
    if mesh is None:
        # irregular layouts cannot place whole edges per device — they run
        # single-shard; uniform ones spread edges over the visible devices
        mesh = make_edge_mesh(best_edge_shards(n_edges)
                              if topo.is_contiguous_uniform else 1)
    if EDGE_AXIS not in mesh.axis_names:
        raise ValueError(f"mesh must carry an {EDGE_AXIS!r} axis, got "
                         f"{mesh.axis_names}")
    shards = dict(zip(mesh.axis_names, mesh.devices.shape))[EDGE_AXIS]
    if n_edges % shards:
        raise ValueError(
            f"{n_edges} edges must divide evenly over the {shards}-way "
            f"{EDGE_AXIS!r} mesh axis")
    uniform = topo.is_contiguous_uniform
    if shards > 1 and not uniform:
        raise ValueError(
            "a multi-shard edge mesh needs a contiguous-uniform topology "
            "(N % E == 0, consecutive equal blocks) so whole edges land "
            "on one device; run irregular topologies on a 1-shard mesh")
    e_local = n_edges // shards
    n_local = n // shards           # uniform guaranteed when shards > 1
    block = n // n_edges if uniform else None
    if uniform:
        # identical on every shard: local client row r belongs to the
        # shard's local edge r // block
        local_assign = jnp.asarray(np.arange(n_local) // block, jnp.int32)
    else:
        local_assign = jnp.asarray(topo.assignment, jnp.int32)

    if profile is not None and profile.n_clients != n:
        raise ValueError(
            f"device profile covers {profile.n_clients} clients, data has "
            f"{n}")

    if shards > 1:
        def local_rows(x):
            """This shard's client rows of a replicated (N, ...) array."""
            i = jax.lax.axis_index(EDGE_AXIS)
            return jax.lax.dynamic_slice_in_dim(x, i * n_local, n_local)

        def gather(x):
            return jax.lax.all_gather(x, EDGE_AXIS, axis=0, tiled=True)

        def edge_ids_of():
            return (local_assign
                    + jax.lax.axis_index(EDGE_AXIS) * e_local)
    else:
        def local_rows(x):
            return x

        def gather(x):
            return x

        def edge_ids_of():
            return jnp.asarray(topo.assignment, jnp.int32)

    hist_keys = strategy.history_keys
    channel = uplink_channel(fed)

    if shards > 1:
        def client_ids_of():
            """Absolute client ids of this shard's rows (uniform layout:
            shard s holds the contiguous block s·n_local ...)."""
            return (jax.lax.axis_index(EDGE_AXIS) * n_local
                    + jnp.arange(n_local, dtype=jnp.int32))
    else:
        def client_ids_of():
            return jnp.arange(n, dtype=jnp.int32)

    def hier_round(G, rnd, edge_params, hist, keys, cx, cy, sizes,
                   sel, train, k_active, energy=None):
        """One two-tier round over this shard's clients and edges; returns
        (new_G replicated, new_edge_params, new_hist)."""
        edge_ids = edge_ids_of()
        client_start = jax.tree.map(lambda x: x[local_assign], edge_params)
        local = _train_clients(model, fed, client_start, keys, cx, cy,
                               sizes, k_active,
                               prox=strategy.prox_coeff(),
                               dual=strategy.local_dual(hist))
        trained_delta = tree_sub(local, client_start)
        stale_delta = tree_sub(hist["prev_local"], client_start)
        stale_delta = masked_select(hist["trained_ever"], stale_delta,
                                    tree_zeros_like(stale_delta))
        ctx = RoundCtx(sel_mask=sel, train_mask=train, k_active=k_active,
                       round=rnd, tau=fed.tau, stale_delta=stale_delta,
                       trained_delta=trained_delta, axis_name=None,
                       energy=energy, edge_id=edge_ids)
        est = strategy.estimate(hist, ctx)
        delta_i = masked_select(train, trained_delta, est)
        aggf = strategy.agg_mask(ctx).astype(jnp.float32)
        # client→edge uplink fading: one gain draw per client per round,
        # shared by whichever tier consumes the upload this round (the
        # history still stores the true deltas — see _cohort_round)
        up_i = (delta_i if channel is None else
                channel.fade(delta_i, rnd, client_ids_of(), n, TAG_C2E))

        # ---- intra-edge tier: each edge aggregates only its members ---
        # Uniform layouts slice each edge's own block, so total work stays
        # O(N) and nothing crosses shards; irregular layouts (1-shard
        # only) pay E full-width masked aggregations — the cost of
        # arbitrary assignments at small scale.
        def intra_update(edge_params):
            parts = []
            for e in range(e_local):
                if uniform:
                    sl = slice(e * block, (e + 1) * block)
                    d_e = strategy.aggregate(_tree_rows(up_i, sl),
                                             aggf[sl], _slice_ctx(ctx, sl))
                else:
                    member = (local_assign == e).astype(jnp.float32)
                    d_e = strategy.aggregate(up_i, aggf * member, ctx)
                if channel is not None:
                    # independent AWGN per edge receiver, keyed on the
                    # GLOBAL edge id so results are shard-layout-invariant
                    ge = (e if shards == 1 else
                          e + jax.lax.axis_index(EDGE_AXIS) * e_local)
                    d_e = channel.corrupt(d_e, rnd, TAG_C2E, sub=ge)
                parts.append(tree_add(tree_index(edge_params, e), d_e))
            return tree_stack(parts)

        if n_edges == 1:
            # the edge IS the server: the sync is an identity, performed
            # every round so the global model never goes stale — this is
            # exactly the flat executor's update, bit-for-bit
            ep_intra = intra_update(edge_params)
            return tree_index(ep_intra, 0), ep_intra, _roll_hist(
                hist, ctx, trained_delta, local, est, sel, train)

        # ---- sync tier: fold the last edge aggregation into the merge -
        def sync_update(edge_params):
            if period == 1:
                y = delta_i    # edge displacement is exactly zero
            else:
                y = tree_add(delta_i,
                             tree_sub(client_start,
                                      tree_broadcast_clients(G, n_local)))
            if channel is not None:
                # the client transmits the WHOLE upload y_i (fresh delta +
                # edge displacement) over the air — same gain draw as the
                # intra tier, applied to the full signal
                y = channel.fade(y, rnd, client_ids_of(), n, TAG_C2E)
            ctx_full = dataclasses.replace(
                ctx, sel_mask=gather(sel), train_mask=gather(train),
                k_active=gather(k_active),
                stale_delta=jax.tree.map(gather, stale_delta),
                trained_delta=jax.tree.map(gather, trained_delta),
                energy=None if energy is None else gather(energy),
                edge_id=gather(edge_ids))
            d_global = strategy.aggregate(jax.tree.map(gather, y),
                                          gather(aggf), ctx_full)
            if channel is not None:
                # two independent hops — client→edge, then edge→server —
                # both keyed only on (seed, tag, round), so every shard
                # computes the identical replicated draws
                d_global = channel.corrupt(d_global, rnd, TAG_C2E)
                d_global = channel.corrupt(d_global, rnd, TAG_E2S)
            G_sync = tree_add(G, d_global)
            return G_sync, tree_broadcast_clients(G_sync, e_local)

        if period == 1:
            new_G, new_ep = sync_update(edge_params)
        else:
            # lax.cond, NOT a where-select: the all_gather + full merge of
            # the sync branch must only execute on period boundaries —
            # intra-edge rounds stay collective-free (the predicate is
            # replicated, so no shard can diverge)
            is_sync = ((rnd + 1) % period) == 0
            new_G, new_ep = jax.lax.cond(
                is_sync, sync_update,
                lambda ep: (G, intra_update(ep)), edge_params)
        return new_G, new_ep, _roll_hist(hist, ctx, trained_delta, local,
                                         est, sel, train)

    def _roll_hist(hist, ctx, trained_delta, local, est, sel, train):
        deltas, prev_local = strategy.update_history(hist, ctx,
                                                     trained_delta, local,
                                                     est)
        out = {"deltas": deltas, "prev_local": prev_local,
               "trained_ever": hist["trained_ever"] | (sel & train)}
        out.update(strategy.update_extra_history(hist, ctx, trained_delta,
                                                 local, est))
        return out

    rspec, sspec = PartitionSpec(), PartitionSpec(EDGE_AXIS)
    state_spec = {"params": rspec, "round": rspec, "key": rspec,
                  "edge_params": sspec}
    state_spec.update({k: sspec for k in hist_keys})
    if policy is not None:
        state_spec.update(policy=sspec, device=sspec, ledger=sspec)
    chunk_spec = PartitionSpec(None, EDGE_AXIS)
    data_args = (data.x, data.y, data.sizes)

    if policy is None:
        def span_body(state, sel_chunk, train_chunk, k_active, cx, cy,
                      sizes):
            def step(st, xs):
                sel, train = xs
                key, keys = _round_keys(st["key"], n)
                new_G, new_ep, new_hist = hier_round(
                    st["params"], st["round"], st["edge_params"],
                    {k: st[k] for k in hist_keys}, local_rows(keys),
                    cx, cy, sizes, sel, train, k_active)
                return {"params": new_G, "edge_params": new_ep,
                        **new_hist, "round": st["round"] + 1,
                        "key": key}, None

            state, _ = jax.lax.scan(step, state, (sel_chunk, train_chunk))
            return state

        if shards > 1:
            # check_rep=False: the replication checker cannot see through
            # the scan carry that params/round/key stay replicated — they
            # are by construction (the merge runs on all_gather'ed values
            # identically on every shard)
            span_body = shard_map(
                span_body, mesh=mesh,
                in_specs=(state_spec, chunk_spec, chunk_spec, sspec,
                          sspec, sspec, sspec),
                out_specs=state_spec, check_rep=False)

        @jax.jit
        def run_span(state, sel_chunk, train_chunk, k_active):
            return span_body(state, sel_chunk, train_chunk, k_active,
                             *data_args)

        return run_span

    # ---- policy mode: in-loop decisions over per-edge device state ----
    from repro.core.budget import budget_ctx
    from repro.system.devices import advance_devices, update_ledger

    prof_rows = profile.rows()
    all_ids = jnp.arange(n, dtype=jnp.int32)

    def span_body(state, sel_chunk, k_active, cx, cy, sizes):
        prof_l = jax.tree.map(local_rows, prof_rows)
        ids_l = local_rows(all_ids)

        def step(st, sel):
            key, keys = _round_keys(st["key"], n)
            dev = st["device"]
            bctx = budget_ctx(prof_l, dev, st["round"], ids_l, sel,
                              profile.seed, edge_ids=edge_ids_of())
            train, new_pol = policy.decide(st["policy"], bctx)
            train = train & sel
            new_G, new_ep, new_hist = hier_round(
                st["params"], st["round"], st["edge_params"],
                {k: st[k] for k in hist_keys}, local_rows(keys),
                cx, cy, sizes, sel, train, k_active,
                energy=dev["energy"])
            spent = sel & train
            return {"params": new_G, "edge_params": new_ep, **new_hist,
                    "policy": new_pol,
                    "device": advance_devices(prof_l, dev, spent,
                                              st["round"], ids_l,
                                              profile.seed),
                    "ledger": update_ledger(st["ledger"], prof_l, sel,
                                            train),
                    "round": st["round"] + 1, "key": key}, None

        state, _ = jax.lax.scan(step, state, sel_chunk)
        return state

    if shards > 1:
        span_body = shard_map(
            span_body, mesh=mesh,
            in_specs=(state_spec, chunk_spec, sspec, sspec, sspec, sspec),
            out_specs=state_spec, check_rep=False)

    @jax.jit
    def run_span(state, sel_chunk, k_active):
        return span_body(state, sel_chunk, k_active, *data_args)

    return run_span


def span_boundaries(rounds: int, eval_every: int) -> list[int]:
    """Eval checkpoints of the classic loop: every ``eval_every`` rounds
    plus the final round — spans run scan-fused between them.

    ``eval_every > rounds`` means a single span ending at the final round;
    non-positive values are rejected (they used to silently produce a
    bogus round-0 boundary / negative stops).
    """
    if rounds < 1:
        raise ValueError(f"rounds must be >= 1, got {rounds}")
    if eval_every < 1:
        raise ValueError(f"eval_every must be >= 1, got {eval_every}")
    stops = list(range(eval_every, rounds + 1, eval_every))
    if not stops or stops[-1] != rounds:
        stops.append(rounds)
    return stops
