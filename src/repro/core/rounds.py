"""Round executors for the vectorized-client federation.

Three ways to run the same round semantics, all built from one traceable
round body so they are numerically interchangeable:

* :func:`make_round_fn` — one jitted round (the classic per-round API);
* :func:`make_span_runner` — ``jax.lax.scan`` over a stacked (C, N) chunk
  of plan masks, so an eval-free span of C rounds executes as ONE jitted
  program instead of C separate dispatches (the dominant cost at small
  model sizes is host→device round-trips, not FLOPs — see
  ``benchmarks/round_loop.py``);
* ``fused=True`` — route the train-or-estimate + masked-mean + global
  update through the single-HBM-pass Pallas kernel
  (:func:`repro.kernels.ops.cc_delta_update`) on flat (N, P) parameters;
  interpret mode on CPU, Mosaic on TPU. Only strategies whose estimate is
  a verbatim Δ replay (``fused_capable``) qualify.

Strategy semantics themselves live in :mod:`repro.core.strategies`; this
module never branches on a strategy name.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.strategies import (RoundCtx, Strategy, get_strategy,
                                   masked_select)
from repro.data.federated import FederatedData
from repro.models.simple import Classifier, xent_loss
from repro.utils.pytree import (
    PyTree,
    tree_add,
    tree_broadcast_clients,
    tree_ravel,
    tree_ravel_clients,
    tree_sub,
    tree_zeros_like,
)

_FUSED_PAD = 512               # flat params padded to a tile-friendly multiple


@dataclass(frozen=True)
class FedConfig:
    strategy: str = "cc"
    variant: str = "client"        # Alg.1 client | Alg.2 server | Alg.3 mixed
    local_steps: int = 5           # K
    batch_size: int = 32
    lr: float = 0.05
    tau: int = 100                 # CC-FedAvg(c) switch round
    seed: int = 0

    def __post_init__(self):
        get_strategy(self.strategy)    # raises ValueError on unknown names

    def resolve(self) -> Strategy:
        return get_strategy(self.strategy)


def _local_train(model: Classifier, params, key, cx, cy, size,
                 k_steps: int, k_active, batch_size: int, lr: float):
    """K local SGD steps on one client (Eq. 2). ``k_active`` ≤ k_steps masks
    steps off for FedNova's reduced-iteration budget."""
    def step(carry, k):
        p, key = carry
        key, sk = jax.random.split(key)
        idx = jax.random.randint(sk, (batch_size,), 0, 2 ** 30) % size
        g = jax.grad(lambda q: xent_loss(model, q, cx[idx], cy[idx]))(p)
        new = jax.tree.map(lambda a, b: a - lr * b, p, g)
        do = k < k_active
        p = jax.tree.map(
            lambda a, b: jnp.where(do, a, b), new, p)
        return (p, key), None

    (params, _), _ = jax.lax.scan(step, (params, key),
                                  jnp.arange(k_steps))
    return params


def init_fed_state(rng, model: Classifier, n_clients: int) -> PyTree:
    params = model.init(rng)
    zeros = tree_broadcast_clients(tree_zeros_like(params), n_clients)
    return {
        "params": params,
        "deltas": zeros,                       # Δ_{t−1}^i  (Strategy 3)
        "prev_local": tree_broadcast_clients(params, n_clients),
        "trained_ever": jnp.zeros((n_clients,), bool),
        "round": jnp.zeros((), jnp.int32),
        "key": rng,
    }


def _train_all_clients(model: Classifier, data: FederatedData,
                       fed: FedConfig, state: PyTree, k_active):
    """Split the round key and vmap local training over every client."""
    n = data.n_clients
    key, *keys = jax.random.split(state["key"], n + 1)
    keys = jnp.stack(keys)
    broadcast = tree_broadcast_clients(state["params"], n)
    local = jax.vmap(
        lambda p, k, cx, cy, sz, ka: _local_train(
            model, p, k, cx, cy, sz, fed.local_steps, ka,
            fed.batch_size, fed.lr)
    )(broadcast, keys, data.x, data.y, data.sizes, k_active)
    return key, broadcast, local


def make_round_body(model: Classifier, data: FederatedData, fed: FedConfig,
                    *, fused: bool = False):
    """The traceable single-round transition ``(state, sel, train, k) →
    state`` that every executor (jit, scan, fused) wraps."""
    strategy = fed.resolve()
    if fused:
        return _make_fused_round_body(model, data, fed, strategy)

    def round_body(state, sel_mask, train_mask, k_active):
        key, broadcast, local = _train_all_clients(model, data, fed,
                                                   state, k_active)
        trained_delta = tree_sub(local, broadcast)

        # ---- estimation for skipped clients --------------------------
        stale_delta = tree_sub(state["prev_local"], broadcast)
        stale_delta = masked_select(state["trained_ever"], stale_delta,
                                    tree_zeros_like(stale_delta))
        ctx = RoundCtx(sel_mask=sel_mask, train_mask=train_mask,
                       k_active=k_active, round=state["round"], tau=fed.tau,
                       stale_delta=stale_delta, trained_delta=trained_delta)
        est = strategy.estimate(state, ctx)
        delta_i = masked_select(train_mask, trained_delta, est)

        # ---- aggregation (Eq. 3 over Δ) -------------------------------
        aggf = strategy.agg_mask(ctx).astype(jnp.float32)
        delta = strategy.aggregate(delta_i, aggf, ctx)
        new_params = tree_add(state["params"], delta)

        # ---- history updates ------------------------------------------
        upd = sel_mask & train_mask
        deltas, prev_local = strategy.update_history(
            state, ctx, trained_delta, local, est)
        return {
            "params": new_params,
            "deltas": deltas,
            "prev_local": prev_local,
            "trained_ever": state["trained_ever"] | upd,
            "round": state["round"] + 1,
            "key": key,
        }

    return round_body


def _make_fused_round_body(model: Classifier, data: FederatedData,
                           fed: FedConfig, strategy: Strategy):
    """Route the round through the fused Pallas kernel: one HBM pass
    computes Δ_t^i = train ? (x_K^i − x_t) : Δ_{t−1}^i, the masked mean and
    the global update over flat (N, P) parameters."""
    from repro.kernels import ops

    if not strategy.fused_capable:
        raise ValueError(
            f"strategy {strategy.name!r} is not fused-capable (the kernel "
            "replays stored Δ verbatim); use the tree-ops path")

    def round_body(state, sel_mask, train_mask, k_active):
        key, _, local = _train_all_clients(model, data, fed, state, k_active)
        flat_local, unravel_clients = tree_ravel_clients(local)
        flat_deltas, _ = tree_ravel_clients(state["deltas"])
        flat_global, unravel = tree_ravel(state["params"])
        p = flat_global.shape[0]
        pad = (-p) % _FUSED_PAD
        if pad:                     # zero-pad: padded lanes stay exactly 0
            flat_local = jnp.pad(flat_local, ((0, 0), (0, pad)))
            flat_deltas = jnp.pad(flat_deltas, ((0, 0), (0, pad)))
            flat_global = jnp.pad(flat_global, (0, pad))
        # history semantics: stored Δ only advances for sel∧train clients,
        # so that (not bare train_mask) is the kernel's train input
        upd = sel_mask & train_mask
        new_deltas, new_global = ops.cc_delta_update(
            flat_local, flat_deltas, flat_global,
            upd.astype(jnp.float32), sel_mask.astype(jnp.float32),
            block=min(65536, p + pad))
        prev_local = masked_select(upd, local, state["prev_local"])
        return {
            "params": unravel(new_global[:p]),
            "deltas": unravel_clients(new_deltas[:, :p]),
            "prev_local": prev_local,
            "trained_ever": state["trained_ever"] | upd,
            "round": state["round"] + 1,
            "key": key,
        }

    return round_body


def make_round_fn(model: Classifier, data: FederatedData, fed: FedConfig,
                  *, fused: bool = False):
    """One jitted round: ``round_fn(state, sel_mask, train_mask, k_active)``."""
    return jax.jit(make_round_body(model, data, fed, fused=fused))


def make_span_runner(model: Classifier, data: FederatedData, fed: FedConfig,
                     *, fused: bool = False):
    """Scan executor: ``run_span(state, sel_chunk, train_chunk, k_active)``
    advances the federation over a (C, N) chunk of plan masks as one jitted
    ``lax.scan`` — no host sync until the span ends. Recompiles once per
    distinct chunk length C (eval cadence makes C constant in practice)."""
    round_body = make_round_body(model, data, fed, fused=fused)

    @jax.jit
    def run_span(state, sel_chunk, train_chunk, k_active):
        def step(st, masks):
            sel, train = masks
            return round_body(st, sel, train, k_active), None

        state, _ = jax.lax.scan(step, state, (sel_chunk, train_chunk))
        return state

    return run_span


def span_boundaries(rounds: int, eval_every: int) -> list[int]:
    """Eval checkpoints of the classic loop: every ``eval_every`` rounds
    plus the final round — spans run scan-fused between them."""
    stops = list(range(eval_every, rounds + 1, max(1, eval_every)))
    if not stops or stops[-1] != rounds:
        stops.append(rounds)
    return stops
