"""Sharded, optionally int8-quantized per-client Δ-history store.

Every synchronous executor carries the full (N, P) f32 Δ history in the
round state — 4·N·P bytes, the term that caps the simulated federation
size long before compute does. This module factors that carry into a
:class:`HistoryStore` with two interchangeable layouts:

* ``kind="dense"`` — the plain f32 matrix (the seed-era carry, exact);
* ``kind="int8"`` — per-row symmetric int8 payload + one f32 scale per
  client (the layout of :mod:`repro.kernels.cc_delta_update_q8`,
  produced/consumed via :func:`repro.core.compress.quantize_rows`):
  ``N·P + 4·N`` bytes, ≈ 25% of dense f32 at P ≫ 1 — N = 10⁵ clients at
  P = 1024 is ~102 MB instead of ~410 MB.

Rows shard over the ``("clients",)`` mesh axis (:meth:`HistoryStore.
shard`) and are gathered/dequantized only for the active cohort
(:meth:`read` / the fused ops :func:`repro.kernels.ops.q8_gather_rows` /
``q8_scatter_rows``), so CC-FedAvg estimation replay never materializes
O(N·P) f32. The async executor (:mod:`repro.core.async_rounds`) carries
its Δ history through this store; ``benchmarks/async_throughput.py``
measures both layouts up to N = 10⁵.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core.compress import quantize_rows

#: supported store layouts
STORE_KINDS = ("dense", "int8")

#: row width padded to a tile-friendly multiple (matches the fused
#: executors' ``_FUSED_PAD`` so int8 carries are layout-compatible)
TILE = 512


def padded_width(p: int, tile: int = TILE) -> int:
    """Flat parameter count rounded up to the store's tile multiple."""
    return p + (-p) % tile


@dataclass(frozen=True)
class HistoryStore:
    """One federation's Δ-history rows: layout, init, gather/scatter."""

    n_clients: int
    width: int                 # padded flat parameter count P
    kind: str = "dense"
    #: pre-padding flat parameter count; ``None`` means width itself. Set by
    #: :meth:`for_flat` so round bodies can hand the store un-padded rows
    #: (:meth:`pad_rows`) and read back exactly the logical columns
    #: (:meth:`read_logical`) — e.g. the O(r·d) LoRA adapter subtree, whose
    #: flat width is almost never a TILE multiple.
    logical_width: int | None = None

    def __post_init__(self):
        if self.kind not in STORE_KINDS:
            raise ValueError(f"history store kind must be one of "
                             f"{STORE_KINDS}, got {self.kind!r}")
        if self.n_clients < 1:
            raise ValueError(f"n_clients must be >= 1, got {self.n_clients}")
        if self.width < 1:
            raise ValueError(f"width must be >= 1, got {self.width}")
        lw = self.logical_width
        if lw is not None and not 1 <= lw <= self.width:
            raise ValueError(f"logical_width must be in [1, width="
                             f"{self.width}], got {lw}")

    @classmethod
    def for_flat(cls, n_clients: int, p: int, kind: str = "dense",
                 tile: int = TILE) -> "HistoryStore":
        """Store for an un-padded flat parameter count ``p`` — the width is
        tile-padded, ``p`` is remembered as the logical width."""
        return cls(n_clients, padded_width(p, tile), kind, logical_width=p)

    @property
    def p_logical(self) -> int:
        return self.width if self.logical_width is None else \
            self.logical_width

    def pad_rows(self, rows: jnp.ndarray) -> jnp.ndarray:
        """Zero-pad (..., p_logical) rows to the store width. The padded
        tail quantizes to payload 0 under the per-row symmetric scheme, so
        it stays exactly zero through every round trip (pinned in
        ``tests/test_history_store_padding.py``)."""
        pad = self.width - rows.shape[-1]
        if pad < 0:
            raise ValueError(f"rows wider ({rows.shape[-1]}) than the store "
                             f"({self.width})")
        if pad == 0:
            return rows
        return jnp.pad(rows, ((0, 0),) * (rows.ndim - 1) + ((0, pad),))

    # ---- carry lifecycle ------------------------------------------------

    def init(self) -> dict:
        """Zero history in this store's carry layout. The int8 carry is
        exactly ``quantize_rows(zeros)`` — payload 0, clamp-floor scales —
        so a fresh store round-trips a checkpoint bit-wise."""
        if self.kind == "dense":
            return {"rows": jnp.zeros((self.n_clients, self.width),
                                      jnp.float32)}
        payload, scales = quantize_rows(
            jnp.zeros((self.n_clients, self.width)))
        return {"payload": payload, "scales": scales}

    def like(self, carry: dict) -> None:
        """Validate that ``carry`` matches this store's layout."""
        want = {"rows"} if self.kind == "dense" else {"payload", "scales"}
        if set(carry) != want:
            raise ValueError(f"{self.kind} store carry needs keys {want}, "
                             f"got {sorted(carry)}")

    # ---- row access -----------------------------------------------------

    def read(self, carry: dict, idx=None) -> jnp.ndarray:
        """f32 rows — the full matrix, or only the cohort ``idx`` (the
        int8 path gathers quantized rows first, so the f32 intermediate is
        (M, P), never (N, P))."""
        if self.kind == "dense":
            rows = carry["rows"]
            return rows if idx is None else jnp.take(rows, idx, axis=0)
        if idx is None:
            from repro.core.compress import dequantize_rows
            return dequantize_rows(carry["payload"], carry["scales"])
        from repro.kernels.ops import q8_gather_rows
        return q8_gather_rows(carry["payload"], carry["scales"], idx)

    def read_logical(self, carry: dict, idx=None) -> jnp.ndarray:
        """:meth:`read` cropped to the logical (pre-padding) columns."""
        return self.read(carry, idx)[:, :self.p_logical]

    def write(self, carry: dict, mask, rows: jnp.ndarray) -> dict:
        """Masked full-N write: rows where ``mask`` take the new values
        (requantized under int8); unmasked rows keep their stored bits
        verbatim — unchanged clients never drift through a round trip."""
        if self.kind == "dense":
            return {"rows": jnp.where(mask[:, None], rows, carry["rows"])}
        q_payload, q_scales = quantize_rows(rows)
        return {"payload": jnp.where(mask[:, None], q_payload,
                                     carry["payload"]),
                "scales": jnp.where(mask, q_scales, carry["scales"])}

    def scatter(self, carry: dict, idx, rows: jnp.ndarray) -> dict:
        """Cohort write: the (M, P) updated rows land at ``idx``."""
        if self.kind == "dense":
            return {"rows": carry["rows"].at[idx].set(rows)}
        from repro.kernels.ops import q8_scatter_rows
        payload, scales = q8_scatter_rows(carry["payload"], carry["scales"],
                                          idx, rows)
        return {"payload": payload, "scales": scales}

    # ---- memory accounting + placement ----------------------------------

    def nbytes(self) -> int:
        """Bytes the carry holds (the history-store memory math of the
        README: dense 4·N·P vs int8 N·P + 4·N)."""
        if self.kind == "dense":
            return 4 * self.n_clients * self.width
        return self.n_clients * self.width + 4 * self.n_clients

    @staticmethod
    def carry_bytes(carry: dict) -> int:
        """Live bytes of a materialized carry (any layout)."""
        return int(sum(np.prod(v.shape) * v.dtype.itemsize
                       for v in carry.values()))

    def shard(self, carry: dict, mesh=None) -> dict:
        """Place the carry with rows split over the ``("clients",)`` mesh
        axis (scales replicated-free too — every leaf's leading dim is N).
        Defaults to the largest device count dividing N."""
        import jax
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.core.rounds import CLIENT_AXIS
        from repro.launch.mesh import best_client_shards, make_client_mesh

        if mesh is None:
            mesh = make_client_mesh(best_client_shards(self.n_clients))
        if CLIENT_AXIS not in mesh.axis_names:
            raise ValueError(f"mesh must carry a {CLIENT_AXIS!r} axis, got "
                             f"{mesh.axis_names}")
        shards = dict(zip(mesh.axis_names, mesh.devices.shape))[CLIENT_AXIS]
        if self.n_clients % shards:
            raise ValueError(
                f"{self.n_clients} client rows must divide evenly over the "
                f"{shards}-way {CLIENT_AXIS!r} mesh axis")
        sh = NamedSharding(mesh, PartitionSpec(CLIENT_AXIS))
        return {k: jax.device_put(v, sh) for k, v in carry.items()}
