"""CC-FedAvg core: the paper's contribution as a composable JAX module.

* :mod:`repro.core.engine`    — vectorized-client federation (Alg. 1/2/3,
  Strategies 1/2/3, CC(c), FedNova, FedAvg full/dropout).
* :mod:`repro.core.schedules` — round-robin / ad-hoc / sync / dropout plans.
* :mod:`repro.core.podlevel`  — pods-as-clients CC-FedAvg for LLM-scale
  training on the multi-pod mesh.
"""
from repro.core.engine import (  # noqa: F401
    FedConfig,
    STRATEGIES,
    init_fed_state,
    make_round_fn,
    run_federated,
    evaluate,
    cost_report,
)
from repro.core.schedules import Plan, make_plan, fednova_local_steps  # noqa: F401
