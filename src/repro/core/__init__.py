"""CC-FedAvg core: the paper's contribution as a composable JAX module.

* :mod:`repro.core.strategies` — pluggable estimation-strategy registry
  (paper §III names + extensions; register new schemes by name).
* :mod:`repro.core.rounds`     — round executors: jitted round, scan span
  runner, fused Pallas fast path.
* :mod:`repro.core.engine`     — host-side driver (Alg. 1/2/3), evaluation,
  Appendix-A cost accounting.
* :mod:`repro.core.budget`     — runtime budget policies: traced in-loop
  train/estimate decisions over simulated device state
  (:mod:`repro.system.devices`); legacy plans replay bit-for-bit through
  ``PrecompiledPolicy``.
* :mod:`repro.core.hierarchy`  — two-tier client→edge→server topologies
  (``EdgeTopology``): edges aggregate their members for ``edge_period``
  rounds before the server averages the edge models; collapses to flat
  FedAvg bit-for-bit with one edge or ``edge_period=1``.
* :mod:`repro.core.schedules`  — round-robin / ad-hoc / sync / dropout
  plans (now policy *inputs*, no longer engine inputs).
* :mod:`repro.core.podlevel`   — pods-as-clients CC-FedAvg for LLM-scale
  training on the multi-pod mesh.
"""
from repro.core.engine import (  # noqa: F401
    FedConfig,
    STRATEGIES,
    init_fed_state,
    make_round_fn,
    run_federated,
    evaluate,
    cost_report,
)
from repro.core.budget import (  # noqa: F401
    AdaptiveProbability,
    BudgetCtx,
    BudgetPolicy,
    DeadlineAware,
    EnergyAware,
    PrecompiledPolicy,
    available_policies,
    make_policy,
)
from repro.core.hierarchy import (  # noqa: F401
    EdgeTopology,
    edge_mass,
    edge_masked_means,
    edge_weighted_mean,
)
from repro.core.rounds import (  # noqa: F401
    make_hierarchical_span_runner,
    make_policy_round_fn,
    make_policy_span_runner,
    make_round_body,
    make_sharded_span_runner,
    make_span_runner,
)
from repro.core.strategies import (  # noqa: F401
    Strategy,
    available_strategies,
    get_strategy,
    register,
)
from repro.core.schedules import Plan, make_plan, fednova_local_steps  # noqa: F401
