"""Runtime budget policies: traced in-loop train/estimate decisions.

The paper's premise is that clients "determine whether to perform
traditional local training or model estimation *in each round* based on
their current computational budgets" (§VI-A; Fig. 1b ad-hoc mode). The
seed-era engine precompiled every decision into a static (T, N) plan; this
module moves the decision *inside* the traced round loop, where it can
react to the simulated device runtime (:mod:`repro.system.devices`):
energy reserves, background load, deadlines, duty cycles.

A policy is two pure-JAX hooks:

* ``init_rows(n_clients)`` — per-client policy-state rows (a dict of (N,)
  arrays; may be empty). Rows ride in the round carry next to the Δ
  history, are gathered/scattered per cohort by the sharded executor, and
  are checkpointed with the rest of the federated state — resume is
  bit-identical.
* ``decide(rows, ctx)`` → ``(train_mask, new_rows)`` — the round-t
  decision, traced under ``jit``/``scan``/``shard_map``. ``ctx`` is a
  :class:`BudgetCtx` of per-client views (device state, profile rows,
  absolute client ids, selection mask, duty mask).

Every legacy schedule kind survives as a special case:
:class:`PrecompiledPolicy` replays a :func:`repro.core.schedules.make_plan`
training table bit-for-bit (pinned per kind × executor in
``tests/test_executor_matrix.py``), so ``make_plan`` is now only a *policy
input*, not an engine input. Native runtime policies — EnergyAware,
DeadlineAware, AdaptiveProbability — express the adaptive/energy/deadline
workloads the resource-constrained-FL surveys (arXiv:2307.09182,
arXiv:2002.10610) catalogue.

Stochastic policies draw stateless randomness keyed on (seed, round,
client id) via ``fold_in`` — identical under resume, cohort sharding and
every executor, the same contract the device simulator follows.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.system.devices import device_awake, stateless_uniform


@dataclass(frozen=True)
class BudgetCtx:
    """Everything a policy may condition on in one round. All array members
    are per-client rows of the *decision cohort* (the full federation, or a
    gathered shard under the sharded executor)."""

    round: jax.Array        # () int32 — current round t
    client_ids: jax.Array   # (M,) int32 — absolute client ids
    sel_mask: jax.Array     # (M,) bool — server selection S_t
    device: dict            # {"energy", "load"} per-client device state
    profile: dict           # DeviceProfile.rows() (gathered)
    awake: jax.Array        # (M,) bool — duty-cycle mask for round t
    seed: int               # static stream id for stateless randomness
    #: (M,) int32 edge-aggregator id per client under a two-tier topology
    #: (:mod:`repro.core.hierarchy`); None in flat runs. Lets a policy
    #: condition on which gateway a client hangs off (heterogeneous edges).
    edge_id: jax.Array | None = None


@dataclass(frozen=True)
class BudgetPolicy:
    """Base policy: hooks only; subclasses implement ``decide``."""

    #: registry key; subclasses override via their ``name`` field default
    name: str = ""

    def init_rows(self, n_clients: int) -> dict:
        """Per-client policy-state rows. Default: stateless (empty dict —
        still a valid carry/checkpoint/gather target)."""
        return {}

    def decide(self, rows: dict, ctx: BudgetCtx
               ) -> tuple[jax.Array, dict]:
        """Return (train_mask, new_rows). ``train_mask`` is ANDed with the
        selection mask by the executor, so a policy never needs to."""
        raise NotImplementedError


# ---------------------------------------------------------------------------
# legacy schedules as a policy
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PrecompiledPolicy(BudgetPolicy):
    """Replay a static (T, N) training table — every legacy schedule kind
    (`round_robin`/`adhoc`/`sync`/`dropout`/`full`) rides through here
    bit-for-bit. The table is closed over as a trace-time constant; round
    ``t`` reads row ``t`` gathered at the cohort's absolute client ids."""

    name: str = "precompiled"
    table: jax.Array | None = None     # (T, N) bool

    def __post_init__(self):
        if self.table is None:
            raise ValueError("PrecompiledPolicy needs a (T, N) training "
                             "table (e.g. make_plan(...).training)")
        object.__setattr__(self, "table", jnp.asarray(self.table, bool))

    @classmethod
    def from_plan(cls, plan) -> "PrecompiledPolicy":
        return cls(table=jnp.asarray(plan.training))

    def decide(self, rows, ctx):
        return self.table[ctx.round][ctx.client_ids], rows


# ---------------------------------------------------------------------------
# native runtime policies
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class EnergyAware(BudgetPolicy):
    """Train iff the reserve covers this round's training cost (plus an
    optional safety margin), and the device is awake. With the ``"budget"``
    profile (harvest = p_i · cost) the sustainable training fraction is
    ≈ p_i — the energy-ledger translation of the paper's budgets."""

    name: str = "energy"
    reserve_frac: float = 0.0   # keep this × train_cost in reserve

    def decide(self, rows, ctx):
        need = ctx.profile["train_cost"] * (1.0 + self.reserve_frac)
        return (ctx.device["energy"] >= need) & ctx.awake, rows


@dataclass(frozen=True)
class DeadlineAware(BudgetPolicy):
    """Train iff the *estimated round time* meets the server deadline.

    Round time for client i is ``1 / (flops_rate_i · (1 − load_i))`` in
    units of the nominal unloaded round; a slow or heavily-loaded device
    would straggle past the deadline, so it estimates instead (the
    straggler-avoidance workload of arXiv:2002.10610 §IV)."""

    name: str = "deadline"
    deadline: float = 2.0       # × nominal round time

    def __post_init__(self):
        if self.deadline <= 0:
            raise ValueError(f"deadline must be > 0, got {self.deadline}")

    def decide(self, rows, ctx):
        speed = ctx.profile["flops_rate"] * (1.0 - ctx.device["load"])
        round_time = 1.0 / jnp.maximum(speed, 1e-6)
        return (round_time <= self.deadline) & ctx.awake, rows


@dataclass(frozen=True)
class AdaptiveProbability(BudgetPolicy):
    """Ad-hoc mode with feedback: train with probability p_i, nudged by how
    far the client's realized training fraction has drifted from p_i.

    Rows track per-client (trained, seen) counts; the effective probability
    is ``clip(p_i + eta · (p_i − trained/seen), 0, 1)`` — a client that
    fell behind its budget (e.g. it slept through duty-off rounds) catches
    up, one that overspent backs off. ``eta = 0`` recovers the paper's
    memoryless ad-hoc coin flips exactly."""

    name: str = "adaptive"
    eta: float = 0.5

    def __post_init__(self):
        if self.eta < 0:
            raise ValueError(f"eta must be >= 0, got {self.eta}")

    def init_rows(self, n_clients):
        return {"trained": jnp.zeros((n_clients,), jnp.float32),
                "seen": jnp.zeros((n_clients,), jnp.float32)}

    def decide(self, rows, ctx):
        p = ctx.profile["budget"]
        frac = rows["trained"] / jnp.maximum(rows["seen"], 1.0)
        p_eff = jnp.clip(p + self.eta * (p - frac), 0.0, 1.0)
        u = stateless_uniform(ctx.seed, ctx.round, ctx.client_ids)
        mask = (u < p_eff) & ctx.awake
        counted = (ctx.sel_mask & mask).astype(jnp.float32)
        new_rows = {"trained": rows["trained"] + counted,
                    "seen": rows["seen"] + ctx.sel_mask.astype(jnp.float32)}
        return mask, new_rows


# ---------------------------------------------------------------------------
# registry / factory
# ---------------------------------------------------------------------------

POLICY_KINDS = ("precompiled", "energy", "deadline", "adaptive")


def available_policies() -> tuple[str, ...]:
    return POLICY_KINDS


def make_policy(kind: str, *, plan=None, deadline: float = 2.0,
                eta: float = 0.5, reserve_frac: float = 0.0) -> BudgetPolicy:
    """Build a policy by kind. ``"precompiled"`` requires a legacy
    :class:`~repro.core.schedules.Plan` (its training table is replayed
    bit-for-bit); the runtime kinds take their scalar knobs."""
    if kind == "precompiled":
        if plan is None:
            raise ValueError("policy='precompiled' needs a plan "
                             "(make_plan output) to replay")
        return PrecompiledPolicy.from_plan(plan)
    if kind == "energy":
        return EnergyAware(reserve_frac=reserve_frac)
    if kind == "deadline":
        return DeadlineAware(deadline=deadline)
    if kind == "adaptive":
        return AdaptiveProbability(eta=eta)
    raise ValueError(f"unknown policy kind {kind!r}; available: "
                     f"{', '.join(POLICY_KINDS)}")


def budget_ctx(rows_profile: dict, dev: dict, rnd, client_ids: jax.Array,
               sel_mask: jax.Array, seed: int,
               edge_ids: jax.Array | None = None) -> BudgetCtx:
    """Assemble the per-round decision context (shared by all executors)."""
    return BudgetCtx(round=rnd, client_ids=client_ids, sel_mask=sel_mask,
                     device=dev, profile=rows_profile,
                     awake=device_awake(rows_profile, rnd), seed=seed,
                     edge_id=edge_ids)
