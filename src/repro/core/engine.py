"""Vectorized-client federated engine — the paper's Algorithm 1/2/3 plus all
baselines, with every client's state stacked along a leading axis so one
jitted round function executes the whole federation (vmap local training,
mask-based skip/estimate decisions, masked-mean aggregation).

Strategies (paper §III):
  * ``fedavg``  — FedAvg(full): everyone trains (plans decide selection).
  * ``dropout`` — FedAvg under an energy quota; client leaves when spent.
  * ``s1``      — skip rounds, server aggregates only received models.
  * ``s2``      — skip rounds, client returns its stale local model.
  * ``cc``      — CC-FedAvg (Strategy 3): replay Δ_{t−1}^i.
  * ``ccc``     — CC-FedAvg(c) (Eq. 4): Strategy 3 before round τ, then s2.
  * ``fednova`` — budget spent as fewer local iterations each round, with
                  FedNova's normalized aggregation [32].

Algorithm variants (Appendix A) are numerically identical by construction;
``variant`` ∈ {client, server, mixed} drives the storage/communication cost
accounting (:func:`cost_report`) and which side of the simulation holds Δ.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedules import Plan, fednova_local_steps
from repro.data.federated import FederatedData
from repro.models.simple import Classifier, xent_loss
from repro.utils.logging import MetricLogger, log
from repro.utils.pytree import (
    PyTree,
    tree_broadcast_clients,
    tree_masked_mean,
    tree_sub,
    tree_add,
    tree_zeros_like,
)

STRATEGIES = ("fedavg", "dropout", "s1", "s2", "cc", "ccc", "fednova")


@dataclass(frozen=True)
class FedConfig:
    strategy: str = "cc"
    variant: str = "client"        # Alg.1 client | Alg.2 server | Alg.3 mixed
    local_steps: int = 5           # K
    batch_size: int = 32
    lr: float = 0.05
    tau: int = 100                 # CC-FedAvg(c) switch round
    seed: int = 0

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}")


def _mask_tree(mask: jax.Array, a: PyTree, b: PyTree) -> PyTree:
    """Leafwise select with (N,) client mask broadcast to (N, ...) leaves."""
    def sel(x, y):
        m = mask.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.where(m, x, y)
    return jax.tree.map(sel, a, b)


def _local_train(model: Classifier, params, key, cx, cy, size,
                 k_steps: int, k_active, batch_size: int, lr: float):
    """K local SGD steps on one client (Eq. 2). ``k_active`` ≤ k_steps masks
    steps off for FedNova's reduced-iteration budget."""
    def step(carry, k):
        p, key = carry
        key, sk = jax.random.split(key)
        idx = jax.random.randint(sk, (batch_size,), 0, 2 ** 30) % size
        g = jax.grad(lambda q: xent_loss(model, q, cx[idx], cy[idx]))(p)
        new = jax.tree.map(lambda a, b: a - lr * b, p, g)
        do = k < k_active
        p = jax.tree.map(
            lambda a, b: jnp.where(do, a, b), new, p)
        return (p, key), None

    (params, _), _ = jax.lax.scan(step, (params, key),
                                  jnp.arange(k_steps))
    return params


def init_fed_state(rng, model: Classifier, n_clients: int) -> PyTree:
    params = model.init(rng)
    zeros = tree_broadcast_clients(tree_zeros_like(params), n_clients)
    return {
        "params": params,
        "deltas": zeros,                       # Δ_{t−1}^i  (Strategy 3)
        "prev_local": tree_broadcast_clients(params, n_clients),
        "trained_ever": jnp.zeros((n_clients,), bool),
        "round": jnp.zeros((), jnp.int32),
        "key": rng,
    }


def make_round_fn(model: Classifier, data: FederatedData, fed: FedConfig):
    n = data.n_clients

    @functools.partial(jax.jit, static_argnames=())
    def round_fn(state, sel_mask, train_mask, k_active):
        key, *keys = jax.random.split(state["key"], n + 1)
        keys = jnp.stack(keys)
        broadcast = tree_broadcast_clients(state["params"], n)
        local = jax.vmap(
            lambda p, k, cx, cy, sz, ka: _local_train(
                model, p, k, cx, cy, sz, fed.local_steps, ka,
                fed.batch_size, fed.lr)
        )(broadcast, keys, data.x, data.y, data.sizes, k_active)
        trained_delta = tree_sub(local, broadcast)

        # ---- estimation for skipped clients --------------------------
        stale_delta = tree_sub(state["prev_local"], broadcast)
        stale_delta = _mask_tree(state["trained_ever"], stale_delta,
                                 tree_zeros_like(stale_delta))
        if fed.strategy == "cc":
            est = state["deltas"]
        elif fed.strategy == "ccc":
            use_s3 = state["round"] < fed.tau
            est = jax.tree.map(
                lambda a, b: jnp.where(use_s3, a, b),
                state["deltas"], stale_delta)
        elif fed.strategy == "s2":
            est = stale_delta
        else:  # s1 / fedavg / dropout / fednova never aggregate estimates
            est = tree_zeros_like(trained_delta)

        delta_i = _mask_tree(train_mask, trained_delta, est)

        # ---- aggregation (Eq. 3 over Δ) -------------------------------
        if fed.strategy in ("s1", "fedavg", "dropout", "fednova"):
            agg_mask = sel_mask & train_mask
        else:
            agg_mask = sel_mask
        aggf = agg_mask.astype(jnp.float32)
        if fed.strategy == "fednova":
            ka = jnp.maximum(k_active.astype(jnp.float32), 1.0)
            d_norm = jax.tree.map(
                lambda x: x / ka.reshape((-1,) + (1,) * (x.ndim - 1)), delta_i)
            coeff = jnp.sum(aggf * ka) / jnp.maximum(jnp.sum(aggf), 1e-9)
            delta = jax.tree.map(
                lambda x: coeff * x, tree_masked_mean(d_norm, aggf))
        else:
            delta = tree_masked_mean(delta_i, aggf)
        new_params = tree_add(state["params"], delta)

        # ---- history updates ------------------------------------------
        upd = sel_mask & train_mask
        deltas = _mask_tree(upd, trained_delta, state["deltas"])
        prev_local = _mask_tree(upd, local, state["prev_local"])
        return {
            "params": new_params,
            "deltas": deltas,
            "prev_local": prev_local,
            "trained_ever": state["trained_ever"] | upd,
            "round": state["round"] + 1,
            "key": key,
        }

    return round_fn


def make_probe_fn(model: Classifier, data: FederatedData, fed: FedConfig,
                  client: int):
    """Fig. 2 instrumentation: distance between the estimated local models
    (Strategies 2/3) and the true locally-trained model for one client."""
    from repro.utils.pytree import tree_euclidean, tree_cosine

    @jax.jit
    def probe(state, key):
        cx = data.x[client]
        cy = data.y[client]
        sz = data.sizes[client]
        true_local = _local_train(model, state["params"], key, cx, cy, sz,
                                  fed.local_steps,
                                  jnp.asarray(fed.local_steps),
                                  fed.batch_size, fed.lr)
        true_delta = tree_sub(true_local, state["params"])
        est3 = jax.tree.map(lambda d: d[client], state["deltas"])
        prev = jax.tree.map(lambda p: p[client], state["prev_local"])
        est2_model = prev
        est3_model = tree_add(state["params"], est3)
        s2_delta = tree_sub(prev, state["params"])
        return {
            "euclid_s2": tree_euclidean(true_local, est2_model),
            "euclid_s3": tree_euclidean(true_local, est3_model),
            "cos_s2": tree_cosine(true_delta, s2_delta),
            "cos_s3": tree_cosine(true_delta, est3),
        }

    return probe


def evaluate(model: Classifier, params, x_test, y_test,
             batch: int = 512) -> float:
    n = x_test.shape[0]
    correct = 0
    apply = jax.jit(model.apply)
    for i in range(0, n, batch):
        logits = apply(params, x_test[i: i + batch])
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y_test[i: i + batch]))
    return correct / n


def run_federated(model: Classifier, data: FederatedData, fed: FedConfig,
                  plan: Plan, *, x_test, y_test, eval_every: int = 10,
                  probe_client: int | None = None,
                  verbose: bool = False) -> tuple[PyTree, MetricLogger]:
    """Run the whole federation per ``plan``; returns final state + metrics."""
    rng = jax.random.PRNGKey(fed.seed)
    state = init_fed_state(rng, model, data.n_clients)
    round_fn = make_round_fn(model, data, fed)
    probe_fn = (make_probe_fn(model, data, fed, probe_client)
                if probe_client is not None else None)
    if fed.strategy == "fednova":
        k_active_all = fednova_local_steps(plan.p, fed.local_steps)
    else:
        k_active_all = np.full(data.n_clients, fed.local_steps, np.int32)
    k_active = jnp.asarray(k_active_all)
    metrics = MetricLogger()
    for t in range(plan.rounds):
        sel = jnp.asarray(plan.selection[t])
        train = jnp.asarray(plan.training[t])
        if probe_fn is not None and t > 0:
            pk = jax.random.fold_in(state["key"], 1234)
            pm = probe_fn(state, pk)
            metrics.record(t, **{k: float(v) for k, v in pm.items()})
        state = round_fn(state, sel, train, k_active)
        if (t + 1) % eval_every == 0 or t == plan.rounds - 1:
            acc = evaluate(model, state["params"], x_test, y_test)
            metrics.record(t + 1, test_acc=acc)
            if verbose:
                log(f"round {t + 1}/{plan.rounds}", strategy=fed.strategy,
                    acc=f"{acc:.4f}")
    return state, metrics


def cost_report(plan: Plan, model_bytes: int, variant: str = "client",
                mixed_client_frac: float = 0.5) -> dict:
    """Appendix-A accounting: per-variant storage & upload bytes."""
    t, n = plan.selection.shape
    trained = (plan.selection & plan.training).sum()
    estimated = (plan.selection & ~plan.training).sum()
    if variant == "client":        # Alg. 1
        up = (trained + estimated) * model_bytes
        client_store = model_bytes          # each client keeps its Δ
        server_store = 0
    elif variant == "server":      # Alg. 2
        up = trained * model_bytes + estimated // 8 + 1
        client_store = 0
        server_store = n * model_bytes
    elif variant == "mixed":       # Alg. 3
        c = mixed_client_frac
        up = int(trained * model_bytes
                 + estimated * c * model_bytes + estimated * (1 - c) / 8)
        client_store = model_bytes
        server_store = int((1 - c) * n * model_bytes)
    else:
        raise ValueError(variant)
    grad_steps_saved = 1.0 - plan.compute_fraction()
    return {
        "upload_bytes": int(up),
        "client_storage_bytes": int(client_store),
        "server_storage_bytes": int(server_store),
        "compute_saved_frac": grad_steps_saved,
    }
