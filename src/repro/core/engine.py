"""Vectorized-client federated engine — the paper's Algorithm 1/2/3 plus all
baselines, with every client's state stacked along a leading axis so one
jitted round function executes the whole federation (vmap local training,
mask-based skip/estimate decisions, masked-mean aggregation).

The engine is three composable layers:

* :mod:`repro.core.strategies` — the estimation strategies of paper §III as
  a pluggable registry (``fedavg``/``dropout``/``s1``/``s2``/``cc``/``ccc``/
  ``fednova`` + extensions such as ``cc_decay``); new schemes register by
  name and never touch this file.
* :mod:`repro.core.rounds` — round executors: one jitted round, a
  ``lax.scan`` span runner (eval-free spans run as ONE program), and the
  fused Pallas fast path over flat (N, P) params.
* this module — the legacy host-side driver (:func:`run_federated`, now a
  back-compat shim over :class:`repro.api.Session`), Fig.-2 probes and the
  Appendix-A cost accounting (:func:`cost_report`).

Algorithm variants (Appendix A) are numerically identical by construction;
``variant`` ∈ {client, server, mixed} drives the storage/communication cost
accounting (:func:`cost_report`) and which side of the simulation holds Δ.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.evaluation import evaluate  # noqa: F401  (re-exported)
from repro.core.rounds import (  # noqa: F401  (re-exported public API)
    FedConfig,
    _local_train,
    init_fed_state,
    make_round_body,
    make_round_fn,
    make_sharded_span_runner,
    make_span_runner,
    span_boundaries,
)
from repro.core.schedules import Plan
from repro.core.strategies import available_strategies, get_strategy
from repro.data.federated import FederatedData
from repro.models.simple import Classifier
from repro.utils.logging import MetricLogger
from repro.utils.pytree import PyTree, tree_add, tree_sub

#: registered strategy names (kept as a module constant for back-compat;
#: the registry in :mod:`repro.core.strategies` is the source of truth)
STRATEGIES = available_strategies()


def make_probe_fn(model: Classifier, data: FederatedData, fed: FedConfig,
                  client: int):
    """Fig. 2 instrumentation: distance between the estimated local models
    (Strategies 2/3) and the true locally-trained model for one client."""
    from repro.utils.pytree import tree_euclidean, tree_cosine

    @jax.jit
    def probe(state, key):
        cx = data.x[client]
        cy = data.y[client]
        sz = data.sizes[client]
        true_local = _local_train(model, state["params"], key, cx, cy, sz,
                                  fed.local_steps,
                                  jnp.asarray(fed.local_steps),
                                  fed.batch_size, fed.lr)
        true_delta = tree_sub(true_local, state["params"])
        est3 = jax.tree.map(lambda d: d[client], state["deltas"])
        prev = jax.tree.map(lambda p: p[client], state["prev_local"])
        est2_model = prev
        est3_model = tree_add(state["params"], est3)
        s2_delta = tree_sub(prev, state["params"])
        return {
            "euclid_s2": tree_euclidean(true_local, est2_model),
            "euclid_s3": tree_euclidean(true_local, est3_model),
            "cos_s2": tree_cosine(true_delta, s2_delta),
            "cos_s3": tree_cosine(true_delta, est3),
        }

    return probe


def run_federated(model: Classifier, data: FederatedData, fed: FedConfig,
                  plan: Plan, *, x_test, y_test, eval_every: int = 10,
                  probe_client: int | None = None,
                  verbose: bool = False, executor: str = "scan",
                  use_fused: bool = False) -> tuple[PyTree, MetricLogger]:
    """Run the whole federation per ``plan``; returns final state + metrics.

    .. deprecated::
        ``run_federated`` is now a thin back-compat shim over the
        experiment API — prefer :class:`repro.api.Session` (stepwise,
        resumable) and :class:`repro.api.ExperimentSpec` (declarative,
        serializable). Return values and metric streams are identical
        (pinned by ``tests/test_api.py``).

    ``executor`` selects how eval-free spans execute: ``"scan"`` (default)
    runs each span as one jitted ``lax.scan``; ``"python"`` is the classic
    one-dispatch-per-round loop; ``"sharded"`` shard_maps each round's
    cohort over the client mesh (all numerically interchangeable — see
    ``tests/test_executor_matrix.py``). Per-round probing forces the
    python loop. ``use_fused`` routes rounds through the fused Pallas
    kernel (only for ``fused_capable`` strategies such as ``cc``).
    """
    from repro.api.callbacks import ProbeCallback, VerboseLogger
    from repro.api.session import Session

    callbacks = []
    if probe_client is not None:
        callbacks.append(ProbeCallback(probe_client))
    if verbose:
        callbacks.append(VerboseLogger())
    session = Session(model, data, fed, plan, x_test=x_test, y_test=y_test,
                      eval_every=eval_every, executor=executor,
                      use_fused=use_fused, callbacks=callbacks)
    session.run()
    return session.state, session.metrics


def cost_report(plan: Plan, model_bytes: int, variant: str = "client",
                mixed_client_frac: float = 0.5) -> dict:
    """Appendix-A accounting from a static plan's tables (see
    :func:`cost_report_from_counts` for the count-based core — sessions
    running a *runtime* budget policy account from their realized ledger
    instead, since the plan's training table never executed)."""
    trained = int((plan.selection & plan.training).sum())
    estimated = int((plan.selection & ~plan.training).sum())
    return cost_report_from_counts(
        trained, estimated, plan.n_clients, model_bytes, variant=variant,
        mixed_client_frac=mixed_client_frac,
        per_client=plan.compute_fraction(per_client=True))


def cost_report_from_counts(trained: int, estimated: int, n: int,
                            model_bytes: int, variant: str = "client",
                            mixed_client_frac: float = 0.5,
                            per_client=None) -> dict:
    """Appendix-A accounting from raw train/estimate round counts.

    ``trained``/``estimated`` are federation-wide counts of sel∧train and
    sel∧¬train client-rounds; ``per_client`` the (N,) trained-when-selected
    fractions. Works identically for precompiled plans and realized
    ledgers.
    """
    if variant == "client":        # Alg. 1
        up = (trained + estimated) * model_bytes
        client_store = model_bytes          # each client keeps its Δ
        server_store = 0
    elif variant == "server":      # Alg. 2
        up = trained * model_bytes + estimated // 8 + 1
        client_store = 0
        server_store = n * model_bytes
    elif variant == "mixed":       # Alg. 3
        c = mixed_client_frac
        up = int(trained * model_bytes
                 + estimated * c * model_bytes + estimated * (1 - c) / 8)
        client_store = model_bytes
        server_store = int((1 - c) * n * model_bytes)
    else:
        raise ValueError(variant)
    grad_steps_saved = 1.0 - trained / max(1, trained + estimated)
    if per_client is None:
        per_client = []
    return {
        "upload_bytes": int(up),
        "client_storage_bytes": int(client_store),
        "server_storage_bytes": int(server_store),
        "compute_saved_frac": grad_steps_saved,
        # per-client breakdown: how much of its FedAvg(full) work each
        # client actually performed (the scalar hides exactly the
        # heterogeneity the budget law creates)
        "compute_frac_per_client": [float(v) for v in per_client],
    }
