"""Pod-level CC-FedAvg — the paper's technique as a multi-pod training
feature.

On the production mesh ``(pod, data, model)`` each **pod is one federated
client** (cross-silo FL between pods). All per-client state carries a leading
``clients`` axis sharded over ``pod``:

  * ``params``  (clients, …)  — each pod's current local model copy,
  * ``deltas``  (clients, …)  — each pod's stored Δ_{t−1} (Strategy 3),
  * ``global_params`` (…)     — replicated across pods.

One ``cc_pod_round`` = K client-local optimizer steps (vmapped over the
client axis → embarrassingly parallel across pods, data+tensor parallel
inside a pod) followed by the CC aggregation: a *masked mean over the client
axis*, which XLA lowers to the cross-pod all-reduce. A pod that skips the
round (``train_mask=0``) contributes its stored Δ — its K training steps are
dead code *for that pod's devices* only in the sense that the result is
discarded; on real hardware the scheduler simply does not dispatch the
program for that pod, saving the round's FLOPs. The dry-run lowers both the
round with training and the estimation-only round so both cost profiles are
visible (§Roofline).

The same module also provides the single-pod "vectorized silos" layout
(clients sharded over ``data``) used when one pod hosts several silos.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.strategies import get_strategy
from repro.models import decoder
from repro.models.config import ArchConfig
from repro.utils.pytree import PyTree, tree_broadcast_clients, tree_zeros_like


def init_pod_fed_state(rng, cfg: ArchConfig, n_clients: int,
                       delta_dtype=jnp.bfloat16) -> PyTree:
    params = decoder.model_init(rng, cfg)
    deltas = tree_broadcast_clients(
        jax.tree.map(lambda x: jnp.zeros(x.shape, delta_dtype), params),
        n_clients)
    return {
        "global_params": params,
        "deltas": deltas,
        "round": jnp.zeros((), jnp.int32),
    }


def make_cc_pod_round(cfg: ArchConfig, *, lr: float, local_steps: int,
                      n_clients: int, strategy: str = "cc") -> Callable:
    """Build the jittable federated round for LLM-scale clients.

    batches: pytree with leaves (clients, K, per_client_batch, S, ...).
    train_mask: (clients,) float — 1 for pods that train this round
    (ad-hoc/round-robin schedules decide it, exactly as in the small-scale
    engine).
    ``strategy`` resolves through the registry; the pod engine keeps only
    stored Δ (no stale-model history), so replay-style strategies
    (``cc``, ``cc_decay``, …) are supported — others raise at build time.
    """
    strat = get_strategy(strategy)
    # fail fast if the strategy can't estimate from stored Δ alone
    strat.pod_estimate(tree_zeros_like({"probe": jnp.zeros((1,))}))

    def local_train(params, client_batches):
        """K plain SGD steps (Eq. 2) from the broadcast global model."""
        from repro.models.steps import cast_for_compute

        def step(p, batch):
            grads = jax.grad(
                lambda q: decoder.loss_and_metrics(
                    cast_for_compute(q, cfg), cfg, batch)[0])(p)
            p = jax.tree.map(lambda a, g: a - lr * g.astype(a.dtype),
                             p, grads)
            return p, None

        params, _ = jax.lax.scan(step, params, client_batches)
        return params

    def cc_pod_round(fed_state: PyTree, batches: PyTree,
                     train_mask: jax.Array):
        g = fed_state["global_params"]
        broadcast = tree_broadcast_clients(g, n_clients)
        local = jax.vmap(local_train)(broadcast, batches)
        trained_delta = jax.tree.map(
            lambda a, b: (a - b).astype(jnp.bfloat16), local, broadcast)
        m = train_mask.astype(jnp.float32)

        def mix(t, s):
            mm = m.reshape((-1,) + (1,) * (t.ndim - 1)).astype(t.dtype)
            return t * mm + s * (1 - mm)

        est = strat.pod_estimate(fed_state["deltas"])
        delta_i = jax.tree.map(mix, trained_delta, est)
        # aggregation = mean over the client axis → cross-pod all-reduce
        delta = jax.tree.map(lambda d: jnp.mean(d.astype(jnp.float32),
                                                axis=0), delta_i)
        new_global = jax.tree.map(lambda a, d: (a + d).astype(a.dtype),
                                  g, delta)
        return {
            "global_params": new_global,
            "deltas": delta_i,
            "round": fed_state["round"] + 1,
        }

    return cc_pod_round


def make_estimation_only_round(cfg: ArchConfig) -> Callable:
    """The skip-round program a constrained pod actually executes: no
    gradients at all — just replay Δ and join the all-reduce. Lowered in the
    dry-run to document the cost asymmetry CC-FedAvg exploits."""

    def est_round(fed_state: PyTree) -> PyTree:
        delta = jax.tree.map(lambda d: jnp.mean(d.astype(jnp.float32),
                                                axis=0),
                             fed_state["deltas"])
        new_global = jax.tree.map(
            lambda a, d: (a + d).astype(a.dtype),
            fed_state["global_params"], delta)
        return {
            "global_params": new_global,
            "deltas": fed_state["deltas"],
            "round": fed_state["round"] + 1,
        }

    return est_round
