"""Asynchronous staleness-tolerant executor (FedBuff-style buffered merge).

The sixth executor (``executor="async"``): the server never blocks on a
cohort. Clients *pull* the global model when dispatched, work for a
simulated latency (:func:`repro.system.devices.simulate_arrivals` — slow
or loaded devices deliver late), and their updates arrive tagged with a
staleness counter ``s`` = rounds elapsed since the pull. Arrivals land in
a pending buffer; every K-th arrival (``buffer_size``) the server merges
the buffered cohort with staleness-decayed weights ``w(s)``
(:func:`staleness_weights`, ``γ^s`` by default) through the strategy's
:meth:`~repro.core.strategies.Strategy.merge_stale` hook — CC-FedAvg
estimation-replay semantics apply unchanged at each arrival.

The whole loop is still ONE traced ``lax.scan``: the arrival process is
precomputed host-side into (T, N) dispatch/deliver tables plus a (T,)
merge flag (valid because device load dynamics never depend on training
decisions — the same contract that lets plans precompute selection), and
each scan step trains the full federation vmapped from its per-client
pulled models, buffers the round's arrivals and conditionally flushes the
buffer. Merging via ``lax.cond`` keeps non-merge rounds aggregation-free.

Collapse guarantee (the differential oracle pinned in
``tests/test_executor_matrix.py``): with zero latency and jitter every
update delivers in its dispatch round, so at ``buffer_size=1`` each merge
is exactly one synchronous round's aggregation with staleness identically
0 and ``w(0) = 1.0`` exactly — the async executor equals the synchronous
scan executor bit-for-bit, full history and metric streams included.

The Δ history rides a :class:`repro.core.history_store.HistoryStore`:
``history_store="dense"`` keeps the plain f32 client tree;
``history_store="int8"`` carries the quantized (N, P) payload + per-row
scales and requantizes only delivered rows, so estimation replay scales
to N = 10⁵ clients without an O(N·P) f32 carry.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.channel import TAG_MERGE, uplink_channel
from repro.core.history_store import STORE_KINDS, HistoryStore
from repro.core.rounds import (_BASE_KEYS, FedConfig, _round_keys,
                               _train_clients)
from repro.core.strategies import RoundCtx, masked_select
from repro.data.federated import FederatedData
from repro.models.simple import Classifier
from repro.utils.pytree import (PyTree, tree_add, tree_broadcast_clients,
                                tree_ravel_clients, tree_sub,
                                tree_zeros_like)

#: staleness-decay schedules: w(s) for an arrival s rounds stale. Both are
#: exactly 1.0 at s = 0 (the collapse-to-synchronous requirement).
STALENESS_SCHEDULES = ("geometric", "polynomial")

#: the async carry key added to the round state (see ``init_async_carry``)
ASYNC_KEY = "async"

#: mask-mode state keys the policy-mode wrapper passes to the base round
_ASYNC_BASE_KEYS = _BASE_KEYS + (ASYNC_KEY,)


@dataclass(frozen=True)
class AsyncConfig:
    """Runtime knobs of the async executor (spec v5 ``async_*`` fields)."""

    #: merge every K-th arrival (FedBuff buffer size); 1 = merge on every
    #: round with arrivals
    buffer_size: int = 1
    #: γ of the staleness decay w(s) — w(1) under the geometric schedule
    staleness_decay: float = 0.9
    #: decay shape: "geometric" w(s) = γ^s, "polynomial"
    #: w(s) = 1 / (1 + (1 − γ)·s)
    schedule: str = "geometric"
    #: nominal rounds-in-flight of a unit-rate, unloaded device; the
    #: realized latency divides by flops_rate · (1 − load)
    latency: float = 0.0
    #: uniform noise amplitude added to the realized latency (rounds)
    jitter: float = 0.0
    #: Δ-history carry layout: "dense" f32 tree | "int8" quantized store
    history_store: str = "dense"

    def __post_init__(self):
        if not isinstance(self.buffer_size, int) or self.buffer_size < 1:
            raise ValueError(f"async buffer size K must be an int >= 1, "
                             f"got {self.buffer_size!r}")
        if not 0.0 < self.staleness_decay <= 1.0:
            raise ValueError(f"staleness_decay must be in (0, 1], got "
                             f"{self.staleness_decay}")
        if self.schedule not in STALENESS_SCHEDULES:
            raise ValueError(
                f"staleness schedule must be one of {STALENESS_SCHEDULES}, "
                f"got {self.schedule!r}")
        if self.latency < 0:
            raise ValueError(f"latency must be >= 0, got {self.latency}")
        if self.jitter < 0:
            raise ValueError(f"latency jitter must be >= 0, got "
                             f"{self.jitter}")
        if self.history_store not in STORE_KINDS:
            raise ValueError(f"history_store must be one of {STORE_KINDS}, "
                             f"got {self.history_store!r}")


def staleness_weights(schedule: str, decay: float,
                      staleness: jax.Array) -> jax.Array:
    """Per-client merge weights w(s) ≥ 0; w(0) == 1.0 exactly for every
    schedule, so a zero-staleness merge reduces to the synchronous
    aggregation bit-for-bit."""
    s = staleness.astype(jnp.float32)
    if schedule == "geometric":
        return jnp.power(jnp.float32(decay), s)
    if schedule == "polynomial":
        return 1.0 / (1.0 + (1.0 - decay) * s)
    raise ValueError(f"staleness schedule must be one of "
                     f"{STALENESS_SCHEDULES}, got {schedule!r}")


def init_async_carry(state: PyTree, params: PyTree, n_clients: int,
                     cfg: AsyncConfig, *,
                     needs_stale: bool = True) -> PyTree:
    """Extend a fresh federated state with the async executor's carry.

    ``state["async"]`` holds the FedBuff machinery — the in-flight pulled
    models, the per-client pull-round (staleness) counters, the pending
    delta buffer with its masks/staleness/step-count rows, and the scalar
    arrival/merge statistics ``Session.staleness_summary()`` reports. With
    ``history_store="int8"`` the dense ``deltas`` tree is replaced by the
    quantized store carry (and replay-only strategies drop ``prev_local``,
    exactly like the fused q8 carry).
    """
    zeros = tree_broadcast_clients(tree_zeros_like(params), n_clients)
    state[ASYNC_KEY] = {
        "inflight": tree_broadcast_clients(params, n_clients),
        "inflight_train": jnp.zeros((n_clients,), bool),
        "pull_round": jnp.zeros((n_clients,), jnp.int32),
        "pending": zeros,
        "pending_mask": jnp.zeros((n_clients,), bool),
        "pending_train": jnp.zeros((n_clients,), bool),
        "pending_stale": jnp.zeros((n_clients,), jnp.int32),
        "pending_k": jnp.ones((n_clients,), jnp.int32),
        "stats": {
            "arrivals": jnp.zeros((), jnp.int32),
            "merges": jnp.zeros((), jnp.int32),
            "stale_sum": jnp.zeros((), jnp.float32),
            "stale_max": jnp.zeros((), jnp.int32),
            "occupancy_sum": jnp.zeros((), jnp.int32),
        },
    }
    if cfg.history_store == "int8":
        flat, _ = tree_ravel_clients(zeros)
        store = HistoryStore.for_flat(n_clients, flat.shape[1], kind="int8")
        state["deltas"] = store.init()
        if not needs_stale:
            state.pop("prev_local", None)
    return state


def make_async_round_body(model: Classifier, data: FederatedData,
                          fed: FedConfig, cfg: AsyncConfig):
    """The traceable async round transition. One scan step:

    1. **dispatch** — flagged clients pull the current global model and
       record their train/estimate decision and pull round;
    2. **compute** — the whole federation trains vmapped from its pulled
       models (idle clients' work is masked out downstream, exactly like
       unselected clients of a synchronous round), with the delivery
       round's per-client keys;
    3. **deliver** — arriving clients materialize their update via the
       synchronous train-or-estimate semantics (``strategy.estimate``
       against the stored history), the update lands in the pending
       buffer tagged with its staleness, and the Δ history rolls forward
       for exactly the delivered rows;
    4. **merge** — if the round's merge flag is set, the buffered cohort
       aggregates through ``strategy.merge_stale`` with the schedule's
       w(s) weights and the buffer clears; otherwise params carry a
       zero update (numerically what an empty synchronous round applies).
    """
    strategy = fed.resolve()
    channel = uplink_channel(fed)
    n = data.n_clients

    def round_body(state, train_row, dispatch, deliver, merge_flag,
                   k_active, energy=None):
        a = state[ASYNC_KEY]
        params, rnd = state["params"], state["round"]
        key, keys = _round_keys(state["key"], n)

        # ---- 1. dispatch: pull the current global model ----------------
        bcast = tree_broadcast_clients(params, n)
        start = masked_select(dispatch, bcast, a["inflight"])
        pull_round = jnp.where(dispatch, rnd, a["pull_round"])
        inflight_train = jnp.where(dispatch, train_row, a["inflight_train"])

        # ---- 2. compute from the pulled models -------------------------
        local = _train_clients(model, fed, start, keys, data.x, data.y,
                               data.sizes, k_active,
                               prox=strategy.prox_coeff(),
                               dual=strategy.local_dual(state))
        trained_delta = tree_sub(local, start)

        # ---- 3. deliveries: synchronous round semantics at arrival -----
        flat_pending, unravel_clients = tree_ravel_clients(a["pending"])
        p = flat_pending.shape[1]
        q8 = (isinstance(state["deltas"], dict)
              and set(state["deltas"]) == {"payload", "scales"})
        if q8:
            store = HistoryStore(n, state["deltas"]["payload"].shape[1],
                                 kind="int8", logical_width=p)
            hist_deltas = unravel_clients(store.read_logical(state["deltas"]))
        else:
            store = None
            hist_deltas = state["deltas"]
        if "prev_local" in state:
            stale_delta = tree_sub(state["prev_local"], start)
            stale_delta = masked_select(state["trained_ever"], stale_delta,
                                        tree_zeros_like(stale_delta))
            hist_prev = state["prev_local"]
        else:
            # replay-only int8 carry: nothing reads the stale model; the
            # update_history output for it is discarded below
            stale_delta = tree_zeros_like(trained_delta)
            hist_prev = local
        hist = {"deltas": hist_deltas, "prev_local": hist_prev,
                "trained_ever": state["trained_ever"]}
        for hk in strategy.extra_history_keys():
            if hk in state:
                hist[hk] = state[hk]
        t_mask = deliver & inflight_train
        ctx = RoundCtx(sel_mask=deliver, train_mask=t_mask,
                       k_active=k_active, round=rnd, tau=fed.tau,
                       stale_delta=stale_delta,
                       trained_delta=trained_delta, energy=energy)
        est = strategy.estimate(hist, ctx)
        delta_i = masked_select(t_mask, trained_delta, est)

        staleness = rnd - pull_round
        pending = masked_select(deliver, delta_i, a["pending"])
        pending_mask = a["pending_mask"] | deliver
        pending_train = jnp.where(deliver, t_mask, a["pending_train"])
        pending_stale = jnp.where(deliver, staleness, a["pending_stale"])
        pending_k = jnp.where(deliver, k_active, a["pending_k"])

        deltas_tree, prev_local = strategy.update_history(
            hist, ctx, trained_delta, local, est)
        if store is None:
            new_deltas = deltas_tree
        else:
            flat_new, _ = tree_ravel_clients(deltas_tree)
            new_deltas = store.write(state["deltas"], deliver,
                                     store.pad_rows(flat_new))
        trained_ever = state["trained_ever"] | (deliver & t_mask)

        # ---- 4. buffered merge (only the K-arrival boundary pays) ------
        decay_w = staleness_weights(cfg.schedule, cfg.staleness_decay,
                                    pending_stale)
        mctx = RoundCtx(sel_mask=pending_mask, train_mask=pending_train,
                        k_active=pending_k, round=rnd, tau=fed.tau,
                        stale_delta=tree_zeros_like(pending),
                        trained_delta=pending, energy=energy)
        occ = jnp.sum(pending_mask.astype(jnp.int32))

        def _merge(_):
            aggf = strategy.agg_mask(mctx).astype(jnp.float32)
            up = pending
            if channel is not None:
                # merge-time uplink: the buffered cohort transmits over
                # the air NOW — gains and AWGN key on the MERGE round
                up = channel.fade(up, rnd,
                                  jnp.arange(n, dtype=jnp.int32), n,
                                  TAG_MERGE)
            d = strategy.merge_stale(up, aggf, pending_stale, decay_w,
                                     mctx)
            if channel is not None:
                d = channel.corrupt(d, rnd, TAG_MERGE)
            return (tree_add(params, d), jnp.zeros((n,), bool),
                    jnp.ones((), jnp.int32), occ)

        def _hold(_):
            return (tree_add(params, tree_zeros_like(params)), pending_mask,
                    jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))

        new_params, new_pending_mask, merge_inc, occ_inc = jax.lax.cond(
            merge_flag, _merge, _hold, operand=None)

        stats = a["stats"]
        arrived_stale = jnp.where(deliver, staleness, 0)
        new_stats = {
            "arrivals": stats["arrivals"]
            + jnp.sum(deliver.astype(jnp.int32)),
            "merges": stats["merges"] + merge_inc,
            "stale_sum": stats["stale_sum"]
            + jnp.sum(arrived_stale.astype(jnp.float32)),
            "stale_max": jnp.maximum(stats["stale_max"],
                                     jnp.max(arrived_stale)),
            "occupancy_sum": stats["occupancy_sum"] + occ_inc,
        }

        out = {
            "params": new_params,
            "deltas": new_deltas,
            "trained_ever": trained_ever,
            "round": rnd + 1,
            "key": key,
            ASYNC_KEY: {
                "inflight": start,
                "inflight_train": inflight_train,
                "pull_round": pull_round,
                "pending": pending,
                "pending_mask": new_pending_mask,
                "pending_train": pending_train,
                "pending_stale": pending_stale,
                "pending_k": pending_k,
                "stats": new_stats,
            },
        }
        if "prev_local" in state:
            out["prev_local"] = prev_local
        # strategy extras (e.g. feddyn's dual) roll on DELIVERED trained
        # rows — ctx's sel∧train is deliver∧inflight_train, exactly the
        # rows whose Δ history advanced above
        out.update(strategy.update_extra_history(hist, ctx, trained_delta,
                                                 local, est))
        return out

    return round_body


def make_async_span_runner(model: Classifier, data: FederatedData,
                           fed: FedConfig, cfg: AsyncConfig, *,
                           policy=None, profile=None):
    """Async executor span: ``run_span(state, train_chunk, k_active,
    sched)`` advances a (C, N) span of plan *training* rows against the
    span's slice of the arrival schedule ``sched`` — a (dispatch,
    deliver, merge) tuple of (C, N)/(C, N)/(C,) event tables from
    :func:`repro.system.devices.simulate_arrivals` — as one jitted
    ``lax.scan`` over arrival events.

    With ``policy`` + ``profile`` (policy mode, the Session default) the
    signature drops the train chunk — ``run_span(state, k_active,
    sched)`` — and the budget policy decides at each client's DISPATCH
    round (when the work is actually started and its energy drained),
    while the ledger books the upload at the DELIVERY round: a stale
    update counts exactly once, when it realizes as an arrival.
    """
    if (policy is None) != (profile is None):
        raise ValueError("policy mode needs BOTH policy and profile "
                         "(got exactly one)")
    round_body = make_async_round_body(model, data, fed, cfg)
    n = data.n_clients

    if policy is None:
        @jax.jit
        def run_span(state, train_chunk, k_active, sched):
            dispatch_c, deliver_c, merge_c = sched

            def step(st, xs):
                train, disp, dlv, mrg = xs
                return round_body(st, train, disp, dlv, mrg, k_active), None

            state, _ = jax.lax.scan(
                step, state, (train_chunk, dispatch_c, deliver_c, merge_c))
            return state

        return run_span

    # ---- policy mode: decide at dispatch, account at delivery -----------
    from repro.core.budget import budget_ctx
    from repro.system.devices import advance_devices, update_ledger

    if profile.n_clients != n:
        raise ValueError(
            f"device profile covers {profile.n_clients} clients, data has "
            f"{n}")
    rows = profile.rows()
    ids = jnp.arange(n, dtype=jnp.int32)
    # strategy extras (e.g. feddyn's dual rows) ride the base round state
    base_keys = _ASYNC_BASE_KEYS + fed.resolve().extra_history_keys()

    def policy_round(state, dispatch, deliver, merge_flag, k_active):
        dev = state["device"]
        bctx = budget_ctx(rows, dev, state["round"], ids, dispatch,
                          profile.seed)
        train_row, new_rows = policy.decide(state["policy"], bctx)
        train_row = train_row & dispatch
        base_state = {k: state[k] for k in base_keys if k in state}
        new_base = round_body(base_state, train_row, dispatch, deliver,
                              merge_flag, k_active, energy=dev["energy"])
        # energy drains when the work is dispatched (the compute happens
        # then); uploads/estimates are booked per realized ARRIVAL — the
        # recalled in-flight decision classifies each delivery
        spent = dispatch & train_row
        new_base["policy"] = new_rows
        new_base["device"] = advance_devices(rows, dev, spent,
                                             state["round"], ids,
                                             profile.seed)
        new_base["ledger"] = update_ledger(
            state["ledger"], rows, deliver,
            new_base[ASYNC_KEY]["inflight_train"])
        return new_base

    @jax.jit
    def run_span(state, k_active, sched):
        dispatch_c, deliver_c, merge_c = sched

        def step(st, xs):
            disp, dlv, mrg = xs
            return policy_round(st, disp, dlv, mrg, k_active), None

        state, _ = jax.lax.scan(step, state, (dispatch_c, deliver_c,
                                              merge_c))
        return state

    return run_span
