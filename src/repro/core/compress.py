"""Update (Δ) compression for federated uploads — beyond-paper extension.

CC-FedAvg already cuts *computation* by `1 − p_i`; upload cost is still a
full model per participating round (Alg. 1) or per trained round
(Alg. 2). Since Δ is an SGD increment with small dynamic range, int8
per-leaf symmetric quantization compresses uploads ~4× (vs f32) at
negligible aggregation error — and composes with every strategy because
the server aggregates dequantized means.

API mirrors the pytree algebra the engine uses:

    q = quantize_tree(delta)            # int8 payload + f32 scales
    delta2 = dequantize_tree(q)         # back to float
    report = compressed_report(plan, model_bytes)  # Appendix-A accounting
                                                   # with compression
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.engine import cost_report
from repro.core.schedules import Plan

PyTree = Any
_QMAX = 127.0

#: uncompressed bytes per parameter (f32) — the int8 compression ratio
#: every report in this module and ``Session.cost_report`` derives from
BYTES_PER_PARAM_F32 = 4


class QuantizedTree(NamedTuple):
    payload: PyTree     # int8 leaves
    scales: PyTree      # f32 per-leaf scale


def quantize_tree(tree: PyTree) -> QuantizedTree:
    """Symmetric per-leaf int8 quantization (scale = max|x| / 127).

    Degenerate leaves round-trip exactly: an empty leaf gets a unit
    scale (``jnp.max`` over zero elements raises, even under jit), a
    0-d leaf quantizes like a 1-element array, and an all-zero leaf
    dequantizes to exact zeros (the 1e-12 scale floor never divides
    a nonzero payload into existence).
    """
    def q(x):
        xf = x.astype(jnp.float32)
        if xf.size == 0:
            return xf.astype(jnp.int8), jnp.ones((), jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / _QMAX
        return jnp.clip(jnp.round(xf / scale), -_QMAX, _QMAX
                        ).astype(jnp.int8), scale

    pairs = jax.tree.map(q, tree)
    payload = jax.tree.map(lambda p: p[0], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
    scales = jax.tree.map(lambda p: p[1], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
    return QuantizedTree(payload, scales)


def dequantize_tree(q: QuantizedTree, dtype=jnp.float32) -> PyTree:
    return jax.tree.map(
        lambda p, s: (p.astype(jnp.float32) * s).astype(dtype),
        q.payload, q.scales)


def quantize_rows(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-row int8 quantization of an (N, P) matrix.

    The row layout the fused q8 kernel consumes: one f32 scale per client
    row (scale = max|row| / 127). Returns (payload int8 (N, P),
    scales f32 (N,))."""
    xf = x.astype(jnp.float32)
    scales = jnp.maximum(jnp.max(jnp.abs(xf), axis=1), 1e-12) / _QMAX
    payload = jnp.clip(jnp.round(xf / scales[:, None]), -_QMAX, _QMAX
                       ).astype(jnp.int8)
    return payload, scales


def dequantize_rows(payload: jax.Array, scales: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`quantize_rows` (up to the ≤ scale/2 rounding)."""
    return (payload.astype(jnp.float32) * scales[:, None]).astype(dtype)


def quantization_error(tree: PyTree) -> float:
    """Relative L2 error of one quantize→dequantize round trip."""
    from repro.utils.pytree import tree_norm, tree_sub
    back = dequantize_tree(quantize_tree(tree))
    return float(tree_norm(tree_sub(tree, back)) /
                 jnp.maximum(tree_norm(tree), 1e-12))


def compressed_report(plan: Plan, model_bytes: int, *,
                      variant: str = "client",
                      bytes_per_param_before: int = BYTES_PER_PARAM_F32
                      ) -> dict:
    """Appendix-A upload accounting with int8 Δ compression.

    int8 payload + one f32 scale per leaf ≈ model_bytes/4; the 'skip'
    signal paths of Alg. 2/3 are already ~free and stay uncompressed.
    """
    base = cost_report(plan, model_bytes, variant=variant)
    ratio = 1.0 / bytes_per_param_before
    out = dict(base)
    out["upload_bytes_compressed"] = int(base["upload_bytes"] * ratio)
    out["compression_ratio"] = bytes_per_param_before
    return out


def tier_upload_report(*, client_upload_bytes: int, n_syncs: int,
                       n_edges: int, model_bytes: int,
                       bytes_per_param_before: int = BYTES_PER_PARAM_F32
                       ) -> dict:
    """Per-tier upload accounting for a two-tier client→edge→server run
    (:mod:`repro.core.hierarchy`), with and without int8 Δ compression.

    The client tier uploads to its edge gateway every decided round (the
    variant-dependent Appendix-A bytes, computed by the caller from the
    realized ledger); the edge tier uploads one edge model per aggregator
    per sync — ``n_syncs`` period boundaries crossed so far, E models
    each. Quantization compresses BOTH hops by ``bytes_per_param_before``×
    (the per-leaf f32 scales are negligible against the payload).
    """
    if n_syncs < 0 or n_edges < 1:
        raise ValueError(f"need n_syncs >= 0 and n_edges >= 1, got "
                         f"n_syncs={n_syncs}, n_edges={n_edges}")
    ratio = 1.0 / bytes_per_param_before
    edge_up = n_syncs * n_edges * model_bytes
    return {
        "client_to_edge_bytes": int(client_upload_bytes),
        "client_to_edge_bytes_int8": int(client_upload_bytes * ratio),
        "edge_to_server_bytes": int(edge_up),
        "edge_to_server_bytes_int8": int(edge_up * ratio),
        "compression_ratio": bytes_per_param_before,
    }
