"""Test-set evaluation shared by the engine shim and the Session API.

The old ``engine.evaluate`` wrapped ``model.apply`` in ``jax.jit`` on every
call, so every evaluation re-traced the model. The jitted apply is now
cached per model apply-function, so a run with hundreds of eval points
traces once per (model, batch-shape).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.simple import Classifier

#: jitted apply per model.apply function (identity-keyed; bounded so a
#: sweep building many models cannot grow it without limit)
_APPLY_CACHE: dict[Callable, Callable] = {}
_APPLY_CACHE_MAX = 64


def jitted_apply(apply_fn: Callable) -> Callable:
    fn = _APPLY_CACHE.get(apply_fn)
    if fn is None:
        if len(_APPLY_CACHE) >= _APPLY_CACHE_MAX:
            _APPLY_CACHE.clear()
        fn = _APPLY_CACHE[apply_fn] = jax.jit(apply_fn)
    return fn


def evaluate(model: Classifier, params, x_test, y_test,
             batch: int = 512) -> float:
    """Top-1 accuracy over the test set, batched."""
    n = x_test.shape[0]
    correct = 0
    apply = jitted_apply(model.apply)
    for i in range(0, n, batch):
        logits = apply(params, x_test[i: i + batch])
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y_test[i: i + batch]))
    return correct / n
