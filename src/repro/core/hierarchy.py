"""Two-tier client → edge → server federation topology.

CC-FedAvg targets IoT fleets whose devices hang off edge gateways rather
than a flat star: the edge-FL surveys (Khan et al., "Federated Learning
for Edge Networks"; Imteaj et al. on resource-constrained IoT) identify
client→edge→cloud aggregation as the shape that scales FL to millions of
devices. An :class:`EdgeTopology` pins that shape down as data:

* a static **assignment** of the N clients to E edge aggregators (every
  client belongs to exactly one edge — validated eagerly);
* an **edge period** P: each edge runs P rounds of masked intra-edge
  aggregation on its own members before the server averages the edge
  models, weighted by how many clients each edge aggregated.

The round semantics live in
:func:`repro.core.rounds.make_hierarchical_span_runner`; this module owns
the topology itself plus the small algebra the hierarchy is built on —
per-edge masked means and their mass-weighted combination. The governing
identity (property-tested in ``tests/test_hierarchy.py``) is

    edge_weighted_mean(edge_masked_means(x, m), edge_mass(m)) ==
        tree_masked_mean(x, m)            for ANY mask m,

i.e. weighting each edge by its aggregation mass makes the nested
edge-then-server mean equal the flat global masked mean — which is why a
two-tier run with ``edge_period=1`` (or a single edge) collapses to flat
FedAvg, turning the whole flat executor matrix into the hierarchy's
differential oracle.

Topologies are deterministic functions of their spec fields (kind,
n_clients, n_edges), so a resumed session rebuilds the identical
assignment — the same contract the plan masks and cohort sampler follow.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.pytree import PyTree, tree_masked_mean

#: assignment schemes ``EdgeTopology.make`` understands
TOPOLOGY_KINDS = ("contiguous", "striped")


@dataclass(frozen=True, eq=False)
class EdgeTopology:
    """Static client→edge assignment plus the intra-edge round period."""

    assignment: np.ndarray   # (N,) int32 — edge id of every client
    n_edges: int
    edge_period: int = 1

    def __post_init__(self):
        a = np.asarray(self.assignment, np.int32)
        object.__setattr__(self, "assignment", a)
        if a.ndim != 1 or a.size == 0:
            raise ValueError(f"assignment must be a non-empty 1-D vector, "
                             f"got shape {a.shape}")
        if self.n_edges < 1:
            raise ValueError(f"n_edges must be >= 1, got {self.n_edges}")
        if self.edge_period < 1:
            raise ValueError(
                f"edge_period must be >= 1, got {self.edge_period}")
        if ((a < 0) | (a >= self.n_edges)).any():
            raise ValueError(
                f"assignment ids must lie in [0, {self.n_edges}); got "
                f"range [{a.min()}, {a.max()}]")
        sizes = np.bincount(a, minlength=self.n_edges)
        if (sizes == 0).any():
            empty = np.flatnonzero(sizes == 0).tolist()
            raise ValueError(f"every edge needs at least one client; "
                             f"edges {empty} are empty")

    # ---- constructors ---------------------------------------------------

    @classmethod
    def make(cls, kind: str, n_clients: int, n_edges: int,
             edge_period: int = 1) -> "EdgeTopology":
        """Build a named assignment scheme (the spec-driven entry point)."""
        if kind == "contiguous":
            return cls.contiguous(n_clients, n_edges, edge_period)
        if kind == "striped":
            return cls.striped(n_clients, n_edges, edge_period)
        raise ValueError(f"unknown topology kind {kind!r}; available: "
                         f"{', '.join(TOPOLOGY_KINDS)}")

    @classmethod
    def contiguous(cls, n_clients: int, n_edges: int,
                   edge_period: int = 1) -> "EdgeTopology":
        """Consecutive near-equal blocks: client i → edge ``i // ceil(N/E)``
        style split (block sizes differ by at most one). When ``N % E == 0``
        the blocks are exactly equal, which is what lets the hierarchical
        executor shard whole edges over devices."""
        if not 1 <= n_edges <= n_clients:
            raise ValueError(f"n_edges must be in [1, {n_clients}], "
                             f"got {n_edges}")
        # np.array_split's near-equal contiguous blocks, as an id vector
        sizes = np.full(n_edges, n_clients // n_edges, np.int64)
        sizes[: n_clients % n_edges] += 1
        return cls(np.repeat(np.arange(n_edges), sizes), n_edges,
                   edge_period)

    @classmethod
    def striped(cls, n_clients: int, n_edges: int,
                edge_period: int = 1) -> "EdgeTopology":
        """Round-robin striping: client i → edge ``i % E`` (an irregular
        layout for the 1-shard executor path; it cannot shard whole edges
        over devices)."""
        if not 1 <= n_edges <= n_clients:
            raise ValueError(f"n_edges must be in [1, {n_clients}], "
                             f"got {n_edges}")
        return cls(np.arange(n_clients) % n_edges, n_edges, edge_period)

    # ---- views ----------------------------------------------------------

    @property
    def n_clients(self) -> int:
        return int(self.assignment.shape[0])

    @property
    def edge_sizes(self) -> np.ndarray:
        """(E,) client counts per edge (all >= 1 by construction)."""
        return np.bincount(self.assignment, minlength=self.n_edges)

    @property
    def is_contiguous_uniform(self) -> bool:
        """True when edges are equal-size consecutive blocks — the layout
        the sharded executor requires so whole edges land on one device."""
        n, e = self.n_clients, self.n_edges
        if n % e:
            return False
        return bool((self.assignment == np.arange(n) // (n // e)).all())

    def member_mask(self, edge: int) -> np.ndarray:
        """(N,) bool — membership mask of one edge."""
        if not 0 <= edge < self.n_edges:
            raise ValueError(f"edge must be in [0, {self.n_edges}), "
                             f"got {edge}")
        return self.assignment == edge

    def client_edges(self) -> jax.Array:
        """(N,) int32 edge ids as a device array (the ``edge_id`` rows the
        round/budget contexts carry)."""
        return jnp.asarray(self.assignment, jnp.int32)

    def sync_count(self, rounds_done: int) -> int:
        """How many edge→server syncs a run of ``rounds_done`` rounds has
        performed (a sync closes every ``edge_period``-th round)."""
        if rounds_done < 0:
            raise ValueError(f"rounds_done must be >= 0, got {rounds_done}")
        return rounds_done // self.edge_period


# ---------------------------------------------------------------------------
# the hierarchy's aggregation algebra
# ---------------------------------------------------------------------------


def edge_mass(mask: jax.Array, assignment, n_edges: int) -> jax.Array:
    """(E,) per-edge mask mass: how many of each edge's clients carry
    weight in an aggregation round. These are the server-tier weights that
    make the nested mean exact (see module docstring)."""
    a = jnp.asarray(assignment)
    onehot = (a[None, :] == jnp.arange(n_edges)[:, None])
    return onehot.astype(jnp.float32) @ jnp.asarray(mask, jnp.float32)


def edge_masked_means(tree: PyTree, mask: jax.Array, assignment,
                      n_edges: int) -> PyTree:
    """Per-edge masked means of a client-stacked tree: an E-stacked tree
    whose slice e is ``tree_masked_mean`` restricted to edge e's members
    (an edge with zero mass contributes exact zeros, like the flat empty
    mask)."""
    a = jnp.asarray(assignment)
    maskf = jnp.asarray(mask, jnp.float32)
    means = [tree_masked_mean(tree, maskf * (a == e).astype(jnp.float32))
             for e in range(n_edges)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *means)


def edge_weighted_mean(edge_tree: PyTree, weights: jax.Array,
                       eps: float = 1e-12) -> PyTree:
    """Weighted mean over the leading (edge) axis — the server tier's
    average of edge models. With ``weights = edge_mass(mask)`` this equals
    the flat global masked mean for any mask."""
    w = jnp.asarray(weights, jnp.float32)
    denom = jnp.maximum(jnp.sum(w), eps)

    def _mean(x):
        wf = w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)
        return jnp.sum(x * wf, axis=0) / denom.astype(x.dtype)

    return jax.tree.map(_mean, edge_tree)
