"""Participation & training schedules (paper §VI-A).

A federated run is driven by two precomputed boolean plans over
(rounds T × clients N):

* ``selection`` — which clients the server selects each round (S_t),
* ``training``  — which selected clients perform real local training
  (vs. estimating; the client-side decision driven by p_i).

Schedules:
* **round-robin** — client i trains once every W_i = round(1/p_i) rounds,
  deterministically (energy-budget planning in advance; Fig. 1a).
* **ad-hoc** — client i trains with probability p_i independently each round
  (real-time load-dependent decision; Fig. 1b).
* **sync** — all constrained clients skip/train in lockstep (the FedOpt-like
  degenerate schedule of §VI-F, used to show ad-hoc matters).
* **dropout** — FedAvg(dropout) baseline: client trains every round until its
  budget quota ``p_i · T`` is exhausted, then leaves the federation.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Plan:
    selection: np.ndarray  # (T, N) bool — S_t membership
    training: np.ndarray   # (T, N) bool — performs local training
    p: np.ndarray          # (N,) budgets used to build the plan

    @property
    def rounds(self) -> int:
        return self.selection.shape[0]

    @property
    def n_clients(self) -> int:
        return self.selection.shape[1]

    def compute_fraction(self) -> float:
        """Fraction of FedAvg(full) gradient work actually performed."""
        return float((self.selection & self.training).sum()
                     / max(1, self.selection.sum()))


def server_selection(rng: np.random.Generator, t_rounds: int, n: int,
                     ratio: float = 1.0) -> np.ndarray:
    if ratio >= 1.0:
        return np.ones((t_rounds, n), bool)
    k = max(1, int(round(ratio * n)))
    sel = np.zeros((t_rounds, n), bool)
    for t in range(t_rounds):
        sel[t, rng.choice(n, size=k, replace=False)] = True
    return sel


def _w_of(p: np.ndarray) -> np.ndarray:
    return np.maximum(1, np.round(1.0 / np.clip(p, 1e-9, 1.0))).astype(int)


def make_plan(kind: str, p: np.ndarray, t_rounds: int,
              participation_ratio: float = 1.0, seed: int = 0) -> Plan:
    p = np.asarray(p, float)
    if p.ndim != 1 or len(p) == 0:
        raise ValueError(f"p must be a non-empty 1-D budget vector, got "
                         f"shape {p.shape}")
    if not ((p > 0) & (p <= 1)).all():     # also rejects NaN
        raise ValueError("budgets must satisfy 0 < p_i <= 1")
    if t_rounds < 1:
        raise ValueError(f"t_rounds must be >= 1, got {t_rounds}")
    rng = np.random.default_rng(seed)
    n = len(p)
    sel = server_selection(rng, t_rounds, n, participation_ratio)
    w = _w_of(p)
    if kind == "round_robin":
        # client i trains on selected rounds counted mod W_i (so a client
        # selected less often still meets its 1-in-W budget in expectation).
        # offsets must stay in the half-open [0, W_i) — an offset == W_i
        # could never fire through ``counters % w`` — which is what
        # ``Generator.integers``' exclusive high end gives; p_i = 1 clients
        # then always get offset 0, i.e. train whenever selected
        # (regression-tested in test_fed_engine.py).
        train = np.zeros((t_rounds, n), bool)
        offsets = rng.integers(0, w)
        counters = np.zeros(n, int)
        for t in range(t_rounds):
            due = (counters % w) == offsets
            train[t] = sel[t] & due
            counters += sel[t].astype(int)
    elif kind == "adhoc":
        train = rng.random((t_rounds, n)) < p[None, :]
        train &= sel
    elif kind == "sync":
        # every client with p_i < 1 trains only when t % max(W) == 0
        wmax = int(w.max())
        beat = (np.arange(t_rounds) % wmax) == 0
        train = np.where(p[None, :] >= 1.0, True, beat[:, None])
        train &= sel
    elif kind == "dropout":
        quota = np.maximum(1, np.round(p * t_rounds)).astype(int)
        used = np.zeros(n, int)
        train = np.zeros((t_rounds, n), bool)
        for t in range(t_rounds):
            active = used < quota
            train[t] = sel[t] & active
            used += train[t].astype(int)
        # dropped-out clients also leave aggregation entirely
        sel = train.copy()
    elif kind == "full":
        train = sel.copy()
    else:
        raise ValueError(f"unknown schedule kind {kind!r}")
    return Plan(selection=sel, training=train, p=np.asarray(p, float))


def fednova_local_steps(p: np.ndarray, k_full: int) -> np.ndarray:
    """FedNova spends the budget as fewer local iterations every round."""
    return np.maximum(1, np.round(p * k_full)).astype(np.int32)
