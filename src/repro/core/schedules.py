"""Participation & training schedules (paper §VI-A).

.. deprecated::
    Plans are no longer an *engine* input: the round executors decide
    train-vs-estimate in-loop through :mod:`repro.core.budget` policies,
    and every schedule kind below survives as a
    ``PrecompiledPolicy(make_plan(...).training)`` special case, replayed
    bit-for-bit (pinned per kind × executor in
    ``tests/test_executor_matrix.py``). ``make_plan`` remains the
    compatibility shim that builds those tables plus the server-side
    selection masks.

A plan is two precomputed boolean tables over (rounds T × clients N):

* ``selection`` — which clients the server selects each round (S_t),
* ``training``  — which selected clients perform real local training
  (vs. estimating; the client-side decision driven by p_i).

Schedules:
* **round-robin** — client i trains once every W_i = round(1/p_i) rounds,
  deterministically (energy-budget planning in advance; Fig. 1a).
* **ad-hoc** — client i trains with probability p_i independently each round
  (real-time load-dependent decision; Fig. 1b).
* **sync** — all constrained clients skip/train in lockstep (the FedOpt-like
  degenerate schedule of §VI-F, used to show ad-hoc matters).
* **dropout** — FedAvg(dropout) baseline: client trains every round until its
  budget quota ``p_i · T`` is exhausted, then leaves the federation.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Plan:
    selection: np.ndarray  # (T, N) bool — S_t membership
    training: np.ndarray   # (T, N) bool — performs local training
    p: np.ndarray          # (N,) budgets used to build the plan

    @property
    def rounds(self) -> int:
        return self.selection.shape[0]

    @property
    def n_clients(self) -> int:
        return self.selection.shape[1]

    def compute_fraction(self, per_client: bool = False):
        """Fraction of FedAvg(full) gradient work actually performed.

        ``per_client=True`` returns the (N,) breakdown — each client's
        trained-when-selected fraction — instead of the federation-wide
        scalar (clients never selected report 0).
        """
        trained = (self.selection & self.training).sum(axis=0)
        selected = self.selection.sum(axis=0)
        if per_client:
            return trained / np.maximum(1, selected)
        return float(trained.sum() / max(1, selected.sum()))


def server_selection(rng: np.random.Generator, t_rounds: int, n: int,
                     ratio: float = 1.0) -> np.ndarray:
    """Uniform k-of-N participation per round, vectorized: one (T, N)
    uniform draw, each round selecting its k smallest entries — one rng
    call and a partition instead of T ``choice`` loops (``random((T, N))``
    fills row-major, so round t's row equals the t-th sequential
    ``random(N)`` draw; equality with the per-round loop formulation is
    pinned in ``tests/test_fed_engine.py``).

    .. note::
        The distribution is unchanged (uniform without replacement), but
        the seeded bit-stream differs from the pre-vectorization
        ``rng.choice`` loop — same-seed plans with ``participation < 1``
        select different (equally-distributed) cohorts than they did
        before the vectorization. Full participation consumes no
        randomness in either version.
    """
    if ratio >= 1.0:
        return np.ones((t_rounds, n), bool)
    k = max(1, int(round(ratio * n)))
    u = rng.random((t_rounds, n))
    kth = np.partition(u, k - 1, axis=1)[:, k - 1:k]
    return u <= kth


def _w_of(p: np.ndarray) -> np.ndarray:
    return np.maximum(1, np.round(1.0 / np.clip(p, 1e-9, 1.0))).astype(int)


def make_plan(kind: str, p: np.ndarray, t_rounds: int,
              participation_ratio: float = 1.0, seed: int = 0) -> Plan:
    p = np.asarray(p, float)
    if p.ndim != 1 or len(p) == 0:
        raise ValueError(f"p must be a non-empty 1-D budget vector, got "
                         f"shape {p.shape}")
    if not ((p > 0) & (p <= 1)).all():     # also rejects NaN
        raise ValueError("budgets must satisfy 0 < p_i <= 1")
    if t_rounds < 1:
        raise ValueError(f"t_rounds must be >= 1, got {t_rounds}")
    rng = np.random.default_rng(seed)
    n = len(p)
    sel = server_selection(rng, t_rounds, n, participation_ratio)
    w = _w_of(p)
    if kind == "round_robin":
        # client i trains on selected rounds counted mod W_i (so a client
        # selected less often still meets its 1-in-W budget in expectation).
        # offsets must stay in the half-open [0, W_i) — an offset == W_i
        # could never fire through ``counters % w`` — which is what
        # ``Generator.integers``' exclusive high end gives; p_i = 1 clients
        # then always get offset 0, i.e. train whenever selected
        # (regression-tested in test_fed_engine.py).
        # vectorized: the loop's running counter at round t is the
        # exclusive cumulative selection count (loop equality pinned in
        # test_fed_engine.py).
        offsets = rng.integers(0, w)
        counters = np.cumsum(sel, axis=0) - sel      # exclusive cumsum
        train = sel & ((counters % w[None, :]) == offsets[None, :])
    elif kind == "adhoc":
        train = rng.random((t_rounds, n)) < p[None, :]
        train &= sel
    elif kind == "sync":
        # every client with p_i < 1 trains only when t % max(W) == 0
        wmax = int(w.max())
        beat = (np.arange(t_rounds) % wmax) == 0
        train = np.where(p[None, :] >= 1.0, True, beat[:, None])
        train &= sel
    elif kind == "dropout":
        # a client trains on its first quota_i selected rounds, then drops
        # out — i.e. trains while its exclusive cumulative selection count
        # is under quota (vectorized form of the loop's used-counter; loop
        # equality pinned in test_fed_engine.py)
        quota = np.maximum(1, np.round(p * t_rounds)).astype(int)
        used = np.cumsum(sel, axis=0) - sel          # exclusive cumsum
        train = sel & (used < quota[None, :])
        # dropped-out clients also leave aggregation entirely
        sel = train.copy()
    elif kind == "full":
        train = sel.copy()
    else:
        raise ValueError(f"unknown schedule kind {kind!r}")
    return Plan(selection=sel, training=train, p=np.asarray(p, float))


def fednova_local_steps(p: np.ndarray, k_full: int) -> np.ndarray:
    """FedNova spends the budget as fewer local iterations every round.

    Validates like :func:`make_plan`: budgets must satisfy 0 < p_i <= 1
    (NaN rejected) and the full step count must be >= 1.
    """
    p = np.asarray(p, float)
    if p.ndim != 1 or len(p) == 0:
        raise ValueError(f"p must be a non-empty 1-D budget vector, got "
                         f"shape {p.shape}")
    if not ((p > 0) & (p <= 1)).all():     # also rejects NaN
        raise ValueError("budgets must satisfy 0 < p_i <= 1")
    if k_full < 1:
        raise ValueError(f"k_full must be >= 1, got {k_full}")
    return np.maximum(1, np.round(p * k_full)).astype(np.int32)
