"""Minimal structured logging + metric accumulation for training runs."""
from __future__ import annotations

import json
import sys
import time
from dataclasses import dataclass, field
from typing import Any


def log(msg: str, **kv: Any) -> None:
    parts = [f"[repro {time.strftime('%H:%M:%S')}] {msg}"]
    parts += [f"{k}={v}" for k, v in kv.items()]
    print(" ".join(parts), file=sys.stderr, flush=True)


@dataclass
class MetricLogger:
    """Accumulates scalar metric history; can dump JSON for benchmarks."""

    history: dict[str, list[tuple[int, float]]] = field(default_factory=dict)

    def record(self, step: int, **metrics: float) -> None:
        for k, v in metrics.items():
            self.history.setdefault(k, []).append((int(step), float(v)))

    def last(self, key: str) -> float:
        return self.history[key][-1][1]

    def series(self, key: str) -> list[float]:
        return [v for _, v in self.history[key]]

    def best(self, key: str, mode: str = "max") -> float:
        vals = self.series(key)
        return max(vals) if mode == "max" else min(vals)

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.history, f)

    @classmethod
    def load(cls, path: str) -> "MetricLogger":
        with open(path) as f:
            return cls(history=json.load(f))
