"""Pytree utilities used across the framework.

All federated state in this framework is a pytree (nested dicts of
jnp.ndarray); these helpers implement the vector-space algebra the
CC-FedAvg math needs (x + Δ, masked means over a client axis, norms) plus
generic introspection (param counting, dtype casting).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def tree_add(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: PyTree, b: PyTree) -> PyTree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a: PyTree, s) -> PyTree:
    return jax.tree.map(lambda x: x * s, a)


def tree_zeros_like(a: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, a)


def tree_ones_like(a: PyTree) -> PyTree:
    return jax.tree.map(jnp.ones_like, a)


def tree_cast(a: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype), a)


def tree_where(mask, a: PyTree, b: PyTree) -> PyTree:
    """Leafwise ``where`` with a scalar/broadcastable mask."""
    return jax.tree.map(lambda x, y: jnp.where(mask, x, y), a, b)


def tree_dot(a: PyTree, b: PyTree) -> jax.Array:
    leaves = jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b)
    return functools.reduce(jnp.add, jax.tree.leaves(leaves))


def tree_sq_norm(a: PyTree) -> jax.Array:
    leaves = jax.tree.map(lambda x: jnp.sum(jnp.square(x.astype(jnp.float32))), a)
    return functools.reduce(jnp.add, jax.tree.leaves(leaves))


def tree_norm(a: PyTree) -> jax.Array:
    return jnp.sqrt(tree_sq_norm(a))


def tree_cosine(a: PyTree, b: PyTree, eps: float = 1e-12) -> jax.Array:
    """Cosine similarity between two pytrees flattened to vectors."""
    return tree_dot(a, b) / (tree_norm(a) * tree_norm(b) + eps)


def tree_euclidean(a: PyTree, b: PyTree) -> jax.Array:
    return tree_norm(tree_sub(a, b))


def tree_stack(trees: list[PyTree], axis: int = 0) -> PyTree:
    """Stack a list of identical pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=axis), *trees)


def tree_unstack(tree: PyTree, axis: int = 0) -> list[PyTree]:
    leaves, treedef = jax.tree.flatten(tree)
    n = leaves[0].shape[axis]
    out = []
    for i in range(n):
        out.append(treedef.unflatten([jnp.take(l, i, axis=axis) for l in leaves]))
    return out


def tree_index(tree: PyTree, idx) -> PyTree:
    """Index the leading axis of every leaf (e.g. select one client)."""
    return jax.tree.map(lambda x: x[idx], tree)


def tree_broadcast_clients(tree: PyTree, n_clients: int) -> PyTree:
    """Tile a pytree along a new leading client axis."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_clients,) + x.shape), tree
    )


def tree_masked_mean(tree: PyTree, mask: jax.Array, axis: int = 0,
                     eps: float = 1e-12,
                     axis_name: str | None = None) -> PyTree:
    """Mean over the leading (client) axis weighted by ``mask``.

    ``mask`` has shape (n_clients,); leaves have shape (n_clients, ...).
    Equivalent to ``(1/|S_t|) Σ_{i∈S_t}`` in the paper's aggregation (Eq. 3).

    Inside ``shard_map`` the client axis is split across devices; passing the
    mesh ``axis_name`` makes both the numerator and the mask count reduce
    across shards (``lax.psum``), so the mean is over the *global* client
    axis and the result is replicated.
    """
    count = jnp.sum(mask)
    if axis_name is not None:
        count = jax.lax.psum(count, axis_name)
    denom = jnp.maximum(count, eps)

    def _mean(x):
        m = mask.reshape((-1,) + (1,) * (x.ndim - 1)).astype(x.dtype)
        s = jnp.sum(x * m, axis=axis)
        if axis_name is not None:
            s = jax.lax.psum(s, axis_name)
        return s / denom.astype(x.dtype)

    return jax.tree.map(_mean, tree)


def tree_ravel(tree: PyTree) -> tuple[jax.Array, Callable[[jax.Array], PyTree]]:
    """Flatten a pytree to one 1-D vector; returns (flat, unravel_fn).

    The flat layout (leaf traversal order) matches :func:`tree_ravel_clients`
    so per-client (N, P) stacks and the (P,) global vector line up — the
    contract the fused Pallas round kernel relies on.
    """
    leaves, treedef = jax.tree.flatten(tree)
    flat = jnp.concatenate([jnp.ravel(l) for l in leaves]) if leaves \
        else jnp.zeros((0,))

    def unravel(vec: jax.Array) -> PyTree:
        out, off = [], 0
        for l in leaves:
            size = int(np.prod(l.shape)) if l.ndim else 1
            out.append(vec[off: off + size].reshape(l.shape).astype(l.dtype))
            off += size
        return treedef.unflatten(out)

    return flat, unravel


def tree_ravel_clients(tree: PyTree) -> tuple[jax.Array,
                                              Callable[[jax.Array], PyTree]]:
    """Flatten a client-stacked pytree ((N, ...) leaves) to an (N, P) matrix.

    Returns (flat, unravel_fn); ``unravel_fn`` accepts any (M, P) matrix and
    rebuilds the tree with leading axis M (dtypes restored per leaf).
    """
    leaves, treedef = jax.tree.flatten(tree)
    n = leaves[0].shape[0]
    flat = jnp.concatenate([l.reshape(n, -1) for l in leaves], axis=1)

    def unravel(mat: jax.Array) -> PyTree:
        out, off = [], 0
        for l in leaves:
            size = int(np.prod(l.shape[1:])) if l.ndim > 1 else 1
            out.append(mat[:, off: off + size]
                       .reshape((mat.shape[0],) + l.shape[1:])
                       .astype(l.dtype))
            off += size
        return treedef.unflatten(out)

    return flat, unravel


def tree_count_params(tree: PyTree) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(tree)))


def tree_bytes(tree: PyTree) -> int:
    return int(sum(np.prod(l.shape) * l.dtype.itemsize for l in jax.tree.leaves(tree)))


def tree_all_finite(tree: PyTree) -> jax.Array:
    leaves = jax.tree.map(lambda x: jnp.all(jnp.isfinite(x)), tree)
    return functools.reduce(jnp.logical_and, jax.tree.leaves(leaves))


def tree_map_with_path(fn: Callable, tree: PyTree) -> PyTree:
    """Map ``fn(path_str, leaf)`` over a tree; path is '/'-joined dict keys."""

    def _name(entry) -> str:
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.SequenceKey):
            return str(entry.idx)
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return str(entry.name)
        return str(entry)

    def _fn(path, leaf):
        return fn("/".join(_name(p) for p in path), leaf)

    return jax.tree_util.tree_map_with_path(_fn, tree)
