"""Entry point: ``python -m repro`` → the experiment CLI."""
import sys

from repro.api.cli import main

sys.exit(main())
