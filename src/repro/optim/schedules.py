"""Learning-rate schedules as pure ``step -> lr`` callables (jit-safe)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_lr(lr: float):
    def schedule(step):
        return jnp.asarray(lr, jnp.float32)
    return schedule


def cosine_decay_lr(lr: float, total_steps: int, final_frac: float = 0.1):
    def schedule(step):
        t = jnp.clip(step / max(1, total_steps), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.asarray(lr * (final_frac + (1 - final_frac) * cos),
                           jnp.float32)
    return schedule


def warmup_cosine_lr(lr: float, warmup_steps: int, total_steps: int,
                     final_frac: float = 0.1):
    cos = cosine_decay_lr(lr, max(1, total_steps - warmup_steps), final_frac)

    def schedule(step):
        warm = lr * jnp.minimum(1.0, step / max(1, warmup_steps))
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))
    return schedule
