from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    sgd,
    sgd_momentum,
    adamw,
    make_optimizer,
)
from repro.optim.schedules import (  # noqa: F401
    constant_lr,
    cosine_decay_lr,
    warmup_cosine_lr,
)
