"""From-scratch optimizers (the container has no optax).

An :class:`Optimizer` is a pair of pure functions over pytrees:

    state  = opt.init(params)
    params, state = opt.update(params, grads, state, lr=...)

The federated engine vmaps ``update`` over a leading client axis, so all
optimizer state must be a pytree of arrays (no Python-side mutation).

The paper's experiments use plain SGD (§VI-A, lr 0.01); AdamW is provided for
the LLM-scale configs and the beyond-paper runs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.utils.pytree import PyTree


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[PyTree], PyTree]
    update: Callable[..., tuple[PyTree, PyTree]]
    # number of bytes of state per fp32 parameter (for memory accounting)
    state_factor: float = 0.0


def sgd() -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    def update(params, grads, state, lr):
        new_params = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                                  params, grads)
        return new_params, {"count": state["count"] + 1}

    return Optimizer("sgd", init, update, state_factor=0.0)


def sgd_momentum(beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32),
                "mom": jax.tree.map(jnp.zeros_like, params)}

    def update(params, grads, state, lr):
        mom = jax.tree.map(lambda m, g: beta * m + g.astype(m.dtype),
                           state["mom"], grads)
        if nesterov:
            step = jax.tree.map(lambda m, g: g.astype(m.dtype) + beta * m,
                                mom, grads)
        else:
            step = mom
        new_params = jax.tree.map(lambda p, s: p - lr * s.astype(p.dtype),
                                  params, step)
        return new_params, {"count": state["count"] + 1, "mom": mom}

    return Optimizer("sgd_momentum", init, update, state_factor=1.0)


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {
            "count": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(params, grads, state, lr):
        c = state["count"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def _step(p, m_, v_):
            mhat = m_ / bc1
            vhat = v_ / bc2
            upd = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

        new_params = jax.tree.map(_step, params, m, v)
        return new_params, {"count": c, "m": m, "v": v}

    return Optimizer("adamw", init, update, state_factor=2.0)


_REGISTRY: dict[str, Callable[..., Optimizer]] = {
    "sgd": sgd,
    "sgd_momentum": sgd_momentum,
    "adamw": adamw,
}


def make_optimizer(name: str, **kwargs) -> Optimizer:
    if name not in _REGISTRY:
        raise ValueError(f"unknown optimizer {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kwargs)
