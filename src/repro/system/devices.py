"""Per-client device simulator: FLOPs rate, energy budget, background load.

The resource-constrained-FL surveys (arXiv:2307.09182, arXiv:2002.10610)
model clients as devices with a *rate* (how fast a local-training round
runs), an *energy reserve* (drained by training, refilled by harvesting /
charging) and a *time-varying background load* (other apps competing for
the accelerator). CC-FedAvg's ad-hoc mode (§VI-A, Fig. 1b) has each client
consult exactly this state when deciding train-vs-estimate every round —
so the simulator lives *inside* the traced round loop:

* :class:`DeviceProfile` — static per-client parameters, stacked along the
  client axis like everything else in the vectorized engine;
* device **state** — a ``{"energy", "load"}`` dict of per-client rows
  advanced once per round by :func:`advance_devices` (pure JAX, safe under
  ``jit``/``scan``/``shard_map``);
* an energy/cost **ledger** — cumulative per-client accounting
  (:func:`init_ledger`/:func:`update_ledger`) accumulated in-carry so a
  checkpoint resume continues the books bit-identically.

Randomness is *stateless*: background-load noise for client ``i`` in round
``t`` derives from ``fold_in(fold_in(PRNGKey(seed), t), i)``, so a resumed
run, a sharded cohort and a full-federation round all see identical draws
(the same contract the plan masks and cohort sampler follow).

Dynamics (one round):

* ``load'   = clip(rho * load + (1 - rho) * load_mean + jitter * u, 0, 0.95)``
  with ``u ~ U[-1, 1)`` — an AR(1) background load;
* ``energy' = clip(energy - trained * train_cost + harvest, 0, capacity)``;
* a device is *awake* in round ``t`` iff ``t % duty_period < duty_on``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

#: background load never reaches 1.0 — a fully-loaded device would imply an
#: infinite round time in the deadline policy's estimate
_LOAD_MAX = 0.95

#: offset mixed into the stateless-noise seed for arrival-latency jitter so
#: latency draws never collide with the load / budget-policy noise streams
#: (which key on the bare profile seed)
_LATENCY_SALT = 9176

#: per-client array fields of a profile, in ``rows()`` order
PROFILE_ROW_KEYS = ("budget", "flops_rate", "train_cost", "harvest",
                    "capacity", "init_energy", "load_mean", "load_rho",
                    "load_jitter", "duty_period", "duty_on")

#: device-profile kinds accepted by :func:`make_profile` — the spec/CLI
#: ``choices`` derive from this tuple
PROFILE_KINDS = ("budget", "uniform")


@dataclass(frozen=True)
class DeviceProfile:
    """Static per-client device parameters (all arrays are (N,))."""

    budget: jnp.ndarray       # p_i — the paper's computational budgets
    flops_rate: jnp.ndarray   # relative device speed (1.0 = nominal)
    train_cost: jnp.ndarray   # energy drained by one local-training round
    harvest: jnp.ndarray      # energy recovered every round (charging)
    capacity: jnp.ndarray     # energy reserve ceiling
    init_energy: jnp.ndarray  # reserve at round 0
    load_mean: jnp.ndarray    # stationary background load in [0, 0.95]
    load_rho: jnp.ndarray     # AR(1) persistence in [0, 1)
    load_jitter: jnp.ndarray  # load noise amplitude
    duty_period: jnp.ndarray  # (N,) int32 — duty-cycle window length
    duty_on: jnp.ndarray      # (N,) int32 — awake rounds per window
    seed: int = 0             # stateless-noise stream id

    @property
    def n_clients(self) -> int:
        return self.budget.shape[0]

    def rows(self) -> dict:
        """Per-client parameter rows as a plain dict — the gatherable view
        the executors ``jnp.take`` per cohort (mirrors the history rows of
        :mod:`repro.core.strategies`)."""
        return {k: getattr(self, k) for k in PROFILE_ROW_KEYS}


def make_profile(kind: str, p, *, capacity: float = 4.0,
                 init_energy: float = 1.0, harvest_scale: float = 1.0,
                 load_mean: float = 0.0, load_rho: float = 0.7,
                 load_jitter: float = 0.0, duty_period: int = 1,
                 duty_on: int = 1, seed: int = 0) -> DeviceProfile:
    """Build a profile from the paper's budget vector ``p``.

    Kinds:

    * ``"budget"`` — heterogeneity follows p_i: device speed ∝ p_i and
      energy harvest = ``harvest_scale · p_i`` per round, so a client can
      *sustain* training a fraction ≈ p_i of rounds (the energy-reserve
      translation of the paper's computational budget);
    * ``"uniform"`` — every device is nominal-speed and harvests a full
      training round's energy every round (energy never binds).

    Energies are in units of one training round's cost (``train_cost = 1``).
    """
    p = np.asarray(p, float)
    if p.ndim != 1 or len(p) == 0:
        raise ValueError(f"p must be a non-empty 1-D budget vector, got "
                         f"shape {p.shape}")
    if not ((p > 0) & (p <= 1)).all():
        raise ValueError("budgets must satisfy 0 < p_i <= 1")
    n = len(p)
    if kind == "budget":
        flops_rate = p.copy()
        harvest = harvest_scale * p
    elif kind == "uniform":
        flops_rate = np.ones(n)
        harvest = np.ones(n)
    else:
        raise ValueError(f"unknown device profile kind {kind!r}; "
                         f"available: {', '.join(PROFILE_KINDS)}")
    if capacity <= 0:
        raise ValueError(f"capacity must be > 0, got {capacity}")
    if not 0 <= load_mean <= _LOAD_MAX:
        raise ValueError(f"load_mean must be in [0, {_LOAD_MAX}], "
                         f"got {load_mean}")
    if not 0 <= load_rho < 1:
        raise ValueError(f"load_rho must be in [0, 1), got {load_rho}")
    if duty_period < 1 or not 1 <= duty_on <= duty_period:
        raise ValueError(
            f"duty cycle needs 1 <= duty_on <= duty_period, got "
            f"duty_on={duty_on}, duty_period={duty_period}")
    f32 = lambda v: jnp.full((n,), v, jnp.float32)  # noqa: E731
    return DeviceProfile(
        budget=jnp.asarray(p, jnp.float32),
        flops_rate=jnp.asarray(flops_rate, jnp.float32),
        train_cost=f32(1.0),
        harvest=jnp.asarray(harvest, jnp.float32),
        capacity=f32(capacity),
        init_energy=f32(min(init_energy, capacity)),
        load_mean=f32(load_mean),
        load_rho=f32(load_rho),
        load_jitter=f32(load_jitter),
        duty_period=jnp.full((n,), duty_period, jnp.int32),
        duty_on=jnp.full((n,), duty_on, jnp.int32),
        seed=seed)


def edge_scaled_profile(profile: DeviceProfile, assignment, *,
                        flops_scale=None,
                        harvest_scale=None) -> DeviceProfile:
    """Modulate a profile per edge aggregator — heterogeneous gateways.

    Under a two-tier topology (:mod:`repro.core.hierarchy`) the devices
    behind one gateway often share its character: a solar-powered rural
    edge harvests less, an industrial edge hosts faster hardware.
    ``flops_scale`` / ``harvest_scale`` are (E,) per-edge multipliers
    applied to every member client's ``flops_rate`` / ``harvest`` rows;
    ``None`` leaves a row family untouched.
    """
    import dataclasses

    a = np.asarray(assignment, np.int64)
    if a.shape != (profile.n_clients,):
        raise ValueError(
            f"assignment covers {a.shape} clients, profile has "
            f"{profile.n_clients}")
    updates: dict = {}
    for name, scale in (("flops_rate", flops_scale),
                        ("harvest", harvest_scale)):
        if scale is None:
            continue
        s = np.asarray(scale, np.float32)
        # exact length: every edge is nonempty (EdgeTopology invariant),
        # so the edge count is a.max()+1 — a per-CLIENT-length vector here
        # is a caller confusion that must not silently truncate
        if s.ndim != 1 or len(s) != int(a.max()) + 1:
            raise ValueError(
                f"{name} scale needs one entry per edge "
                f"({int(a.max()) + 1}), got shape {s.shape}")
        if not (s > 0).all():
            raise ValueError(f"{name} scale factors must be > 0")
        updates[name] = getattr(profile, name) * jnp.asarray(s[a])
    return dataclasses.replace(profile, **updates) if updates else profile


# ---------------------------------------------------------------------------
# traced state transitions
# ---------------------------------------------------------------------------


def init_device_state(profile: DeviceProfile) -> dict:
    """Round-0 device state: full initial reserve, load at its mean."""
    return {"energy": jnp.asarray(profile.init_energy, jnp.float32),
            "load": jnp.asarray(profile.load_mean, jnp.float32)}


def device_awake(rows: dict, rnd) -> jax.Array:
    """Duty-cycle mask for round ``rnd`` (per-client bool)."""
    return (rnd % rows["duty_period"]) < rows["duty_on"]


def stateless_uniform(seed: int, rnd, client_ids: jax.Array,
                      minval: float = 0.0, maxval: float = 1.0) -> jax.Array:
    """Uniform noise keyed on (seed, round, ABSOLUTE client id) — identical
    whether the client runs in a full round, a sharded cohort or a resumed
    session. The single source of the determinism contract shared by the
    device simulator and the stochastic budget policies."""
    base = jax.random.fold_in(jax.random.PRNGKey(seed), rnd)
    keys = jax.vmap(lambda i: jax.random.fold_in(base, i))(client_ids)
    return jax.vmap(
        lambda k: jax.random.uniform(k, minval=minval, maxval=maxval))(keys)


def advance_devices(rows: dict, dev: dict, trained: jax.Array, rnd,
                    client_ids: jax.Array, seed: int) -> dict:
    """One round of device dynamics: drain trainers, harvest, evolve load.

    ``rows`` are (gathered) profile rows, ``trained`` the sel∧train mask of
    clients that actually spent a training round's energy.
    """
    u = stateless_uniform(seed, rnd, client_ids, minval=-1.0, maxval=1.0)
    load = jnp.clip(
        rows["load_rho"] * dev["load"]
        + (1.0 - rows["load_rho"]) * rows["load_mean"]
        + rows["load_jitter"] * u,
        0.0, _LOAD_MAX)
    energy = jnp.clip(
        dev["energy"] - trained.astype(jnp.float32) * rows["train_cost"]
        + rows["harvest"],
        0.0, rows["capacity"])
    return {"energy": energy, "load": load}


# ---------------------------------------------------------------------------
# arrival-process simulator (asynchronous executor)
# ---------------------------------------------------------------------------


class ArrivalSchedule(NamedTuple):
    """Host-precomputed event tables the async executor scans over.

    All tables are numpy; the Session slices them per span and ships them
    as scan inputs, exactly like the plan masks of the synchronous
    executors.
    """

    dispatch: np.ndarray   # (T, N) bool — client pulls the global model
    deliver: np.ndarray    # (T, N) bool — client's update arrives
    merge: np.ndarray      # (T,) bool — the K-arrival buffer flushes


def simulate_arrivals(profile: DeviceProfile, selection, *,
                      buffer_size: int = 1, latency: float = 0.0,
                      jitter: float = 0.0) -> ArrivalSchedule:
    """Simulate the asynchronous arrival process over a plan's selection.

    Each selected, idle client *dispatches* (pulls the current global
    model and starts local work); its update *delivers* ``L`` rounds
    later, where ``L = rint(latency / (flops_rate · (1 − load)) +
    jitter · u)`` clipped at 0 — slow or heavily-loaded devices deliver
    stale updates. The server buffers arrivals and *merges* whenever at
    least ``buffer_size`` (K) are pending, FedBuff-style. A client keeps
    at most one update in flight and re-dispatches only after its
    previous one has been merged.

    The background-load trajectory replays :func:`advance_devices`
    exactly (load dynamics never depend on training decisions), and the
    latency jitter draws come from :func:`stateless_uniform` under a
    salted seed — the whole schedule is a pure function of (profile,
    selection), so a resumed session recomputes the identical tables.

    With ``latency == 0`` and ``jitter == 0`` every update delivers in
    its dispatch round; at ``buffer_size = 1`` the merge then fires every
    round with arrivals and staleness is identically zero — the
    collapse-to-synchronous configuration the executor matrix pins.
    """
    if not isinstance(buffer_size, int) or buffer_size < 1:
        raise ValueError(
            f"async buffer size K must be an int >= 1, got {buffer_size!r}")
    if latency < 0:
        raise ValueError(f"latency must be >= 0, got {latency}")
    if jitter < 0:
        raise ValueError(f"latency jitter must be >= 0, got {jitter}")
    sel = np.asarray(selection, bool)
    if sel.ndim != 2:
        raise ValueError(
            f"selection must be a (T, N) bool table, got shape {sel.shape}")
    t_rounds, n = sel.shape
    if n != profile.n_clients:
        raise ValueError(f"selection covers {n} clients, profile has "
                         f"{profile.n_clients}")
    if buffer_size > n:
        # each client parks at most one update in the buffer, so a K
        # beyond the federation size can never fill and would deadlock
        raise ValueError(
            f"async buffer size K must be <= n_clients={n} (one pending "
            f"update per client), got {buffer_size}")
    rate = np.asarray(profile.flops_rate, np.float64)
    rho = np.asarray(profile.load_rho, np.float64)
    mean = np.asarray(profile.load_mean, np.float64)
    load_jit = np.asarray(profile.load_jitter, np.float64)
    ids = jnp.arange(n, dtype=jnp.int32)
    load = mean.copy()                     # round-0 load (init_device_state)
    zero_lag = latency == 0.0 and jitter == 0.0
    dispatch = np.zeros((t_rounds, n), bool)
    deliver = np.zeros((t_rounds, n), bool)
    merge = np.zeros((t_rounds,), bool)
    due = np.full((n,), -1, np.int64)      # delivery round of in-flight work
    pending = np.zeros((n,), bool)         # delivered, awaiting the merge
    for t in range(t_rounds):
        d = sel[t] & (due < 0) & ~pending
        dispatch[t] = d
        if zero_lag:
            lag = np.zeros((n,), np.int64)
        else:
            u = np.asarray(stateless_uniform(
                profile.seed + _LATENCY_SALT, t, ids))
            lag = np.maximum(np.rint(
                latency / np.maximum(rate * (1.0 - load), 1e-6)
                + jitter * u).astype(np.int64), 0)
        due = np.where(d, t + lag, due)
        arriving = due == t
        deliver[t] = arriving
        pending |= arriving
        due[arriving] = -1
        if pending.sum() >= buffer_size:
            merge[t] = True
            pending[:] = False
        if not zero_lag and load_jit.any():
            u_load = np.asarray(stateless_uniform(
                profile.seed, t, ids, minval=-1.0, maxval=1.0))
            load = np.clip(rho * load + (1.0 - rho) * mean
                           + load_jit * u_load, 0.0, _LOAD_MAX)
    return ArrivalSchedule(dispatch=dispatch, deliver=deliver, merge=merge)


# ---------------------------------------------------------------------------
# energy/cost ledger (accumulated in-carry)
# ---------------------------------------------------------------------------


def init_ledger(n_clients: int) -> dict:
    """Per-client cumulative books: energy spent, train/estimate rounds."""
    return {"energy_spent": jnp.zeros((n_clients,), jnp.float32),
            "train_rounds": jnp.zeros((n_clients,), jnp.int32),
            "est_rounds": jnp.zeros((n_clients,), jnp.int32)}


def update_ledger(ledger: dict, rows: dict, sel_mask: jax.Array,
                  train_mask: jax.Array) -> dict:
    """Accumulate one round (pure; safe inside scan/shard_map)."""
    trained = (sel_mask & train_mask)
    estimated = (sel_mask & ~train_mask)
    return {
        "energy_spent": ledger["energy_spent"]
        + trained.astype(jnp.float32) * rows["train_cost"],
        "train_rounds": ledger["train_rounds"] + trained.astype(jnp.int32),
        "est_rounds": ledger["est_rounds"] + estimated.astype(jnp.int32),
    }
