"""Simulated client-device runtime (energy, load, duty cycles).

The budget-policy engine (:mod:`repro.core.budget`) decides train-vs-
estimate *inside* the traced round loop; this package supplies the device
model those decisions condition on: per-client FLOPs rates, energy
reserves with harvesting, stochastic background load and duty cycles —
all advanced as pure-JAX state in the round carry.
"""
from repro.system.devices import (  # noqa: F401
    DeviceProfile,
    advance_devices,
    device_awake,
    init_device_state,
    init_ledger,
    make_profile,
    stateless_uniform,
    update_ledger,
)
