"""Pallas TPU kernel for the fused CC-FedAvg server round update.

This is the paper's own hot spot made into one HBM pass. Per parameter
element, given the stacked client results, Algorithm 1 lines 12/15/20/21 do:

    Δ_t^i  = train_i ? (x_K^i − x_t) : Δ_{t−1}^i      (train or estimate)
    Δ_t    = (1/|S_t|) Σ_{i∈S_t} sel_i · Δ_t^i         (aggregate)
    x_{t+1} = x_t + Δ_t                                 (global update)

Done naively this reads/writes each model-sized array several times
(compute trained delta, select, mean, add). The kernel streams one tile of
every operand through VMEM and produces both outputs (new per-client deltas
+ new global params) in a single pass — the op is purely HBM-bandwidth
bound, so fewer passes is the whole game on TPU.

Shapes: locals_, deltas: (N, P) — N clients, P flat params (tile-aligned);
globals_: (P,); train/sel masks: (N,) in SMEM (scalar-prefetch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _cc_kernel(masks_ref, locals_ref, deltas_ref, global_ref,
               new_deltas_ref, new_global_ref, *, n_clients: int):
    g = global_ref[...].astype(jnp.float32)          # (1, block)
    acc = jnp.zeros_like(g)
    denom = 1e-9
    for i in range(n_clients):                        # N is small & static
        train_i = masks_ref[0, i]
        sel_i = masks_ref[1, i]
        trained = locals_ref[i].astype(jnp.float32) - g[0]
        est = deltas_ref[i].astype(jnp.float32)
        d_i = jnp.where(train_i > 0, trained, est)
        new_deltas_ref[i, :] = d_i.astype(new_deltas_ref.dtype)
        acc = acc + sel_i * d_i[None]
        denom = denom + sel_i
    new_global_ref[...] = (g + acc / denom).astype(new_global_ref.dtype)


def cc_delta_update_fwd(locals_, deltas, globals_, train_mask, sel_mask, *,
                        block: int = 65536, interpret: bool = False):
    """Fused round update.

    locals_: (N, P) client post-training params; deltas: (N, P) stored Δ;
    globals_: (P,); masks: (N,). Returns (new_deltas (N, P), new_global (P,)).
    """
    n, p = locals_.shape
    block = min(block, p)
    while p % block:
        block -= 1
    masks = jnp.stack([train_mask.astype(jnp.float32),
                       sel_mask.astype(jnp.float32)])
    kernel = functools.partial(_cc_kernel, n_clients=n)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(p // block,),
        in_specs=[
            pl.BlockSpec((n, block), lambda ip, masks: (0, ip)),
            pl.BlockSpec((n, block), lambda ip, masks: (0, ip)),
            pl.BlockSpec((1, block), lambda ip, masks: (0, ip)),
        ],
        out_specs=[
            pl.BlockSpec((n, block), lambda ip, masks: (0, ip)),
            pl.BlockSpec((1, block), lambda ip, masks: (0, ip)),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n, p), deltas.dtype),
            jax.ShapeDtypeStruct((1, p), globals_.dtype),
        ],
        interpret=interpret,
    )(masks, locals_, deltas, globals_.reshape(1, -1))
    return out[0], out[1].reshape(-1)
