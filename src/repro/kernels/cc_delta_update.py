"""Pallas TPU kernel for the fused CC-FedAvg server round update.

This is the paper's own hot spot made into one HBM pass. Per parameter
element, given the stacked client results, Algorithm 1 lines 12/15/20/21 do:

    Δ_t^i  = train_i ? (x_K^i − x_t) : Δ̂_t^i           (train or estimate)
    Δ_t    = (1/|S_t|) Σ_{i∈S_t} sel_i · Δ_t^i         (aggregate)
    x_{t+1} = x_t + Δ_t                                 (global update)

Done naively this reads/writes each model-sized array several times
(compute trained delta, select, mean, add). The kernel streams one tile of
every operand through VMEM and produces both outputs (new per-client deltas
+ new global params) in a single pass — the op is purely HBM-bandwidth
bound, so fewer passes is the whole game on TPU.

The kernel is parameterized by a per-strategy *epilogue*
(:class:`repro.core.strategies.FusedEpilogue`): every strategy's estimate
is affine in the stored Δ and the stale-model delta, so per-client f32
coefficient rows — computed outside in O(N) — specialize one kernel body
to the whole registry:

    est_i   = e_replay_i·Δ_{t−1}^i + e_stale_i·stale_i
    d_i     = train_i ? (x_K^i − x_t) : est_i
    Δ_t^i   = upd_i ? (x_K^i − x_t) : store_scale_i·Δ_{t−1}^i
    x_{t+1} = x_t + (Σ agg_w_i·d_i / denom) · post_scale

Shapes: locals_, deltas (and the optional stale): (N, P) — N clients,
P flat params; globals_: (P,); coefficient rows: (N,) f32 in SMEM
(scalar-prefetch). P is zero-padded up to a lane-aligned block multiple
and sliced back, so awkward (prime-ish) P never degrades the block size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_LANE = 128


def _block_and_pad(p: int, block: int) -> tuple[int, int]:
    """Lane-aligned block plus the padded P it evenly divides."""
    p_lane = -(-p // _LANE) * _LANE
    block = max(_LANE, min(block - block % _LANE, p_lane))
    return block, -(-p // block) * block


def _pad_cols(x, p_pad: int):
    p = x.shape[-1]
    if p == p_pad:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, p_pad - p)]
    return jnp.pad(x, widths)


def _cc_kernel(rows_ref, extras_ref, locals_ref, deltas_ref, *rest,
               n_clients: int, has_stale: bool):
    if has_stale:
        stale_ref, global_ref, new_deltas_ref, new_global_ref = rest
    else:
        global_ref, new_deltas_ref, new_global_ref = rest
    g = global_ref[...].astype(jnp.float32)          # (1, block)
    acc = jnp.zeros_like(g)
    for i in range(n_clients):                        # N is small & static
        train_i = rows_ref[0, i]
        upd_i = rows_ref[1, i]
        w_i = rows_ref[2, i]
        trained = locals_ref[i].astype(jnp.float32) - g[0]
        d_old = deltas_ref[i].astype(jnp.float32)
        est = rows_ref[3, i] * d_old
        if has_stale:
            est = est + rows_ref[4, i] * stale_ref[i].astype(jnp.float32)
        d_i = jnp.where(train_i > 0, trained, est)
        new_deltas_ref[i, :] = jnp.where(
            upd_i > 0, trained, rows_ref[5, i] * d_old
        ).astype(new_deltas_ref.dtype)
        acc = acc + w_i * d_i[None]
    new_global_ref[...] = (
        g + (acc / extras_ref[0]) * extras_ref[1]
    ).astype(new_global_ref.dtype)


def cc_epilogue_update_fwd(locals_, deltas, globals_, train, upd, agg_w,
                           e_replay, e_stale, store_scale, denom, post_scale,
                           stale=None, *, block: int = 65536,
                           interpret: bool = False):
    """Strategy-parameterized fused round update.

    locals_, deltas (and stale, when given): (N, P); globals_: (P,);
    train/upd/agg_w/e_replay/e_stale/store_scale: (N,); denom/post_scale:
    scalars. Returns (new_deltas (N, P), new_global (P,)).
    """
    n, p = locals_.shape
    block, p_pad = _block_and_pad(p, block)
    rows = jnp.stack([train.astype(jnp.float32), upd.astype(jnp.float32),
                      agg_w.astype(jnp.float32),
                      e_replay.astype(jnp.float32),
                      e_stale.astype(jnp.float32),
                      store_scale.astype(jnp.float32)])
    extras = jnp.stack([jnp.asarray(denom, jnp.float32),
                        jnp.asarray(post_scale, jnp.float32)])
    has_stale = stale is not None
    kernel = functools.partial(_cc_kernel, n_clients=n, has_stale=has_stale)
    mat_spec = pl.BlockSpec((n, block), lambda ip, rows, extras: (0, ip))
    vec_spec = pl.BlockSpec((1, block), lambda ip, rows, extras: (0, ip))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(p_pad // block,),
        in_specs=[mat_spec, mat_spec] + ([mat_spec] if has_stale else [])
        + [vec_spec],
        out_specs=[mat_spec, vec_spec],
    )
    operands = [_pad_cols(locals_, p_pad), _pad_cols(deltas, p_pad)]
    if has_stale:
        operands.append(_pad_cols(stale, p_pad))
    operands.append(_pad_cols(globals_.reshape(1, -1), p_pad))
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n, p_pad), deltas.dtype),
            jax.ShapeDtypeStruct((1, p_pad), globals_.dtype),
        ],
        interpret=interpret,
    )(rows, extras, *operands)
    return out[0][:, :p], out[1].reshape(-1)[:p]


def cc_delta_update_fwd(locals_, deltas, globals_, train_mask, sel_mask, *,
                        block: int = 65536, interpret: bool = False):
    """Legacy fused round update (bit-compatible specialization).

    locals_: (N, P) client post-training params; deltas: (N, P) stored Δ;
    globals_: (P,); masks: (N,). Returns (new_deltas (N, P), new_global (P,)).

    The identity epilogue reproduces the original kernel bit-for-bit:
    e_replay=1 and store_scale=1 multiply exactly, post_scale=1 multiplies
    exactly, and denom = 1e-9 + Σ sel matches the old sequential mask
    accumulation (0/1 sums are exact in f32; the 1e-9 rounds away
    identically once any client is selected).
    """
    n, _ = locals_.shape
    train = train_mask.astype(jnp.float32)
    sel = sel_mask.astype(jnp.float32)
    ones = jnp.ones((n,), jnp.float32)
    return cc_epilogue_update_fwd(
        locals_, deltas, globals_, train, train, sel, ones,
        jnp.zeros((n,), jnp.float32), ones, 1e-9 + jnp.sum(sel),
        jnp.ones((), jnp.float32), block=block, interpret=interpret)
