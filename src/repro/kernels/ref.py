"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        scale: float | None = None):
    """q: (B, H, Sq, hd); k, v: (B, Kv, Sk, hd). Naive fp32 attention."""
    b, h, sq, hd = q.shape
    kv, sk = k.shape[1], k.shape[2]
    g = h // kv
    scale = hd ** -0.5 if scale is None else scale
    qf = q.astype(jnp.float32).reshape(b, kv, g, sq, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qf, kf) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, vf)
    return o.reshape(b, h, sq, hd).astype(q.dtype)


def rglru_scan_ref(a, b, h0):
    """Sequential reference: h_t = a_t h_{t−1} + b_t. (B, S, D)."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h0,
                         (a.transpose(1, 0, 2), b.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)


def slstm_scan_ref(wx, r, h0, c0, n0, m0):
    """Sequential sLSTM reference matching the kernel's gate math.

    wx: (B, S, 4D) with b_in folded in; r: (4, H, hd, hd).
    Returns (hs (B, S, D), (h, c, n, m))."""
    b, s, d4 = wx.shape
    d = d4 // 4
    _, h_heads, hd, _ = r.shape
    rf = r.astype(jnp.float32)

    def step(state, wx_t):
        h, c, n, m = state
        hh = h.reshape(b, h_heads, hd)
        rec = jnp.einsum("bhd,ghde->gbhe", hh, rf).reshape(4, b, d)
        pre = wx_t.astype(jnp.float32).reshape(b, 4, d).transpose(1, 0, 2) \
            + rec
        z = jnp.tanh(pre[0])
        i_ = pre[1]
        lf = jax.nn.log_sigmoid(pre[2])
        o = jax.nn.sigmoid(pre[3])
        m_new = jnp.maximum(lf + m, i_)
        iexp = jnp.exp(i_ - m_new)
        fexp = jnp.exp(lf + m - m_new)
        c_new = fexp * c + iexp * z
        n_new = jnp.maximum(fexp * n + iexp, 1e-6)
        h_new = o * c_new / n_new
        return (h_new, c_new, n_new, m_new), h_new

    state, hs = jax.lax.scan(step, (h0, c0, n0, m0),
                             wx.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2), state


def cc_delta_update_ref(locals_, deltas, globals_, train_mask, sel_mask):
    """Unfused reference of the CC round update (Alg. 1 lines 12/15/20/21)."""
    g = globals_.astype(jnp.float32)
    trained = locals_.astype(jnp.float32) - g[None]
    d = jnp.where(train_mask[:, None] > 0, trained,
                  deltas.astype(jnp.float32))
    selw = sel_mask.astype(jnp.float32)[:, None]
    agg = jnp.sum(d * selw, axis=0) / jnp.maximum(jnp.sum(selw), 1e-9)
    return d.astype(deltas.dtype), (g + agg).astype(globals_.dtype)


def cc_epilogue_update_ref(locals_, deltas, globals_, train, upd, agg_w,
                           e_replay, e_stale, store_scale, denom, post_scale,
                           stale=None):
    """Sequential reference of the epilogue-parameterized round update.

    Unrolls the client loop in the same order and with the same (1, P)
    shapes as the Pallas kernel body, so under ``jax.jit`` (where XLA's
    mul+add contraction decisions match the traced kernel) it is
    bit-exact against the interpret-mode kernel."""
    g = globals_.astype(jnp.float32).reshape(1, -1)
    if stale is None:
        stale = jnp.zeros_like(locals_, jnp.float32)
    acc = jnp.zeros_like(g)
    new_rows = []
    trainf = train.astype(jnp.float32)
    updf = upd.astype(jnp.float32)
    wf = agg_w.astype(jnp.float32)
    erf = e_replay.astype(jnp.float32)
    esf = e_stale.astype(jnp.float32)
    ssf = store_scale.astype(jnp.float32)
    for i in range(locals_.shape[0]):
        trained = locals_[i].astype(jnp.float32) - g[0]
        d_old = deltas[i].astype(jnp.float32)
        est = erf[i] * d_old + esf[i] * stale[i].astype(jnp.float32)
        d_i = jnp.where(trainf[i] > 0, trained, est)
        new_rows.append(jnp.where(updf[i] > 0, trained, ssf[i] * d_old
                                  ).astype(deltas.dtype))
        acc = acc + wf[i] * d_i[None]
    new_global = g + (acc / jnp.asarray(denom, jnp.float32)) \
        * jnp.asarray(post_scale, jnp.float32)
    return (jnp.stack(new_rows),
            new_global.reshape(-1).astype(globals_.dtype))


def cc_delta_update_q8_ref(locals_, payload, scales, globals_, train, upd,
                           agg_w, e_replay, e_stale, store_scale, denom,
                           post_scale, stale=None):
    """Sequential quantized tree-ops reference of the q8 round update.

    Same elementwise dequant→select→requant math as the q8 kernel and the
    same unrolled client-order f32 accumulation, so under ``jax.jit`` the
    Pallas-interpret kernel is pinned *bit-exact* against this."""
    from repro.kernels.cc_delta_update_q8 import q8_new_scales

    g = globals_.astype(jnp.float32).reshape(1, -1)
    if stale is None:
        stale = jnp.zeros_like(locals_, jnp.float32)
    updf = upd.astype(jnp.float32)
    new_scales, inv = q8_new_scales(locals_, globals_, scales, updf,
                                    store_scale)
    acc = jnp.zeros_like(g)
    new_rows = []
    trainf = train.astype(jnp.float32)
    wf = agg_w.astype(jnp.float32)
    erf = e_replay.astype(jnp.float32)
    esf = e_stale.astype(jnp.float32)
    scf = scales.astype(jnp.float32)
    for i in range(locals_.shape[0]):
        q = payload[i].astype(jnp.float32)
        deq = q * scf[i]
        trained = locals_[i].astype(jnp.float32) - g[0]
        est = erf[i] * deq + esf[i] * stale[i].astype(jnp.float32)
        d_i = jnp.where(trainf[i] > 0, trained, est)
        newq = jnp.clip(jnp.round(trained * inv[i]), -127.0, 127.0)
        new_rows.append(jnp.where(updf[i] > 0, newq, q).astype(jnp.int8))
        acc = acc + wf[i] * d_i[None]
    new_global = (g + (acc / jnp.asarray(denom, jnp.float32))
                  * jnp.asarray(post_scale, jnp.float32))
    return (jnp.stack(new_rows), new_scales,
            new_global.reshape(-1).astype(globals_.dtype))
