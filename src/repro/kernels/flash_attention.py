"""Pallas TPU flash-attention kernel (causal / sliding-window, GQA).

TPU-native adaptation of the paper-adjacent attention hot spot: the online-
softmax tiling lives in VMEM, Q/K tiles are MXU-shaped (multiples of
(8, 128)), and the (m, l, acc) running state persists in VMEM scratch across
the innermost (key-block) grid dimension — the TPU grid is sequential over
the last axis, which replaces the CUDA-style thread-block loop.

Layout: q (B, H, Sq, hd); k, v (B, Kv, Sk, hd); GQA maps query head h to
key/value head h // (H // Kv) in the BlockSpec index map (no materialized
head broadcast).

Out-of-band (fully masked) key blocks are predicated off with ``pl.when`` —
for causal masks this skips the upper-triangular half of the grid's work,
and for sliding windows everything outside the band.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MASK_VALUE = -1.0e30
_LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, n_kb: int,
                  causal: bool, window: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, MASK_VALUE)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k
    # block-level band test: any (q, k) pair in range?
    needed = jnp.bool_(True)
    if causal:
        needed &= k_start <= q_start + block_q - 1
    if window > 0:
        needed &= (k_start + block_k - 1) > (q_start - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)              # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = jnp.bool_(True)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, MASK_VALUE)

        m_prev = m_scr[...]                                  # (bq, LANES)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1)[:, None]                  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, :1])                        # (bq, bk)
        l_new = l_prev * corr + jnp.broadcast_to(
            jnp.sum(p, axis=1)[:, None], l_prev.shape)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)              # (bq, hd)
        acc_scr[...] = acc_scr[...] * corr[:, :1] + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ik == n_kb - 1)
    def _finalize():
        l = l_scr[...][:, :1]
        o_ref[0, 0, ...] = (acc_scr[...]
                            / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        scale: float | None = None, block_q: int = 128,
                        block_k: int = 128, interpret: bool = False):
    """q: (B, H, Sq, hd); k, v: (B, Kv, Sk, hd). Returns (B, H, Sq, hd)."""
    b, h, sq, hd = q.shape
    kv, sk = k.shape[1], k.shape[2]
    g = h // kv
    scale = hd ** -0.5 if scale is None else scale
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    n_qb, n_kb = sq // block_q, sk // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_kb=n_kb, causal=causal, window=window)

    return pl.pallas_call(
        kernel,
        grid=(b, h, n_qb, n_kb),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda ib, ih, iq, ik, g=g: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda ib, ih, iq, ik, g=g: (ib, ih // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            # (m, l) carried across key blocks; lane-replicated for TPU tiling
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
