"""Pallas TPU kernel for the sLSTM recurrence (xLSTM, arXiv:2405.04517).

The XLA lowering of the sLSTM time scan re-reads the recurrent gate
matrices R (4, H, hd, hd) from HBM every timestep — at prefill_32k that is
S·layers ≈ 196k reads of 2.4 MB ≈ 460 GB of HBM traffic per device, which
makes xlstm-125m/prefill_32k the worst roofline point of the whole fleet
(§Perf pair 2). The TPU-native fix: R easily fits VMEM, so the kernel
pins R (and the running state h/c/n/m) in VMEM across a whole time chunk —
HBM traffic collapses to the wx stream + the hs output.

Grid: (batch, time-chunks), time innermost (sequential on TPU). Gate math
is the stabilized exponential-gating form of the reference
(:func:`repro.models.xlstm._slstm_step`), evaluated in f32 on the VPU; the
per-head R matmuls hit the MXU via dot_general batched over heads.

Shapes: wx (B, S, 4D) — input projections including b_in; r (4, H, hd, hd);
state h/c/n/m (B, D). Outputs: hs (B, S, D) + final (h, c, n, m).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _slstm_kernel(wx_ref, r_ref, h0_ref, c0_ref, n0_ref, m0_ref,
                  hs_ref, hT_ref, cT_ref, nT_ref, mT_ref,
                  h_scr, c_scr, n_scr, m_scr, *,
                  chunk: int, n_chunks: int, n_heads: int, head_dim: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = h0_ref[...]
        c_scr[...] = c0_ref[...]
        n_scr[...] = n0_ref[...]
        m_scr[...] = m0_ref[...]

    r = r_ref[...].astype(jnp.float32)            # (4, H, hd, hd)
    d = n_heads * head_dim

    def step(t, state):
        h, c, n, m = state                        # each (1, D) f32
        hh = h.reshape(n_heads, head_dim)
        # rec[g,h,e] = Σ_d hh[h,d]·r[g,h,d,e]  (einsum bhd,ghde->ghe)
        rec = jax.lax.dot_general(
            r, hh, (((2,), (1,)), ((1,), (0,))),
            preferred_element_type=jnp.float32)   # (H, 4, hd)
        rec = rec.transpose(1, 0, 2).reshape(4, d)
        wx_t = wx_ref[0, t].astype(jnp.float32)   # (4D,)
        pre = wx_t.reshape(4, d) + rec
        z = jnp.tanh(pre[0])[None]
        i_ = pre[1][None]
        lf = jax.nn.log_sigmoid(pre[2])[None]
        o = jax.nn.sigmoid(pre[3])[None]
        m_new = jnp.maximum(lf + m, i_)
        iexp = jnp.exp(i_ - m_new)
        fexp = jnp.exp(lf + m - m_new)
        c_new = fexp * c + iexp * z
        n_new = jnp.maximum(fexp * n + iexp, 1e-6)
        h_new = o * c_new / n_new
        hs_ref[0, t, :] = h_new[0]
        return h_new, c_new, n_new, m_new

    h, c, n, m = jax.lax.fori_loop(
        0, chunk, step, (h_scr[...], c_scr[...], n_scr[...], m_scr[...]))
    h_scr[...], c_scr[...], n_scr[...], m_scr[...] = h, c, n, m

    @pl.when(ic == n_chunks - 1)
    def _finalize():
        hT_ref[...] = h
        cT_ref[...] = c
        nT_ref[...] = n
        mT_ref[...] = m


def slstm_scan_fwd(wx, r, h0, c0, n0, m0, *, chunk: int = 256,
                   interpret: bool = False):
    """wx: (B, S, 4D) f32; r: (4, H, hd, hd); state: (B, D) each.

    Returns (hs (B, S, D), (hT, cT, nT, mT)).
    """
    b, s, d4 = wx.shape
    d = d4 // 4
    _, h_heads, hd, _ = r.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    n_chunks = s // chunk

    kernel = functools.partial(
        _slstm_kernel, chunk=chunk, n_chunks=n_chunks, n_heads=h_heads,
        head_dim=hd)
    state_spec = pl.BlockSpec((1, d), lambda ib, ic: (ib, 0))
    out = pl.pallas_call(
        kernel,
        grid=(b, n_chunks),                       # time innermost
        in_specs=[
            pl.BlockSpec((1, chunk, d4), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((4, h_heads, hd, hd), lambda ib, ic: (0, 0, 0, 0)),
            state_spec, state_spec, state_spec, state_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, d), lambda ib, ic: (ib, ic, 0)),
            state_spec, state_spec, state_spec, state_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32) for _ in range(4)],
        interpret=interpret,
    )(wx, r, h0, c0, n0, m0)
    hs, hT, cT, nT, mT = out
    return hs, (hT, cT, nT, mT)
