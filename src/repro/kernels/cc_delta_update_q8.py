"""Quantized (int8) Pallas kernel for the fused CC-FedAvg round update.

Same round semantics as :mod:`repro.kernels.cc_delta_update`, but the
O(N·P) client Δ history lives in int8 with one f32 scale per client row
(symmetric quantization, q = clip(round(x/scale), ±127), matching
:func:`repro.core.compress.quantize_tree`). One VMEM pass per tile:

    deq_i   = payload_i · scale_i                      (dequantize)
    est_i   = e_replay_i·deq_i + e_stale_i·stale_i     (strategy estimate)
    d_i     = train_i ? (x_K^i − x_t) : est_i
    x_{t+1} = x_t + (Σ agg_w_i·d_i / denom) · post_scale
    q'_i    = upd_i ? clip(round((x_K^i − x_t)·inv_scale'_i)) : payload_i

The new per-row scales are computed *outside* the kernel in O(N) row
maxima: updating rows requantize against max|x_K^i − x_t|, rows that keep
their history only have their scale multiplied by the strategy's
store_scale — the int8 payload is copied through unchanged, so a skipping
client's decay (cc_decay's γ) costs no extra quantization error.

Payoff: the history gather/scatter and the aggregation pass move 4× fewer
bytes, and replay-style strategies (needs_stale=False — every strategy
except s2/ccc) never read the (N, P) f32 prev_local at all, so the carry
drops it entirely.

On CPU the public wrapper (:func:`repro.kernels.ops.cc_delta_update_q8`)
dispatches to :func:`cc_delta_update_q8_jnp`, a vectorized XLA path with
bit-identical payload/scale outputs (only the f32 summation order of the
global update differs); the Pallas path compiles to Mosaic on TPU and is
pinned bit-exact against the sequential reference in
:func:`repro.kernels.ref.cc_delta_update_q8_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.cc_delta_update import _block_and_pad, _pad_cols

_QMAX = 127.0

#: chunk length for the accumulator-style row maxima, and the column count
#: above which it replaces the plain ``jnp.max``. XLA:CPU lowers a plain
#: axis-1 reduce to a scalar loop (~1.5 GB/s on one core); an explicit
#: elementwise ``maximum`` accumulator over (1, chunk) slices vectorizes
#: (~2×), and rows with upd=0 skip the pass entirely — their maxima are
#: discarded by the ``where`` anyway. max is exactly associative and
#: commutative, so every accumulation order gives bit-identical scales.
_MX_CHUNK = 16384
_MX_MIN_COLS = 2 * _MX_CHUNK


def _row_maxima(locals_, globals_, upd):
    """Per-row max|locals − globals|, exactly equal to
    ``jnp.max(|x − g|, axis=1)`` on every row with upd > 0 (rows with
    upd = 0 may return a partial maximum — callers mask them out)."""
    x = locals_.astype(jnp.float32)
    g = globals_.astype(jnp.float32)
    n, p = x.shape
    if p < _MX_MIN_COLS:
        return jnp.max(jnp.abs(x - g[None]), axis=1)
    c = p // _MX_CHUNK
    tail = p - c * _MX_CHUNK
    tail_mx = (jnp.max(jnp.abs(x[:, c * _MX_CHUNK:]
                               - g[None, c * _MX_CHUNK:]), axis=1)
               if tail else jnp.zeros((n,), jnp.float32))

    def row_body(i, acc):
        def compute(_):
            def chunk_body(j, a):
                xc = lax.dynamic_slice(x, (i, j * _MX_CHUNK),
                                       (1, _MX_CHUNK))[0]
                gc = lax.dynamic_slice(g, (j * _MX_CHUNK,), (_MX_CHUNK,))
                return jnp.maximum(a, jnp.abs(xc - gc))
            part = lax.fori_loop(0, c, chunk_body,
                                 jnp.zeros((_MX_CHUNK,), jnp.float32))
            return jnp.max(part)
        m = lax.cond(upd[i] > 0, compute, lambda _: jnp.float32(0.0), None)
        return acc.at[i].set(m)

    mx = lax.fori_loop(0, n, row_body, jnp.zeros((n,), jnp.float32))
    return jnp.maximum(mx, tail_mx)


def q8_new_scales(locals_, globals_, scales, upd, store_scale):
    """New per-row scales + inverse, computed outside the kernel in O(N·P)
    row maxima (one read pass over updating rows' locals)."""
    trained_mx = _row_maxima(locals_, globals_, upd)
    updated = jnp.maximum(trained_mx, 1e-12) / _QMAX
    kept = scales * store_scale.astype(jnp.float32)
    new_scales = jnp.where(upd > 0, updated, kept)
    inv = jnp.where(upd > 0, 1.0 / jnp.maximum(new_scales, 1e-30), 0.0)
    return new_scales, inv


def _cc_q8_kernel(rows_ref, extras_ref, locals_ref, payload_ref, *rest,
                  n_clients: int, has_stale: bool):
    if has_stale:
        stale_ref, global_ref, new_payload_ref, new_global_ref = rest
    else:
        global_ref, new_payload_ref, new_global_ref = rest
    g = global_ref[...].astype(jnp.float32)          # (1, block)
    acc = jnp.zeros_like(g)
    for i in range(n_clients):                        # N is small & static
        train_i = rows_ref[0, i]
        upd_i = rows_ref[1, i]
        w_i = rows_ref[2, i]
        q = payload_ref[i].astype(jnp.float32)
        deq = q * rows_ref[5, i]                      # old scale
        trained = locals_ref[i].astype(jnp.float32) - g[0]
        est = rows_ref[3, i] * deq
        if has_stale:
            est = est + rows_ref[4, i] * stale_ref[i].astype(jnp.float32)
        d_i = jnp.where(train_i > 0, trained, est)
        newq = jnp.clip(jnp.round(trained * rows_ref[6, i]), -_QMAX, _QMAX)
        new_payload_ref[i, :] = jnp.where(upd_i > 0, newq, q
                                          ).astype(jnp.int8)
        acc = acc + w_i * d_i[None]
    new_global_ref[...] = (
        g + (acc / extras_ref[0]) * extras_ref[1]
    ).astype(new_global_ref.dtype)


def cc_delta_update_q8_fwd(locals_, payload, scales, globals_, train, upd,
                           agg_w, e_replay, e_stale, store_scale, denom,
                           post_scale, stale=None, *, block: int = 65536,
                           interpret: bool = False):
    """Fused int8 round update (Pallas path).

    locals_: (N, P) f32; payload: (N, P) int8; scales: (N,) f32 per-row
    quantization scales; globals_: (P,); coefficient rows: (N,); denom /
    post_scale: scalars. Returns (new_payload (N, P) int8, new_scales (N,),
    new_global (P,)).
    """
    n, p = locals_.shape
    block, p_pad = _block_and_pad(p, block)
    updf = upd.astype(jnp.float32)
    new_scales, inv = q8_new_scales(locals_, globals_, scales, updf,
                                    store_scale)
    rows = jnp.stack([train.astype(jnp.float32), updf,
                      agg_w.astype(jnp.float32),
                      e_replay.astype(jnp.float32),
                      e_stale.astype(jnp.float32),
                      scales.astype(jnp.float32), inv])
    extras = jnp.stack([jnp.asarray(denom, jnp.float32),
                        jnp.asarray(post_scale, jnp.float32)])
    has_stale = stale is not None
    kernel = functools.partial(_cc_q8_kernel, n_clients=n,
                               has_stale=has_stale)
    mat_spec = pl.BlockSpec((n, block), lambda ip, rows, extras: (0, ip))
    vec_spec = pl.BlockSpec((1, block), lambda ip, rows, extras: (0, ip))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(p_pad // block,),
        in_specs=[mat_spec, mat_spec] + ([mat_spec] if has_stale else [])
        + [vec_spec],
        out_specs=[mat_spec, vec_spec],
    )
    operands = [_pad_cols(locals_, p_pad), _pad_cols(payload, p_pad)]
    if has_stale:
        operands.append(_pad_cols(stale, p_pad))
    operands.append(_pad_cols(globals_.reshape(1, -1), p_pad))
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n, p_pad), jnp.int8),
            jax.ShapeDtypeStruct((1, p_pad), globals_.dtype),
        ],
        interpret=interpret,
    )(rows, extras, *operands)
    return out[0][:, :p], new_scales, out[1].reshape(-1)[:p]


def _weighted_int8_rowsum(payload, w):
    """Σ_i w_i · payload_i as f32 without materializing the (N, P) f32
    cast: per-row axpy with zero-weight rows (every training client)
    skipped. Sum order differs from the vectorized formula — callers only
    use this on the allclose-pinned global, never on payload/scales."""
    n, p = payload.shape

    def body(i, acc):
        def add(a):
            row = lax.dynamic_slice(payload, (i, 0), (1, p))[0]
            return a + w[i] * row.astype(jnp.float32)
        return lax.cond(w[i] != 0, add, lambda a: a, acc)

    return lax.fori_loop(0, n, body, jnp.zeros((p,), jnp.float32))


def cc_delta_update_q8_jnp(locals_, payload, scales, globals_, train, upd,
                           agg_w, e_replay, e_stale, store_scale, denom,
                           post_scale, stale=None):
    """Vectorized XLA path (the CPU implementation of the same op).

    Payload and scale outputs are bit-identical to the Pallas path — the
    elementwise dequant/requant math is the same; only the f32 summation
    order of the aggregated global differs. The aggregation is decomposed
    into matvecs (Σw·(x−g) = w@x − Σw·g etc.): XLA:CPU's reduce loops run
    far below memory bandwidth on the (N, P) masked sum, while gemv and
    the elementwise requant pass stream near the roofline.
    """
    g = globals_.astype(jnp.float32)
    updf = upd.astype(jnp.float32)
    new_scales, inv = q8_new_scales(locals_, globals_, scales, updf,
                                    store_scale)
    trained = locals_.astype(jnp.float32) - g[None]
    tmask = (train > 0).astype(jnp.float32)
    aw = agg_w.astype(jnp.float32)
    wt = aw * tmask                                   # trained-delta rows
    wq = aw * (1.0 - tmask) * e_replay.astype(jnp.float32) * scales
    agg = (wt @ locals_.astype(jnp.float32) - jnp.sum(wt) * g
           + _weighted_int8_rowsum(payload, wq))
    if stale is not None:
        ws = aw * (1.0 - tmask) * e_stale.astype(jnp.float32)
        agg = agg + ws @ stale.astype(jnp.float32)
    new_global = (g + (agg / denom) * post_scale).astype(globals_.dtype)
    newq = jnp.clip(jnp.round(trained * inv[:, None]), -_QMAX, _QMAX)
    new_payload = jnp.where(updf[:, None] > 0, newq,
                            payload.astype(jnp.float32)).astype(jnp.int8)
    return new_payload, new_scales, new_global
