"""Pallas TPU kernel for the RG-LRU linear recurrence
``h_t = a_t ⊙ h_{t−1} + b_t``  (gates precomputed).

TPU adaptation: the recurrence is *serial in time, parallel in channels* —
the natural TPU layout is a grid over (batch, channel-blocks, time-chunks)
with the time-chunk axis innermost (sequential on TPU), carrying the running
state ``h`` in VMEM scratch across chunks. Each inner step is a (1, block_d)
vector op on the VPU lanes; channel blocks are 128-lane aligned. This
replaces a GPU-style warp-parallel scan: no shuffles exist on TPU, and the
lane dimension already gives the parallelism.

Inputs a, b: (B, S, D) fp32; h0: (B, D). Outputs hs: (B, S, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(h0_ref, a_ref, b_ref, hs_ref, h_scr, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = h0_ref[...]                   # (1, block_d)

    def step(t, h):
        h = a_ref[0, t] * h + b_ref[0, t]          # (block_d,)
        hs_ref[0, t, :] = h
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[0])
    h_scr[...] = h[None]


def rglru_scan_fwd(a, b, h0, *, chunk: int = 128, block_d: int = 128,
                   interpret: bool = False):
    """Blocked scan. a, b: (B, S, D); h0: (B, D) -> hs (B, S, D)."""
    bsz, s, d = a.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    block_d = min(block_d, d)
    while d % block_d:
        block_d -= 1
    n_chunks, n_db = s // chunk, d // block_d

    kernel = functools.partial(_rglru_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bsz, n_db, n_chunks),                 # time innermost
        in_specs=[
            pl.BlockSpec((1, block_d), lambda ib, idb, ic: (ib, idb)),
            pl.BlockSpec((1, chunk, block_d),
                         lambda ib, idb, ic: (ib, ic, idb)),
            pl.BlockSpec((1, chunk, block_d),
                         lambda ib, idb, ic: (ib, ic, idb)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d),
                               lambda ib, idb, ic: (ib, ic, idb)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        interpret=interpret,
    )(h0, a, b)
