"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernel bodies then execute through the Pallas interpreter, which is how the
test suite validates them against :mod:`repro.kernels.ref`). On a TPU
backend the same calls compile to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import cc_delta_update as _cc
from repro.kernels import cc_delta_update_q8 as _q8
from repro.kernels import flash_attention as _fa
from repro.kernels import rglru_scan as _rg
from repro.kernels import slstm_scan as _sl


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """Flash attention over (B, H, S, hd) / (B, Kv, S, hd) tensors."""
    interpret = _default_interpret() if interpret is None else interpret
    return _fa.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   block_q=block_q, block_k=block_k,
                                   interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def rglru_scan(a, b, h0, *, chunk: int = 128, block_d: int = 128,
               interpret: bool | None = None):
    """Linear recurrence h_t = a_t·h_{t−1} + b_t over (B, S, D)."""
    interpret = _default_interpret() if interpret is None else interpret
    return _rg.rglru_scan_fwd(a.astype(jnp.float32), b.astype(jnp.float32),
                              h0.astype(jnp.float32), chunk=chunk,
                              block_d=block_d, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def slstm_scan(wx, r, h0, c0, n0, m0, *, chunk: int = 256,
               interpret: bool | None = None):
    """VMEM-resident sLSTM recurrence over (B, S, 4D) projections."""
    interpret = _default_interpret() if interpret is None else interpret
    f32 = jnp.float32
    return _sl.slstm_scan_fwd(wx.astype(f32), r, h0.astype(f32),
                              c0.astype(f32), n0.astype(f32),
                              m0.astype(f32), chunk=chunk,
                              interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def cc_delta_update(locals_, deltas, globals_, train_mask, sel_mask, *,
                    block: int = 65536, interpret: bool | None = None):
    """Fused CC-FedAvg round update over flat (N, P) client params."""
    interpret = _default_interpret() if interpret is None else interpret
    return _cc.cc_delta_update_fwd(locals_, deltas, globals_, train_mask,
                                   sel_mask, block=block,
                                   interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def cc_epilogue_update(locals_, deltas, globals_, train, upd, agg_w,
                       e_replay, e_stale, store_scale, denom, post_scale,
                       stale=None, *, block: int = 65536,
                       interpret: bool | None = None):
    """Strategy-parameterized fused round update (f32 history)."""
    interpret = _default_interpret() if interpret is None else interpret
    return _cc.cc_epilogue_update_fwd(
        locals_, deltas, globals_, train, upd, agg_w, e_replay, e_stale,
        store_scale, denom, post_scale, stale, block=block,
        interpret=interpret)


@jax.jit
def q8_gather_rows(payload, scales, idx):
    """Gather + dequantize cohort rows of an int8 (N, P) history store.

    The sharded history store (:mod:`repro.core.history_store`) keeps the
    full federation's Δ rows quantized and materializes f32 only for the
    active cohort — this is its gather primitive, one fused XLA program
    (take → widen → scale) so the f32 intermediate never exceeds (M, P).
    """
    from repro.core.compress import dequantize_rows
    return dequantize_rows(jnp.take(payload, idx, axis=0),
                           jnp.take(scales, idx, axis=0))


@jax.jit
def q8_scatter_rows(payload, scales, idx, rows):
    """Quantize + scatter updated cohort rows back into the int8 store.

    Per-row symmetric quantization (:func:`repro.core.compress.
    quantize_rows` semantics) of the (M, P) f32 rows, written at ``idx``;
    rows outside the cohort keep their payload/scale bits verbatim, which
    is what makes a checkpoint resume of the store bit-identical.
    """
    from repro.core.compress import quantize_rows
    q_payload, q_scales = quantize_rows(rows)
    return payload.at[idx].set(q_payload), scales.at[idx].set(q_scales)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def cc_delta_update_q8(locals_, payload, scales, globals_, train, upd,
                       agg_w, e_replay, e_stale, store_scale, denom,
                       post_scale, stale=None, *, block: int = 65536,
                       interpret: bool | None = None):
    """Strategy-parameterized fused round update over int8 Δ history.

    ``interpret=True`` (the off-TPU default) runs the vectorized XLA
    implementation — on CPU the Pallas interpreter is pure overhead, and
    the int8 win comes from moving/storing 4× fewer bytes, which XLA's
    fused elementwise path already realizes. On TPU the Pallas kernel
    compiles to Mosaic. Payload/scale outputs are bit-identical either
    way; kernel tests pin the Pallas path directly."""
    interpret = _default_interpret() if interpret is None else interpret
    if interpret:
        return _q8.cc_delta_update_q8_jnp(
            locals_, payload, scales, globals_, train, upd, agg_w,
            e_replay, e_stale, store_scale, denom, post_scale, stale)
    return _q8.cc_delta_update_q8_fwd(
        locals_, payload, scales, globals_, train, upd, agg_w, e_replay,
        e_stale, store_scale, denom, post_scale, stale, block=block,
        interpret=False)
