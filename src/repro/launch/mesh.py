"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization, and smoke tests must keep seeing 1 device.

Target hardware: TPU v5e pods. Single pod = 256 chips as a 16×16
``(data, model)`` mesh; multi-pod = 2 pods = 512 chips as
``(pod, data, model)`` — the ``pod`` axis carries the federated client
dimension of pod-level CC-FedAvg (DESIGN.md §2) and the outermost data
parallelism for plain training.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU tests of the sharded code paths."""
    return jax.make_mesh((1, 1), ("data", "model"))
