"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization, and smoke tests must keep seeing 1 device.

Target hardware: TPU v5e pods. Single pod = 256 chips as a 16×16
``(data, model)`` mesh; multi-pod = 2 pods = 512 chips as
``(pod, data, model)`` — the ``pod`` axis carries the federated client
dimension of pod-level CC-FedAvg (DESIGN.md §2) and the outermost data
parallelism for plain training.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU tests of the sharded code paths."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_client_mesh(n_shards: int | None = None):
    """1-D ``("clients",)`` mesh for the sharded federated executor.

    The stacked client dimension of the round state is ``shard_map``'ed over
    this axis (:func:`repro.core.rounds.make_sharded_span_runner`). Defaults
    to all visible devices; pass ``n_shards`` to use a prefix of them.
    """
    n = len(jax.devices()) if n_shards is None else n_shards
    if n < 1 or n > len(jax.devices()):
        raise ValueError(f"n_shards must be in [1, {len(jax.devices())}], "
                         f"got {n}")
    return jax.make_mesh((n,), ("clients",))


def make_fed_mesh(axes: tuple[str, ...] = ("clients", "model"),
                 shape: tuple[int, ...] | None = None):
    """2-D federated mesh composing the client axis with model-axis tensor
    sharding.

    The sharded executor ``shard_map``'s the stacked client dimension over
    ``"clients"`` exactly as on the 1-D mesh (specs that never name
    ``"model"`` are simply replicated over it), while
    :func:`repro.sharding.rules.params_pspecs` with
    :func:`repro.sharding.rules.make_fed_rules` places the rank dim of
    stacked per-client LoRA adapters — logical axis ``"lora"`` — on
    ``"model"``. Default shape puts every visible device on the clients
    axis; pass e.g. ``shape=(2, 2)`` on a 4-device host for a genuinely
    2-D layout.
    """
    if "clients" not in axes:
        raise ValueError(f"a federated mesh needs a 'clients' axis, "
                         f"got {axes}")
    ndev = len(jax.devices())
    if shape is None:
        shape = tuple(ndev if a == "clients" else 1 for a in axes)
    if len(shape) != len(axes):
        raise ValueError(f"shape {shape} does not match axes {axes}")
    n = 1
    for s in shape:
        n *= s
    if n < 1 or n > ndev:
        raise ValueError(f"mesh size {n} must be in [1, {ndev}]")
    return jax.make_mesh(shape, axes)


def best_client_shards(cohort_size: int, max_shards: int | None = None) -> int:
    """Largest device count ≤ ``max_shards`` that divides the cohort —
    ``shard_map`` needs the cohort split evenly, so e.g. a 6-client cohort
    on a 4-device host uses 3 shards rather than failing."""
    limit = min(cohort_size, max_shards or len(jax.devices()))
    return max(d for d in range(1, limit + 1) if cohort_size % d == 0)


def make_edge_mesh(n_shards: int | None = None):
    """1-D ``("edges",)`` mesh for the hierarchical two-tier executor.

    Edge aggregators — and with them their member clients — are split over
    this axis (:func:`repro.core.rounds.make_hierarchical_span_runner`):
    intra-edge rounds run entirely shard-local, and only the edge→server
    sync rounds communicate across it. Defaults to all visible devices;
    pass ``n_shards`` to use a prefix of them.
    """
    n = len(jax.devices()) if n_shards is None else n_shards
    if n < 1 or n > len(jax.devices()):
        raise ValueError(f"n_shards must be in [1, {len(jax.devices())}], "
                         f"got {n}")
    return jax.make_mesh((n,), ("edges",))


def best_edge_shards(n_edges: int, max_shards: int | None = None) -> int:
    """Largest device count ≤ ``max_shards`` that divides the edge count —
    whole edges must land on one device so intra-edge aggregation never
    crosses shards."""
    limit = min(n_edges, max_shards or len(jax.devices()))
    return max(d for d in range(1, limit + 1) if n_edges % d == 0)
