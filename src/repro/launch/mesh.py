"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization, and smoke tests must keep seeing 1 device.

Target hardware: TPU v5e pods. Single pod = 256 chips as a 16×16
``(data, model)`` mesh; multi-pod = 2 pods = 512 chips as
``(pod, data, model)`` — the ``pod`` axis carries the federated client
dimension of pod-level CC-FedAvg (DESIGN.md §2) and the outermost data
parallelism for plain training.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU tests of the sharded code paths."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_client_mesh(n_shards: int | None = None):
    """1-D ``("clients",)`` mesh for the sharded federated executor.

    The stacked client dimension of the round state is ``shard_map``'ed over
    this axis (:func:`repro.core.rounds.make_sharded_span_runner`). Defaults
    to all visible devices; pass ``n_shards`` to use a prefix of them.
    """
    n = len(jax.devices()) if n_shards is None else n_shards
    if n < 1 or n > len(jax.devices()):
        raise ValueError(f"n_shards must be in [1, {len(jax.devices())}], "
                         f"got {n}")
    return jax.make_mesh((n,), ("clients",))


def best_client_shards(cohort_size: int, max_shards: int | None = None) -> int:
    """Largest device count ≤ ``max_shards`` that divides the cohort —
    ``shard_map`` needs the cohort split evenly, so e.g. a 6-client cohort
    on a 4-device host uses 3 shards rather than failing."""
    limit = min(cohort_size, max_shards or len(jax.devices()))
    return max(d for d in range(1, limit + 1) if cohort_size % d == 0)


def make_edge_mesh(n_shards: int | None = None):
    """1-D ``("edges",)`` mesh for the hierarchical two-tier executor.

    Edge aggregators — and with them their member clients — are split over
    this axis (:func:`repro.core.rounds.make_hierarchical_span_runner`):
    intra-edge rounds run entirely shard-local, and only the edge→server
    sync rounds communicate across it. Defaults to all visible devices;
    pass ``n_shards`` to use a prefix of them.
    """
    n = len(jax.devices()) if n_shards is None else n_shards
    if n < 1 or n > len(jax.devices()):
        raise ValueError(f"n_shards must be in [1, {len(jax.devices())}], "
                         f"got {n}")
    return jax.make_mesh((n,), ("edges",))


def best_edge_shards(n_edges: int, max_shards: int | None = None) -> int:
    """Largest device count ≤ ``max_shards`` that divides the edge count —
    whole edges must land on one device so intra-edge aggregation never
    crosses shards."""
    limit = min(n_edges, max_shards or len(jax.devices()))
    return max(d for d in range(1, limit + 1) if n_edges % d == 0)
