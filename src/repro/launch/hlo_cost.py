"""Loop-aware cost model over compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body **once**,
ignoring trip counts. Every deep model here runs scan-over-layers (plus
inner scans: attention key chunks, chunked losses, recurrences), so the
built-in numbers undercount FLOPs/bytes by 1–2 orders of magnitude. This
module re-derives the three roofline quantities from ``compiled.as_text()``
with loop multipliers:

* **FLOPs** — every ``dot``/``convolution`` contributes
  ``2 · prod(output dims) · prod(contracted dims)``; computation costs are
  summed recursively through ``fusion`` / ``call`` / ``conditional`` edges,
  and ``while`` edges multiply by the trip count parsed from the loop
  condition (``lax.scan`` lowers to ``i < N`` with constant N).
* **HBM bytes** — per instruction: output bytes + operand bytes, skipping
  pure-metadata ops (tuple/gte/parameter/bitcast); fusions count only their
  boundary operands/outputs, matching HloCostAnalysis' convention.
* **collective bytes** — output bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute, again loop-scaled.

All quantities are for the *per-device* SPMD program.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?(%?[\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_CALLED_RE = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations)="
    r"(?:%?([\w.\-]+)|\{([^}]*)\})")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _shapes_in(text: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dt, shape))
    return out


def _bytes_of(text: str) -> int:
    total = 0
    for dt, shape in _shapes_in(text):
        total += _DTYPE_BYTES[dt] * math.prod(shape) if shape else \
            _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    opcode: str
    out_text: str          # output shape text (may be a tuple)
    rest: str              # operands + attributes
    operands: list[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    is_entry: bool = False


_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "while", "conditional",
    "call", "custom-call", "iota",
}


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and "->" in line \
                and line.rstrip().rstrip("{").rstrip():
            head = line.split("(")[0].strip()
            is_entry = head.startswith("ENTRY")
            name = head.removeprefix("ENTRY").strip().lstrip("%")
            if name and "=" not in head:
                cur = Computation(name=name, is_entry=is_entry)
                comps[name] = cur
                if is_entry:
                    entry_name = name
                continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        _, name, out_text, opcode, rest = m.groups()
        ops = _operand_names(rest)
        cur.instrs.append(Instr(name=name.lstrip("%"), opcode=opcode,
                                out_text=out_text, rest=rest, operands=ops))
    if entry_name is None and comps:
        # fall back: last computation is the entry in XLA dumps
        comps[list(comps)[-1]].is_entry = True
    return comps


def _operand_names(rest: str) -> list[str]:
    """Names in the operand list. ``rest`` starts *inside* the instruction's
    opening paren (the instr regex consumed it), so depth starts at 1.

    Handles both operand print styles: bare names (``dot(%a, %b)``) and
    typed operands (``dot(f32[64,128]{1,0} %a, ...)``) — commas inside
    ``[]``/``{}`` shape/layout annotations are not separators, and the
    operand name is the last whitespace token of each part.
    """
    depth, token = 1, ""
    for ch in rest:
        if ch == "(":
            depth += 1
            continue
        if ch == ")":
            depth -= 1
            if depth == 0:
                break
            continue
        if depth >= 1:
            token += ch
    parts, buf, braces = [], "", 0
    for ch in token:
        if ch in "[{":
            braces += 1
        elif ch in "]}":
            braces -= 1
        if ch == "," and braces == 0:
            parts.append(buf)
            buf = ""
        else:
            buf += ch
    parts.append(buf)
    out = []
    for part in parts:
        words = part.strip().split()
        if not words:
            continue
        mm = re.match(r"%?([\w.\-]+)$", words[-1])
        if mm:
            out.append(mm.group(1))
    return out


def _called_comps(instr: Instr) -> list[str]:
    out = []
    for m in _CALLED_RE.finditer(instr.rest):
        if m.group(1):
            out.append(m.group(1).lstrip("%"))
        elif m.group(2):
            out += [s.strip().lstrip("%")
                    for s in m.group(2).split(",") if s.strip()]
    return out


def _dot_flops(instr: Instr, shapes: dict[str, str]) -> float:
    """2 · prod(out) · prod(contracted lhs dims)."""
    out_elems = 0
    for _, shp in _shapes_in(instr.out_text):
        out_elems += math.prod(shp) if shp else 1
    if instr.opcode == "dot":
        mm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.rest)
        lhs_name = instr.operands[0] if instr.operands else None
        lhs_text = shapes.get(lhs_name, "")
        lhs_shapes = _shapes_in(lhs_text)
        if not mm or not lhs_shapes:
            return 0.0
        dims = [int(d) for d in mm.group(1).split(",") if d]
        lhs = lhs_shapes[0][1]
        k = math.prod(lhs[d] for d in dims if d < len(lhs)) if dims else 1
        return 2.0 * out_elems * k
    if instr.opcode == "convolution":
        # flops = 2 · prod(out) · (kernel spatial · in_channels)
        kern_name = instr.operands[1] if len(instr.operands) > 1 else None
        kern = _shapes_in(shapes.get(kern_name, ""))
        if not kern:
            return 0.0
        kshape = kern[0][1]
        mm = re.search(r"dim_labels=([\w.]+)_([\w.]+)->", instr.rest)
        if mm:
            klabels = mm.group(2)
            k_elems = 1
            for ch, dim in zip(klabels, kshape):
                if ch != "o":        # everything but output features
                    k_elems *= dim
            return 2.0 * out_elems * k_elems
        return 2.0 * out_elems * math.prod(kshape[:-1])
    return 0.0


@dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_op: dict = field(default_factory=dict)

    def add(self, other: "CostTotals", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.collective_bytes += mult * other.collective_bytes
        for k, v in other.collective_by_op.items():
            self.collective_by_op[k] = \
                self.collective_by_op.get(k, 0.0) + mult * v


class HloCostModel:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self._memo: dict[str, CostTotals] = {}
        self._shape_maps: dict[str, dict[str, str]] = {}

    # -- helpers ----------------------------------------------------------

    def _shapes(self, comp: Computation) -> dict[str, str]:
        if comp.name not in self._shape_maps:
            self._shape_maps[comp.name] = {
                i.name: i.out_text for i in comp.instrs}
        return self._shape_maps[comp.name]

    def trip_count(self, cond_name: str) -> int:
        """Parse `i < N` loop conditions (lax.scan); default 1 if opaque.

        The loop bound is an s32[] constant in the condition computation
        (the compare itself may live in a wrapped fusion). lax.scan loops
        run 0..N−1, so the bound constant IS the trip count.
        """
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1
        consts = []
        for i in comp.instrs:
            if i.opcode == "constant" and i.out_text.strip() == "s32[]":
                mc = re.match(r"(\d+)\)", i.rest.strip())
                if mc:
                    consts.append(int(mc.group(1)))
        return max(consts) if consts else 1

    def fusion_operand_bytes(self, instr: Instr,
                             shapes: dict[str, str]) -> float:
        """Operand bytes at a fusion boundary. If a fusion *parameter* is
        only consumed by an internal dynamic-slice (the fused per-step
        read of a loop-carried buffer), the fusion touches just the slice
        — charging the whole buffer every loop iteration overstates bytes
        by orders of magnitude (HloCostAnalysis' convention is slice-only
        too)."""
        callee = None
        for cn in _called_comps(instr):
            if cn in self.comps:
                callee = self.comps[cn]
                break
        # map parameter SHAPES that are only dynamic-sliced inside the
        # fusion to their slice bytes (operand order in the printed HLO is
        # not reliably parseable, shapes are)
        sliced_shapes: dict[tuple, float] = {}
        if callee is not None:
            consumers: dict[str, list[Instr]] = {}
            for ci in callee.instrs:
                for o in ci.operands:
                    consumers.setdefault(o, []).append(ci)
            for ci in callee.instrs:
                if ci.opcode != "parameter":
                    continue
                cons = consumers.get(ci.name, [])
                if cons and all(c.opcode == "dynamic-slice" for c in cons):
                    key = tuple(_shapes_in(ci.out_text))
                    sliced_shapes[key] = sum(
                        _bytes_of(c.out_text) for c in cons)
        total = 0.0
        for o in instr.operands:
            otext = shapes.get(o, "")
            key = tuple(_shapes_in(otext))
            if key and key in sliced_shapes:
                total += sliced_shapes[key]
            else:
                total += _bytes_of(otext)
        return total

    # -- cost -------------------------------------------------------------

    def cost(self, comp_name: str) -> CostTotals:
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        total = CostTotals()
        self._memo[comp_name] = total      # break cycles defensively
        if comp is None:
            return total
        shapes = self._shapes(comp)
        for instr in comp.instrs:
            op = instr.opcode
            # FLOPs
            if op in ("dot", "convolution"):
                total.flops += _dot_flops(instr, shapes)
            # bytes (slice ops touch only the slice, matching
            # HloCostAnalysis' in-place convention)
            if op not in _SKIP_BYTES_OPS:
                lname = instr.name
                if "dynamic-update-slice" in lname \
                        or op == "dynamic-update-slice":
                    upd = (instr.operands[1]
                           if len(instr.operands) > 1 else None)
                    b = 2 * _bytes_of(shapes.get(upd, "")) if upd \
                        else 2 * _bytes_of(instr.out_text)
                elif "dynamic-slice" in lname or op == "dynamic-slice":
                    b = 2 * _bytes_of(instr.out_text)
                elif op == "fusion":
                    b = _bytes_of(instr.out_text) + \
                        self.fusion_operand_bytes(instr, shapes)
                else:
                    b = _bytes_of(instr.out_text)
                    for o in instr.operands:
                        b += _bytes_of(shapes.get(o, ""))
                total.bytes += b
            # collectives (incl. -start variants)
            for coll in COLLECTIVE_OPS:
                if op == coll or op.startswith(coll + "-start"):
                    cb = _bytes_of(instr.out_text)
                    total.collective_bytes += cb
                    total.collective_by_op[coll] = \
                        total.collective_by_op.get(coll, 0.0) + cb
                    break
            # recursion
            if op == "while":
                called = _called_comps(instr)
                body = cond = None
                mb = re.search(r"body=%?([\w.\-]+)", instr.rest)
                mc = re.search(r"condition=%?([\w.\-]+)", instr.rest)
                body = mb.group(1) if mb else (called[0] if called else None)
                cond = mc.group(1) if mc else None
                trips = self.trip_count(cond) if cond else 1
                if body:
                    total.add(self.cost(body), mult=trips)
                if cond:
                    total.add(self.cost(cond), mult=trips)
            elif op == "fusion":
                # fused bodies don't touch HBM per-op — keep only their
                # flops (dots can be fused) and any collectives
                for callee in _called_comps(instr):
                    sub = self.cost(callee)
                    total.flops += sub.flops
                    total.collective_bytes += sub.collective_bytes
                    for k, v in sub.collective_by_op.items():
                        total.collective_by_op[k] = \
                            total.collective_by_op.get(k, 0.0) + v
            elif op in ("call", "map", "reduce", "reduce-window",
                        "scatter", "sort", "conditional", "custom-call"):
                for callee in _called_comps(instr):
                    total.add(self.cost(callee))
        return total

    def entry_cost(self) -> CostTotals:
        for name, comp in self.comps.items():
            if comp.is_entry:
                return self.cost(name)
        raise ValueError("no ENTRY computation found")


def loop_aware_costs(hlo_text: str) -> CostTotals:
    return HloCostModel(hlo_text).entry_cost()
