"""Serving launcher: batched prefill + greedy decode against the KV caches.

Demonstrates the serve path the decode dry-run shapes lower
(``decode_32k`` / ``long_500k``): one prefill builds ring-buffered caches,
then ``serve_step`` produces one token per call for the whole batch.

    python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --batch 2 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs as cfglib
from repro.models import decoder
from repro.models.steps import make_decode_step, make_prefill_step
from repro.utils.logging import log


def generate(cfg, params, prompt_tokens, *, gen: int,
             force_window: int = 0, greedy: bool = True, key=None):
    """prompt_tokens: (B, S) or (B, K, S). Returns generated ids list."""
    b = prompt_tokens.shape[0]
    s = prompt_tokens.shape[-1]
    capacity = s + gen
    batch = {"tokens": prompt_tokens}
    if cfg.n_vision_tokens:
        batch["vision_embeds"] = jnp.zeros(
            (b, cfg.n_vision_tokens, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))
        batch["pos3"] = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32),
                                         (3, b, s))
    elif cfg.mrope_sections:
        batch["pos3"] = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32),
                                         (3, b, s))
    prefill = jax.jit(make_prefill_step(cfg, capacity=capacity,
                                        force_window=force_window))
    serve = jax.jit(make_decode_step(cfg, force_window=force_window))
    caches, logits = prefill(params, batch)
    out = []
    for t in range(gen):
        nxt = jnp.argmax(logits[..., -1, :] if logits.ndim == 3
                         else logits[:, -1], axis=-1)
        if cfg.n_codebooks:
            nxt = jnp.argmax(logits[:, -1], axis=-1)   # (B, K)
            tok = nxt[..., None].astype(jnp.int32)
        else:
            tok = nxt[:, None].astype(jnp.int32)
        out.append(nxt)
        logits, caches = serve(params, caches, tok,
                               jnp.asarray(s + t, jnp.int32))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=cfglib.ARCH_NAMES,
                    default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = cfglib.get_config(args.arch, reduced=args.reduced)
    rng = jax.random.PRNGKey(args.seed)
    params = decoder.model_init(rng, cfg)
    shape = ((args.batch, cfg.n_codebooks, args.prompt_len)
             if cfg.n_codebooks else (args.batch, args.prompt_len))
    prompt = jax.random.randint(rng, shape, 0, cfg.vocab)
    t0 = time.time()
    toks = generate(cfg, params, prompt, gen=args.gen)
    dt = time.time() - t0
    log("serve done", arch=args.arch, batch=args.batch,
        prompt=args.prompt_len, generated=len(toks),
        ms_per_token=f"{1e3 * dt / max(1, args.gen):.1f}")
    first = jax.device_get(toks[0])
    log(f"first generated ids (batch 0): {first[0] if first.ndim else first}")


if __name__ == "__main__":
    main()
