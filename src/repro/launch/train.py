"""Training launcher.

Two modes sharing the same configs and model zoo:

* ``--mode centralized`` — plain data+tensor-parallel LM training of any
  assigned architecture (reduced or full) on the available mesh.
* ``--mode federated``   — CC-FedAvg over the paper's experiment models
  (MLP/CNN/ResNet on synthetic data), the end-to-end driver used by the
  examples and benchmarks.

On this CPU container use ``--reduced`` (the dry-run exercises the full
configs; see launch/dryrun.py).

Examples:
    python -m repro.launch.train --mode centralized --arch qwen3-1.7b \
        --reduced --steps 20 --batch 4 --seq 128
    python -m repro.launch.train --mode federated --strategy cc \
        --clients 8 --rounds 100 --beta 4 --gamma 0.5
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro.api import ExperimentSpec, Session, VerboseLogger
from repro.checkpoint.store import CheckpointManager
from repro.data.synthetic import token_lm_dataset
from repro.models.steps import init_train_state, make_train_step
from repro.optim.optimizers import make_optimizer
from repro.optim.schedules import warmup_cosine_lr
from repro.utils.logging import log


def run_centralized(args) -> dict:
    cfg = cfglib.get_config(args.arch, reduced=args.reduced)
    opt = make_optimizer(args.optimizer)
    lr = warmup_cosine_lr(args.lr, max(1, args.steps // 10), args.steps)
    rng = jax.random.PRNGKey(args.seed)
    state = init_train_state(rng, cfg, opt)
    step = jax.jit(make_train_step(cfg, opt, lr))
    data = token_lm_dataset(np.random.default_rng(args.seed),
                            n_seq=max(64, args.batch * 4),
                            seq_len=args.seq, vocab=cfg.vocab)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2) if args.ckpt_dir else None
    t0 = time.time()
    losses = []
    for i in range(args.steps):
        idx = np.random.default_rng(i).integers(0, len(data), args.batch)
        batch = {"tokens": jnp.asarray(data.x[idx])}
        if cfg.n_codebooks:
            batch["tokens"] = jnp.broadcast_to(
                batch["tokens"][:, None],
                (args.batch, cfg.n_codebooks, args.seq))
        if cfg.n_vision_tokens:
            batch["vision_embeds"] = jnp.zeros(
                (args.batch, cfg.n_vision_tokens, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
            batch["pos3"] = jnp.broadcast_to(
                jnp.arange(args.seq, dtype=jnp.int32), (3, args.batch,
                                                        args.seq))
        elif cfg.mrope_sections:
            batch["pos3"] = jnp.broadcast_to(
                jnp.arange(args.seq, dtype=jnp.int32), (3, args.batch,
                                                        args.seq))
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
        if (i + 1) % max(1, args.steps // 10) == 0:
            log(f"step {i + 1}/{args.steps}", loss=f"{losses[-1]:.4f}",
                lr=f"{float(metrics['lr']):.2e}")
            if ckpt:
                ckpt.save(i + 1, state)
    dt = time.time() - t0
    log("centralized done", arch=args.arch,
        loss0=f"{losses[0]:.4f}", lossN=f"{losses[-1]:.4f}",
        s_per_step=f"{dt / max(1, args.steps):.2f}")
    return {"losses": losses}


def federated_spec(args) -> ExperimentSpec:
    """Map the federated CLI flags onto one declarative spec."""
    return ExperimentSpec(
        dataset=args.dataset, n_samples=args.n_samples, dim=args.dim,
        n_classes=args.classes, n_clients=args.clients,
        partition="gamma", gamma=args.gamma,
        budget="power", beta=args.beta,
        model=args.model, width=args.width,
        strategy=args.strategy, variant=args.variant,
        local_steps=args.local_steps, batch_size=args.batch, lr=args.lr,
        schedule=args.schedule, rounds=args.rounds,
        participation=args.participation, eval_every=args.eval_every,
        seed=args.seed)


def run_federated_mode(args) -> dict:
    spec = federated_spec(args)
    if args.resume and not args.ckpt_dir:
        raise SystemExit("--resume needs --ckpt-dir (nowhere to restore "
                         "from)")
    session = Session.from_spec(spec, callbacks=[VerboseLogger()],
                                ckpt_dir=args.ckpt_dir or None)
    if args.ckpt_dir and args.resume:
        session.restore()
        log(f"resumed at round {session.t}/{spec.rounds}")
    session.run()
    if args.ckpt_dir:
        session.save()
    rep = session.cost_report()
    log("federated done", strategy=args.strategy,
        acc=f"{session.metrics.last('test_acc'):.4f}",
        compute_saved=f"{rep['compute_saved_frac']:.1%}",
        upload_mb=f"{rep['upload_bytes'] / 1e6:.1f}")
    return {"acc": session.metrics.last("test_acc"), "cost": rep}


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", choices=("centralized", "federated"),
                    default="federated")
    ap.add_argument("--seed", type=int, default=0)
    # centralized
    ap.add_argument("--arch", choices=cfglib.ARCH_NAMES,
                    default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--ckpt-dir", default="")
    # federated
    ap.add_argument("--strategy", default="cc")
    ap.add_argument("--variant", default="client",
                    choices=("client", "server", "mixed"))
    ap.add_argument("--schedule", default="adhoc",
                    choices=("adhoc", "round_robin", "sync", "dropout",
                             "full"))
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--beta", type=int, default=4)
    ap.add_argument("--gamma", type=float, default=0.5)
    ap.add_argument("--participation", type=float, default=1.0)
    ap.add_argument("--eval-every", type=int, default=20)
    ap.add_argument("--dataset", default="gaussian",
                    choices=("gaussian", "teacher", "image"))
    ap.add_argument("--model", default="mlp",
                    choices=("mlp", "cnn", "resnet18"))
    ap.add_argument("--n-samples", type=int, default=4096)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--width", type=int, default=16)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--resume", action="store_true",
                    help="federated: restore the latest checkpoint in "
                         "--ckpt-dir before running")
    return ap


def main() -> None:
    args = build_parser().parse_args()
    if args.mode == "centralized":
        run_centralized(args)
    else:
        run_federated_mode(args)


if __name__ == "__main__":
    main()
