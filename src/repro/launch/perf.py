"""§Perf tooling: loop-aware HLO breakdowns for the hillclimb loop.

``breakdown(compiled_text)`` attributes every byte / collective-byte /
FLOP to its instruction with while-loop multipliers applied, so the
hypothesis loop can see WHAT dominates the dominant roofline term
(which tensor is being gathered, which buffer re-read).
"""
from __future__ import annotations

import re
from dataclasses import dataclass

from repro.launch.hlo_cost import (_SKIP_BYTES_OPS, COLLECTIVE_OPS,
                                   HloCostModel, _bytes_of, _called_comps,
                                   _dot_flops)


@dataclass
class Contribution:
    kind: str          # 'bytes' | 'collective' | 'flops'
    amount: float
    comp: str
    instr: str
    opcode: str
    shape: str
    meta: str = ""


def breakdown(hlo_text: str, top: int = 12) -> dict[str, list[Contribution]]:
    model = HloCostModel(hlo_text)
    contribs: list[Contribution] = []

    def walk(name: str, mult: float, seen: tuple):
        comp = model.comps.get(name)
        if comp is None or name in seen:
            return
        shapes = model._shapes(comp)
        for instr in comp.instrs:
            op = instr.opcode
            meta = ""
            mm = re.search(r'op_name="([^"]+)"', instr.rest)
            if mm:
                meta = mm.group(1)[-70:]
            if op not in _SKIP_BYTES_OPS:
                if "dynamic-update-slice" in instr.name \
                        or op == "dynamic-update-slice":
                    upd = (instr.operands[1]
                           if len(instr.operands) > 1 else None)
                    b = 2 * _bytes_of(shapes.get(upd, "")) if upd \
                        else 2 * _bytes_of(instr.out_text)
                elif "dynamic-slice" in instr.name or op == "dynamic-slice":
                    b = 2 * _bytes_of(instr.out_text)
                elif op == "fusion":
                    b = _bytes_of(instr.out_text) + \
                        model.fusion_operand_bytes(instr, shapes)
                else:
                    b = _bytes_of(instr.out_text) + sum(
                        _bytes_of(shapes.get(o, ""))
                        for o in instr.operands)
                contribs.append(Contribution(
                    "bytes", b * mult, name, instr.name, op,
                    instr.out_text[:48], meta))
            for coll in COLLECTIVE_OPS:
                if op == coll or op.startswith(coll + "-start"):
                    cb = _bytes_of(instr.out_text)
                    contribs.append(Contribution(
                        "collective", cb * mult, name, instr.name, op,
                        instr.out_text[:48], meta))
                    break
            if op in ("dot", "convolution"):
                contribs.append(Contribution(
                    "flops", _dot_flops(instr, shapes) * mult, name,
                    instr.name, op, instr.out_text[:48], meta))
            if op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", instr.rest)
                mc = re.search(r"condition=%?([\w.\-]+)", instr.rest)
                trips = model.trip_count(mc.group(1)) if mc else 1
                if mb:
                    walk(mb.group(1), mult * trips, seen + (name,))
            elif op == "fusion":
                for callee in _called_comps(instr):
                    # fused dots / collectives only (bytes counted at the
                    # fusion boundary above)
                    sub = model.comps.get(callee)
                    if sub is None:
                        continue
                    sshapes = model._shapes(sub)
                    for si in sub.instrs:
                        if si.opcode in ("dot", "convolution"):
                            contribs.append(Contribution(
                                "flops", _dot_flops(si, sshapes) * mult,
                                callee, si.name, si.opcode,
                                si.out_text[:48], meta))
            elif op in ("call", "conditional", "custom-call"):
                for callee in _called_comps(instr):
                    walk(callee, mult, seen + (name,))

    entry = next(c.name for c in model.comps.values() if c.is_entry)
    walk(entry, 1.0, ())
    out: dict[str, list[Contribution]] = {}
    for kind in ("bytes", "collective", "flops"):
        rows = sorted((c for c in contribs if c.kind == kind),
                      key=lambda c: -c.amount)
        out[kind] = rows[:top]
        out[f"total_{kind}"] = sum(c.amount for c in contribs
                                   if c.kind == kind)
    return out


def print_breakdown(hlo_text: str, kinds=("bytes", "collective"),
                    top: int = 10) -> None:
    bd = breakdown(hlo_text, top=top)
    for kind in kinds:
        print(f"--- top {kind} contributors "
              f"(total {bd[f'total_{kind}']:.3e}) ---")
        for c in bd[kind]:
            print(f"  {c.amount:10.3e}  {c.opcode:<22} {c.shape:<40} "
                  f"{c.meta}")
