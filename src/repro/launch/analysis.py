"""Roofline-term extraction from compiled XLA artifacts.

``cost_analysis()`` on a GSPMD-compiled executable reports the *per-device*
program (FLOPs and bytes on the sharded shapes), so all three roofline
terms below are per-chip seconds; with even sharding they equal the
prompt's ``global / (chips × peak)`` formulation.

``collective_bytes`` is not in ``cost_analysis()`` — we parse the compiled
(post-SPMD-partitioning) HLO text and sum the *output* tensor bytes of
every ``all-gather`` / ``all-reduce`` / ``reduce-scatter`` / ``all-to-all``
/ ``collective-permute`` op. Output bytes ≥ operand bytes for all-gather
(the worst direction on the wire) and equal them for the others, so this is
a link-traffic upper bound per hop.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

# one tensor literal: f32[2048,16]{1,0}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# an HLO instruction line:  %name = <shape(s)> opcode(
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_by_op(hlo_text: str) -> dict[str, int]:
    """Sum output bytes of every collective op in the (per-device) HLO."""
    out: dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        shapes, opcode = m.groups()
        # normalize fused/start variants: all-reduce-start, all-gather-done…
        for op in COLLECTIVE_OPS:
            if opcode == op or opcode.startswith(op + "-start") \
                    or opcode == op + ".1":
                out[op] += _shape_bytes(shapes)
                break
    return out


@dataclass
class RooflineTerms:
    """Per-chip roofline seconds for one compiled step."""
    flops: float                  # per-device HLO FLOPs (loop-aware)
    hbm_bytes: float              # per-device bytes accessed (loop-aware)
    collective_bytes: float       # per-device collective output bytes
    by_op: dict = field(default_factory=dict)
    raw_flops: float = 0.0        # XLA cost_analysis (loop bodies ×1)
    raw_bytes: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def to_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "by_op": self.by_op,
            "raw_flops": self.raw_flops,
            "raw_bytes": self.raw_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
        }


def roofline_from_compiled(compiled) -> RooflineTerms:
    """Loop-aware terms from the compiled HLO (see :mod:`.hlo_cost` — XLA's
    own cost_analysis counts while bodies once, which undercounts
    scan-over-layers programs by ~n_layers). Raw XLA numbers are kept in
    ``raw_*`` for reference."""
    from repro.launch.hlo_cost import loop_aware_costs

    cost = compiled.cost_analysis()
    if isinstance(cost, list):      # older jax returns [dict]
        cost = cost[0]
    totals = loop_aware_costs(compiled.as_text())
    terms = RooflineTerms(
        flops=totals.flops, hbm_bytes=totals.bytes,
        collective_bytes=totals.collective_bytes,
        by_op=dict(totals.collective_by_op))
    terms.raw_flops = float(cost.get("flops", 0.0))
    terms.raw_bytes = float(cost.get("bytes accessed", 0.0))
    return terms


def model_flops_train(cfg, n_tokens: int) -> float:
    """6·N·D with N = active params (MoE: top-k + shared experts only)."""
    n_active = active_param_count(cfg)
    return 6.0 * n_active * n_tokens


def model_flops_decode(cfg, n_tokens: int) -> float:
    return 2.0 * active_param_count(cfg) * n_tokens


def total_param_count(cfg) -> int:
    """All stored parameters (MoE counts every expert) — the storage-side
    count the weight-stationary decode decision needs."""
    total = active_param_count(cfg)
    if cfg.moe is not None:
        m = cfg.moe
        inactive = m.n_experts - m.top_k
        total += cfg.n_layers * inactive * 3 * cfg.d_model * m.d_ff_expert
    return int(total)


def active_param_count(cfg) -> int:
    """Analytic parameter count; MoE counts top_k (+shared) experts."""
    d, v = cfg.d_model, cfg.vocab
    total = v * d                                  # embedding
    if not cfg.tie_embeddings:
        total += d * v * max(1, cfg.n_codebooks or 1)
    if cfg.n_codebooks:
        total += (cfg.n_codebooks - 1) * v * d     # extra codebook tables
    for seg in cfg.segments:
        for kind in seg.pattern:
            total += seg.repeat * _block_params(cfg, kind)
    return int(total)


def _block_params(cfg, kind: str) -> int:
    d = cfg.d_model
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = 2 * d                                       # the two norms
    if kind in ("attn", "swa", "mrope"):
        p += d * h * hd + 2 * d * kv * hd + h * hd * d
    elif kind == "mla":
        m = cfg.mla
        qk = m.qk_nope_dim + m.qk_rope_dim
        p += (d * m.q_lora_rank + m.q_lora_rank * h * qk
              + d * (m.kv_lora_rank + m.qk_rope_dim)
              + m.kv_lora_rank * h * (m.qk_nope_dim + m.v_head_dim)
              + h * m.v_head_dim * d)
    elif kind == "rglru":
        dr = cfg.d_rnn
        p += 2 * d * dr + 2 * dr * dr + dr * d + cfg.rg_conv_width * dr
    elif kind == "mlstm":
        di = 2 * d
        p += 2 * d * di + 3 * di * di + di * d + 4 * di
    elif kind == "slstm":
        p += d * 4 * d + 4 * (d // max(1, h)) * d + d * 2 * d + d * d
    # FFN half
    if kind in ("attn", "swa", "mrope", "mla", "rglru") \
            and cfg.ffn_kind != "none":
        if cfg.ffn_kind == "moe":
            m = cfg.moe
            active_e = m.top_k + m.n_shared_experts
            p += active_e * 3 * d * m.d_ff_expert + d * m.n_experts
        else:
            p += 3 * d * cfg.d_ff
    return p
