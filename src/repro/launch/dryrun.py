import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402  — the two lines above MUST precede any jax import;
# jax locks the device count at first initialization.
"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes and extract the §Roofline terms.

For each pair this lowers the step the shape dictates —
``train_4k`` → ``train_step`` (AdamW optimizer step),
``prefill_32k`` → ``prefill_step``,
``decode_32k`` / ``long_500k`` → ``serve_step`` (1 token vs KV cache) —
with parameter/batch/cache shardings from :mod:`repro.sharding.rules`,
prints ``memory_analysis()`` / ``cost_analysis()``, and writes one JSON
record per pair for EXPERIMENTS.md §Dry-run/§Roofline.

``--step ccround`` additionally lowers the paper's technique at pod
granularity (pods-as-clients CC-FedAvg round) on the multi-pod mesh.

Usage:
    python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
    python -m repro.launch.dryrun --all --out results/dryrun
    python -m repro.launch.dryrun --all --multi-pod --out results/dryrun
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs as cfglib
from repro.launch import analysis
from repro.launch.mesh import make_production_mesh
from repro.models.config import INPUT_SHAPES, ArchConfig, InputShape
from repro.models.steps import (init_train_state, make_decode_step,
                                make_prefill_step, make_train_step)
from repro.optim.optimizers import adamw
from repro.optim.schedules import constant_lr
from repro.sharding.api import ShardingContext, use_sharding
from repro.sharding.rules import (batch_pspecs, cache_pspecs, make_rules,
                                  params_pspecs)
from repro.utils.pytree import tree_map_with_path


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------


def state_shardings(ctx: ShardingContext, state_specs):
    """NamedShardings for a train-state pytree (params + mirrored opt)."""
    from jax.sharding import NamedSharding
    from repro.sharding.rules import param_logical_axes

    def one(path, leaf):
        axes = param_logical_axes(path, leaf)
        return NamedSharding(ctx.mesh, ctx.spec(axes, tuple(leaf.shape)))

    return tree_map_with_path(one, state_specs)


def named(ctx: ShardingContext, spec_tree):
    from jax.sharding import NamedSharding
    return jax.tree.map(lambda s: NamedSharding(ctx.mesh, s), spec_tree)


# ---------------------------------------------------------------------------
# one dry-run
# ---------------------------------------------------------------------------


def _build(cfg: ArchConfig, shape: InputShape, ctx: ShardingContext):
    """Returns (fn, args tuple of ShapeDtypeStructs, in_shardings tuple)."""
    specs = cfglib.input_specs(cfg, shape)
    if shape.mode == "train":
        opt = adamw()
        fn = make_train_step(cfg, opt, constant_lr(1e-4))
        state_specs = jax.eval_shape(
            lambda: init_train_state(jax.random.PRNGKey(0), cfg, opt))
        in_sh = (state_shardings(ctx, state_specs),
                 named(ctx, batch_pspecs(ctx, specs["batch"])))
        return fn, (state_specs, specs["batch"]), in_sh
    if shape.mode == "prefill":
        fn = make_prefill_step(cfg, capacity=shape.seq_len)
        params_specs = jax.eval_shape(
            lambda: __import__("repro.models.decoder", fromlist=["x"])
            .model_init(jax.random.PRNGKey(0), cfg))
        in_sh = (state_shardings(ctx, params_specs),
                 named(ctx, batch_pspecs(ctx, specs["batch"])))
        return fn, (params_specs, specs["batch"]), in_sh
    # decode
    fw = cfglib.decode_window(cfg, shape)
    fn = make_decode_step(cfg, force_window=fw)
    params_specs = jax.eval_shape(
        lambda: __import__("repro.models.decoder", fromlist=["x"])
        .model_init(jax.random.PRNGKey(0), cfg))
    caches = specs["caches"]
    tok_spec = batch_pspecs(ctx, {"tokens": specs["tokens"]})["tokens"]
    in_sh = (state_shardings(ctx, params_specs),
             named(ctx, cache_pspecs(ctx, caches, stacked=True)),
             named(ctx, tok_spec),
             named(ctx, ctx.spec((), ())))
    return fn, (params_specs, caches, specs["tokens"], specs["t"]), in_sh


def _build_ccround(cfg: ArchConfig, shape: InputShape, ctx: ShardingContext,
                   *, local_steps: int = 1, n_clients: int = 2):
    """The paper's technique at pod granularity (multi-pod mesh only)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.podlevel import init_pod_fed_state, make_cc_pod_round

    fn = make_cc_pod_round(cfg, lr=1e-3, local_steps=local_steps,
                           n_clients=n_clients)
    fed_specs = jax.eval_shape(
        lambda: init_pod_fed_state(jax.random.PRNGKey(0), cfg, n_clients))
    from repro.sharding.rules import param_logical_axes

    def fed_sh(path, leaf):
        if path.startswith("deltas"):
            axes = ("clients",) + param_logical_axes(path, leaf)[1:]
        elif path.startswith("global_params"):
            axes = param_logical_axes(path, leaf)
        else:
            axes = (None,) * leaf.ndim
        return NamedSharding(ctx.mesh, ctx.spec(axes, tuple(leaf.shape)))

    fed_sharding = tree_map_with_path(fed_sh, fed_specs)
    per_client = shape.global_batch // n_clients
    bspec = cfglib.batch_specs(cfg, per_client, shape.seq_len)

    def stack(s):
        return jax.ShapeDtypeStruct(
            (n_clients, local_steps) + s.shape, s.dtype)

    batches = jax.tree.map(stack, bspec)

    def shard_of(key, s):
        if key == "pos3":        # (clients, K, 3, B, S)
            return NamedSharding(ctx.mesh, P("pod", None, None, "data"))
        if len(s.shape) >= 3:    # (clients, K, B, ...)
            return NamedSharding(ctx.mesh, P("pod", None, "data"))
        return NamedSharding(ctx.mesh, P("pod", None))

    b_shard = {k: shard_of(k, v) for k, v in batches.items()}
    mask = jax.ShapeDtypeStruct((n_clients,), jnp.float32)
    mask_sh = NamedSharding(ctx.mesh, P("pod"))
    return fn, (fed_specs, batches, mask), (fed_sharding, b_shard, mask_sh)


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               step: str = "auto", local_steps: int = 1,
               verbose: bool = True, expert_parallel: bool | None = None,
               config_override=None) -> dict:
    cfg = config_override or cfglib.get_config(arch)
    if expert_parallel is not None and cfg.moe is not None:
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, expert_parallel=expert_parallel))
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    model_size = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    # weight-stationary serving: at decode, FSDP means all-gathering the
    # whole model per token; when the (model-axis-sharded) params fit in
    # HBM alongside the caches, replicate over `data` instead (§Perf D1)
    fsdp = True
    if shape.mode == "decode":
        param_bytes = 4 * analysis.total_param_count(cfg)
        fsdp = param_bytes > 8e9
    rules = make_rules(
        multi_pod=multi_pod, mode=shape.mode, fsdp=fsdp,
        expert_parallel=bool(cfg.moe and cfg.moe.expert_parallel),
        context_parallel_attn=bool(cfg.n_heads % model_size),
        kv_divisible=cfg.n_kv_heads % model_size == 0)
    ctx = ShardingContext(mesh=mesh, rules=rules)
    rec: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "step": step, "n_devices": n_dev, "ok": False,
    }
    try:
        t0 = time.time()
        with mesh, use_sharding(ctx):
            if step == "ccround":
                fn, args, in_sh = _build_ccround(
                    cfg, shape, ctx, local_steps=local_steps)
            else:
                fn, args, in_sh = _build(cfg, shape, ctx)
            lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)
        try:
            mem = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "generated_code_bytes": int(mem.generated_code_size_in_bytes),
            }
            if verbose:
                print(f"  memory_analysis: {rec['memory']}")
        except Exception as e:  # CPU backend may not implement it
            rec["memory"] = {"error": str(e)}
        terms = analysis.roofline_from_compiled(compiled)
        rec["roofline"] = terms.to_dict()
        n_active = analysis.active_param_count(cfg)
        rec["active_params"] = n_active
        if shape.mode == "train":
            tokens = shape.global_batch * shape.seq_len
            rec["model_flops"] = analysis.model_flops_train(cfg, tokens)
        elif shape.mode == "prefill":
            tokens = shape.global_batch * shape.seq_len
            rec["model_flops"] = 2.0 * n_active * tokens
        else:
            rec["model_flops"] = analysis.model_flops_decode(
                cfg, shape.global_batch)
        hlo_global_flops = terms.flops * n_dev
        rec["useful_flops_ratio"] = (
            rec["model_flops"] / hlo_global_flops if hlo_global_flops else 0.0)
        rec["ok"] = True
        if verbose:
            print(f"  cost_analysis: flops/dev={terms.flops:.3e} "
                  f"bytes/dev={terms.hbm_bytes:.3e} "
                  f"coll/dev={terms.collective_bytes:.3e}")
            print(f"  roofline: compute={terms.compute_s * 1e3:.2f}ms "
                  f"memory={terms.memory_s * 1e3:.2f}ms "
                  f"collective={terms.collective_s * 1e3:.2f}ms "
                  f"-> {terms.bottleneck}-bound | "
                  f"useful_flops={rec['useful_flops_ratio']:.2%}")
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"  FAILED: {rec['error']}")
    return rec


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=cfglib.ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) pair")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--step", default="auto",
                    choices=("auto", "ccround"))
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--out", default="",
                    help="directory for one JSON per pair")
    args = ap.parse_args()

    pairs: list[tuple[str, str]]
    if args.all:
        pairs = [(a, s) for a in cfglib.ARCH_NAMES for s in INPUT_SHAPES]
    else:
        if not (args.arch and args.shape):
            ap.error("--arch/--shape required unless --all")
        pairs = [(args.arch, args.shape)]

    n_ok = 0
    for arch, shape in pairs:
        tag = "2pod" if args.multi_pod else "1pod"
        name = f"{arch}_{shape}_{tag}"
        if args.step == "ccround":
            name += "_ccround"
        print(f"[dryrun] {name}")
        rec = dryrun_one(arch, shape, multi_pod=args.multi_pod,
                         step=args.step, local_steps=args.local_steps)
        n_ok += rec["ok"]
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            with open(os.path.join(args.out, name + ".json"), "w") as f:
                json.dump(rec, f, indent=1)
    print(f"[dryrun] {n_ok}/{len(pairs)} pairs compiled OK")
    if n_ok < len(pairs):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
