"""Regenerate the EXPERIMENTS.md §Dry-run / §Roofline tables from the
dry-run JSON records.

    PYTHONPATH=src python tools/gen_experiment_tables.py > /tmp/tables.md
"""
from __future__ import annotations

import glob
import json


def load(d):
    out = {}
    for f in sorted(glob.glob(d + "/*.json")):
        r = json.load(open(f))
        out[(r["arch"], r["shape"])] = r
    return out


def fmt_bytes(b):
    return f"{b / 1e9:.2f}"


def roofline_table(recs, title):
    print(f"\n### {title}\n")
    print("| arch | shape | compute_s | memory_s | collective_s | "
          "bottleneck | useful FLOPs | fits 16G HBM |")
    print("|---|---|---:|---:|---:|---|---:|---|")
    for (arch, shape), r in sorted(recs.items()):
        rf = r["roofline"]
        mem = r.get("memory", {})
        temp = mem.get("temp_bytes")
        arg = mem.get("argument_bytes", 0)
        fits = "—"
        if temp is not None:
            tot = (temp + arg) / 1e9
            fits = f"yes ({tot:.1f} GB)" if tot <= 16 else f"**NO ({tot:.1f} GB)**"
        print(f"| {arch} | {shape} | {rf['compute_s']:.4f} | "
              f"{rf['memory_s']:.4f} | {rf['collective_s']:.4f} | "
              f"{rf['bottleneck']} | {r.get('useful_flops_ratio', 0):.2f} "
              f"| {fits} |")


def compare_table(base, opt):
    print("\n### Baseline → optimized (dominant roofline term, single-pod)\n")
    print("| arch | shape | baseline dominant (s) | optimized dominant (s) |"
          " speedup | bottleneck shift |")
    print("|---|---|---:|---:|---:|---|")
    tb = to = 0.0
    for k in sorted(base):
        rb, ro = base[k]["roofline"], opt[k]["roofline"]
        db = max(rb["compute_s"], rb["memory_s"], rb["collective_s"])
        do = max(ro["compute_s"], ro["memory_s"], ro["collective_s"])
        tb += db
        to += do
        print(f"| {k[0]} | {k[1]} | {db:.3f} | {do:.3f} | {db / do:.2f}× | "
              f"{rb['bottleneck']}→{ro['bottleneck']} |")
    print(f"\nFleet sum of dominant terms: **{tb:.1f} s → {to:.1f} s "
          f"({tb / to:.2f}×)** (see §Perf for which deltas are code vs "
          f"cost-model corrections).")


def ccround_table():
    print("\n### CC-FedAvg pod-round (the paper's technique, 2×16×16 mesh, "
          "train_4k)\n")
    print("| arch | compute_s | memory_s | collective_s | bottleneck |")
    print("|---|---:|---:|---:|---|")
    for f in sorted(glob.glob("results/dryrun_ccround_opt/*.json")):
        r = json.load(open(f))
        rf = r["roofline"]
        print(f"| {r['arch']} | {rf['compute_s']:.3f} | {rf['memory_s']:.3f}"
              f" | {rf['collective_s']:.3f} | {rf['bottleneck']} |")


def main():
    base1 = load("results/dryrun_1pod")
    opt1 = load("results/dryrun_1pod_opt")
    opt2 = load("results/dryrun_2pod_opt")
    roofline_table(opt1, "Single-pod 16×16 (256 chips) — optimized")
    roofline_table(opt2, "Multi-pod 2×16×16 (512 chips) — optimized")
    compare_table(base1, opt1)
    ccround_table()


if __name__ == "__main__":
    main()
