#!/usr/bin/env bash
# Fast pre-test lint: every Python file must at least compile.
#   ./tools/check.sh
set -euo pipefail
cd "$(dirname "$0")/.."

python -m compileall -q src benchmarks examples tools tests
echo "compileall: OK"
