"""Quickstart: CC-FedAvg in ~40 lines of public API.

Eight clients with heterogeneous compute budgets collaboratively train a
classifier on non-IID synthetic data. Clients with p_i < 1 skip local
training in (1 − p_i) of rounds and upload their previous update Δ_{t−1}
instead (Strategy 3) — same convergence, ~45% less client compute.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import (FedConfig, available_strategies, cost_report,
                        run_federated)
from repro.core.schedules import make_plan
from repro.data.federated import build_federated
from repro.data.partition import budget_law, partition_gamma
from repro.data.synthetic import make_dataset, train_test_split
from repro.models.simple import make_classifier
from repro.utils.pytree import tree_bytes

N_CLIENTS, ROUNDS = 8, 80

# 1. data: synthetic 8-class task, 50% non-IID across 8 clients
ds = make_dataset("teacher", n=2048, dim=24, n_classes=8, seed=0)
train, test = train_test_split(ds)
parts = partition_gamma(train, N_CLIENTS, gamma=0.5)
fed_data = build_federated(train, parts)

# 2. model: the paper's MLP
model = make_classifier("mlp", input_shape=(24,), n_classes=8, width=8)

# 3. budgets: p_i = (1/2)^⌊β·i/N⌋ → {1, 1/2, 1/4, 1/8} (paper §VI-A)
p = budget_law(N_CLIENTS, beta=4)
plan = make_plan("adhoc", p, ROUNDS)          # each client decides per round

# 4. run CC-FedAvg (Algorithm 1). Any name from the strategy registry works
#    here — eval-free spans execute as one jitted lax.scan program.
print("registered strategies:", ", ".join(available_strategies()))
fed = FedConfig(strategy="cc", local_steps=5, batch_size=32, lr=0.1)
state, metrics = run_federated(model, fed_data, fed, plan,
                               x_test=jnp.asarray(test.x),
                               y_test=jnp.asarray(test.y),
                               eval_every=20, verbose=True)

# 5. what did it cost? (Appendix-A accounting, Alg. 1 = client variant)
report = cost_report(plan, tree_bytes(state["params"]), variant="client")
print(f"\nfinal accuracy     : {metrics.last('test_acc'):.3f}")
print(f"client compute cut : {report['compute_saved_frac']:.1%} "
      f"vs FedAvg(full)")
print(f"total upload       : {report['upload_bytes'] / 1e6:.1f} MB")
