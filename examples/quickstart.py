"""Quickstart: CC-FedAvg through the experiment API in ~30 lines.

Eight clients with heterogeneous compute budgets collaboratively train a
classifier on non-IID synthetic data. Clients with p_i < 1 skip local
training in (1 − p_i) of rounds and upload their previous update Δ_{t−1}
instead (Strategy 3) — same convergence, ~45% less client compute.

An :class:`ExperimentSpec` declares the whole run; a :class:`Session`
executes it stepwise (eval-free spans run as one jitted ``lax.scan``).
The spec serializes to JSON, so the same run works as
``python -m repro run spec.json``.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import ExperimentSpec, Session, VerboseLogger
from repro.core import available_strategies

# 1. declare the experiment: data, partition, budgets, model, plan — one
#    serializable object. p_i = (1/2)^⌊β·i/N⌋ → {1, 1/2, 1/4, 1/8} (§VI-A)
spec = ExperimentSpec(
    dataset="teacher", n_samples=2048, dim=24, n_classes=8,   # data
    n_clients=8, partition="gamma", gamma=0.5,                # 50% non-IID
    budget="power", beta=4,                                   # budgets
    model="mlp", width=8,                                     # paper's MLP
    strategy="cc", local_steps=5, batch_size=32, lr=0.1,      # CC-FedAvg
    schedule="adhoc", rounds=80, eval_every=20,               # plan
)
print("registered strategies:", ", ".join(available_strategies()))
print("spec:", spec.to_json()[:120].replace("\n", " "), "...")

# 2. run it. Any name from the strategy registry works in `strategy=`;
#    Session.run() is resumable — save()/restore() checkpoint everything.
session = Session.from_spec(spec, callbacks=[VerboseLogger()])
session.run()

# 3. what did it cost? (Appendix-A accounting, Alg. 1 = client variant)
report = session.cost_report()
print(f"\nfinal accuracy     : {session.metrics.last('test_acc'):.3f}")
print(f"client compute cut : {report['compute_saved_frac']:.1%} "
      f"vs FedAvg(full)")
print(f"total upload       : {report['upload_bytes'] / 1e6:.1f} MB")
