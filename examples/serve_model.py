"""Batched serving of an assigned architecture: prefill a prompt batch,
then stream greedy tokens from the KV caches — the same ``serve_step``
the decode_32k / long_500k dry-run shapes lower to the production mesh.

    PYTHONPATH=src python examples/serve_model.py \
        [--arch recurrentgemma-9b] [--batch 2] [--gen 12]
"""
import argparse
import time

import jax

from repro import configs as cfglib
from repro.launch.serve import generate
from repro.models import decoder
from repro.utils.logging import log


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-9b",
                    choices=cfglib.ARCH_NAMES)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()

    cfg = cfglib.get_config(args.arch, reduced=True)
    rng = jax.random.PRNGKey(0)
    params = decoder.model_init(rng, cfg)
    shape = ((args.batch, cfg.n_codebooks, args.prompt_len)
             if cfg.n_codebooks else (args.batch, args.prompt_len))
    prompt = jax.random.randint(rng, shape, 0, cfg.vocab)

    t0 = time.time()
    toks = generate(cfg, params, prompt, gen=args.gen)
    dt = time.time() - t0
    ids = [int(jax.device_get(t).reshape(-1)[0]) for t in toks]
    log(f"{args.arch} (reduced) generated", ids=ids,
        ms_per_tok=f"{1e3 * dt / args.gen:.0f}")
    # long-context note: recurrent/windowed archs keep O(1)/O(window)
    # decode state — the property long_500k exercises at 524k tokens.
    from repro.configs import is_subquadratic
    log(f"sub-quadratic decode state: {is_subquadratic(cfg)}")


if __name__ == "__main__":
    main()
