"""CC-FedAvg as a computation-efficient trainer for LLM-scale clients
(§V: the r=1 special case) — the pod-level regime on reduced configs.

Two "pods" (cross-silo clients) train a reduced assigned architecture; in
each round every pod independently trains with probability 1/W or replays
its stored Δ. The global model still improves every round while gradient
work drops to ~1/W of FedAvg's.

    PYTHONPATH=src python examples/compute_efficient_llm.py \
        [--arch qwen3-1.7b] [--rounds 12] [--w 2]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as cfglib
from repro.core.podlevel import init_pod_fed_state, make_cc_pod_round
from repro.models import decoder
from repro.utils.logging import log

N_PODS = 2


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b",
                    choices=cfglib.ARCH_NAMES)
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--w", type=int, default=2,
                    help="train once every W rounds per pod (p=1/W)")
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = cfglib.get_config(args.arch, reduced=True)
    rng = jax.random.PRNGKey(0)
    state = init_pod_fed_state(rng, cfg, N_PODS)
    round_fn = jax.jit(make_cc_pod_round(
        cfg, lr=5e-2, local_steps=args.local_steps, n_clients=N_PODS))
    eval_batch = {"tokens": jax.random.randint(
        jax.random.fold_in(rng, 99), (args.batch, args.seq), 0, cfg.vocab)}

    @jax.jit
    def eval_loss(params):
        return decoder.loss_and_metrics(params, cfg, eval_batch)[1]["loss"]

    nprng = np.random.default_rng(0)
    trained_rounds = 0
    log(f"pod-level CC-FedAvg(r=1, W={args.w}) on {args.arch} (reduced), "
        f"{N_PODS} pods")
    for t in range(args.rounds):
        # ad-hoc schedule: each pod trains with p = 1/W
        mask = (nprng.random(N_PODS) < 1.0 / args.w).astype(np.float32)
        trained_rounds += int(mask.sum())
        key = jax.random.fold_in(rng, t)
        batches = {"tokens": jax.random.randint(
            key, (N_PODS, args.local_steps, args.batch, args.seq), 0,
            cfg.vocab)}
        state = round_fn(state, batches, jnp.asarray(mask))
        loss = float(eval_loss(state["global_params"]))
        log(f"round {t + 1:3d}", trained=f"{mask.astype(int)}",
            eval_loss=f"{loss:.4f}")
    frac = trained_rounds / (args.rounds * N_PODS)
    log(f"gradient work: {frac:.0%} of FedAvg(full) "
        f"(target ≈ 1/W = {1 / args.w:.0%}); the model improved every "
        f"round regardless — that is the paper's §V result.")


if __name__ == "__main__":
    main()
