"""End-to-end federated driver (deliverable b): trains a ~100k-param CNN
federation for a few hundred rounds with checkpoint/resume, comparing
CC-FedAvg against its baselines under one fixed compute-heterogeneity
profile, and prints a Table-I-style summary.

    PYTHONPATH=src python examples/federated_end_to_end.py \
        [--rounds 200] [--strategies cc s1 s2 fedavg_full]
"""
import argparse
import os

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import CheckpointManager
from repro.core import FedConfig, cost_report, run_federated
from repro.core.schedules import make_plan
from repro.data.federated import build_federated
from repro.data.partition import budget_law, partition_gamma
from repro.data.synthetic import make_dataset, train_test_split
from repro.models.simple import make_classifier
from repro.utils.logging import log
from repro.utils.pytree import tree_bytes, tree_count_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--gamma", type=float, default=0.2)
    ap.add_argument("--beta", type=int, default=4)
    ap.add_argument("--width", type=int, default=12)
    ap.add_argument("--strategies", nargs="+",
                    default=["cc", "cc_decay", "s1", "s2", "fedavg"],
                    help="any names from repro.core.available_strategies()")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_fed_ckpt")
    args = ap.parse_args()

    ds = make_dataset("image", n=2048, n_classes=8, hw=8, channels=1,
                      seed=0)
    train, test = train_test_split(ds)
    parts = partition_gamma(train, args.clients, gamma=args.gamma)
    fd = build_federated(train, parts)
    model = make_classifier("cnn", input_shape=train.x.shape[1:],
                            n_classes=8, width=args.width)
    n_params = tree_count_params(model.init(
        __import__("jax").random.PRNGKey(0)))
    log(f"CNN federation: {args.clients} clients, {n_params:,} params, "
        f"{args.rounds} rounds, γ={args.gamma}")
    p = budget_law(args.clients, args.beta)

    results = {}
    for strat in args.strategies:
        kind = "full" if strat == "fedavg" else "adhoc"
        plan = make_plan(kind, p, args.rounds, seed=0)
        fed = FedConfig(strategy=strat, local_steps=5, batch_size=32,
                        lr=0.05)
        state, metrics = run_federated(
            model, fd, fed, plan, x_test=jnp.asarray(test.x),
            y_test=jnp.asarray(test.y), eval_every=args.rounds // 4,
            verbose=True)
        mgr = CheckpointManager(os.path.join(args.ckpt_dir, strat), keep=1)
        path = mgr.save(args.rounds, state["params"],
                        extra={"acc": metrics.last("test_acc")})
        rep = cost_report(plan, tree_bytes(state["params"]))
        results[strat] = (metrics.last("test_acc"),
                          rep["compute_saved_frac"])
        log(f"saved {path}")

    print(f"\n{'strategy':<14}{'accuracy':>10}{'compute saved':>16}")
    for strat, (acc, saved) in sorted(results.items(),
                                      key=lambda kv: -kv[1][0]):
        print(f"{strat:<14}{acc:>10.3f}{saved:>15.1%}")
    best_constrained = max(
        (s for s in results if s != "fedavg"), key=lambda s: results[s][0])
    print(f"\nbest constrained strategy: {best_constrained} "
          f"(paper's claim: cc)")


if __name__ == "__main__":
    main()
