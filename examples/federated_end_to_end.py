"""End-to-end federated driver: trains a ~100k-param CNN federation for a
few hundred rounds, comparing CC-FedAvg against its baselines under one
fixed compute-heterogeneity profile via the sweep runner, demonstrates a
REAL kill-and-resume (full state, bit-identical), and prints a
Table-I-style summary.

    PYTHONPATH=src python examples/federated_end_to_end.py \
        [--rounds 200] [--strategies cc s1 s2 fedavg]
"""
import argparse
import os
import shutil

import jax
import numpy as np

from repro.api import ExperimentSpec, Session, run_sweep, format_table
from repro.models.simple import make_classifier
from repro.utils.logging import log
from repro.utils.pytree import tree_count_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--gamma", type=float, default=0.2)
    ap.add_argument("--beta", type=int, default=4)
    ap.add_argument("--width", type=int, default=12)
    ap.add_argument("--strategies", nargs="+",
                    default=["cc", "cc_decay", "s1", "s2", "fedavg"],
                    help="any names from repro.core.available_strategies()")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_fed_ckpt")
    args = ap.parse_args()

    base = ExperimentSpec(
        dataset="image", n_samples=2048, n_classes=8, hw=8, channels=1,
        n_clients=args.clients, partition="gamma", gamma=args.gamma,
        budget="power", beta=args.beta, model="cnn", width=args.width,
        strategy="cc", local_steps=5, batch_size=32, lr=0.05,
        schedule="adhoc", rounds=args.rounds,
        eval_every=max(1, args.rounds // 4), seed=0)
    n_params = tree_count_params(make_classifier(
        "cnn", input_shape=(base.hw, base.hw, base.channels),
        n_classes=base.n_classes, width=base.width).init(
            jax.random.PRNGKey(0)))
    log(f"CNN federation: {args.clients} clients, {n_params:,} params, "
        f"{args.rounds} rounds, γ={args.gamma}")

    # ---- strategy comparison via the sweep runner ------------------------
    # fedavg means full participation; everyone else runs the ad-hoc plan
    constrained = [s for s in args.strategies if s != "fedavg"]
    result = run_sweep(base, {"strategy": constrained})
    if "fedavg" in args.strategies:
        sess = Session.from_spec(
            base.replace(strategy="fedavg", schedule="full"))
        sess.run()
        result["cells"]["strategy=fedavg,schedule=full"] = {
            "overrides": {"strategy": "fedavg", "schedule": "full"},
            "acc": sess.metrics.last("test_acc"),
            "acc_best": sess.metrics.best("test_acc"),
            "cost": sess.cost_report(),
        }
        result["ranking"] = sorted(
            result["cells"], key=lambda k: -result["cells"][k]["acc"])

    # ---- real kill-and-resume -------------------------------------------
    # run cc to the halfway point, checkpoint the FULL state (params, Δ
    # history, RNG key, round counter, metrics), throw the session away,
    # rebuild purely from disk, and finish: bit-identical to uninterrupted.
    ckpt = os.path.join(args.ckpt_dir, "cc")
    if os.path.isdir(ckpt):          # stale checkpoints would shadow ours
        shutil.rmtree(ckpt)
    half = Session.from_spec(base, ckpt_dir=ckpt)
    half.run(args.rounds // 2)
    path = half.save()
    log(f"killed at round {half.t}; checkpoint {path}")
    del half
    resumed = Session.restore_from(ckpt)
    log(f"resumed at round {resumed.t}/{args.rounds} from spec in "
        "checkpoint")
    resumed.run()
    cc_key = next((k for k in result["cells"]
                   if result["cells"][k]["overrides"].get("strategy")
                   == "cc"), None)
    if cc_key is None:               # cc wasn't in --strategies: no
        log(f"resume finished at acc "   # uninterrupted twin to compare to
            f"{resumed.metrics.last('test_acc'):.4f}")
    else:
        uninterrupted = result["cells"][cc_key]["acc"]
        match = np.isclose(resumed.metrics.last("test_acc"), uninterrupted,
                           atol=0, rtol=0)
        log(f"resume acc {resumed.metrics.last('test_acc'):.4f} vs "
            f"uninterrupted {uninterrupted:.4f} — "
            f"{'bit-identical' if match else 'MISMATCH'}")

    print()
    print(format_table(result))
    best_constrained = max(
        (k for k in result["cells"]
         if result["cells"][k]["overrides"].get("strategy") != "fedavg"),
        key=lambda k: result["cells"][k]["acc"])
    print(f"\nbest constrained strategy: {best_constrained} "
          f"(paper's claim: cc)")


if __name__ == "__main__":
    main()
