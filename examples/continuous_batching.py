"""Continuous-batching serving demo: a stream of variable-length requests
flows through the slot-based scheduler; the decode batch shape stays
fixed (jit compiles once) while slots retire and back-fill — the
production inner loop behind the decode_32k dry-run shape.

    PYTHONPATH=src python examples/continuous_batching.py \
        [--arch qwen3-1.7b] [--slots 3] [--requests 7]
"""
import argparse
import time

import jax

from repro import configs as cfglib
from repro.models import decoder
from repro.serving import BatchingServer, Request
from repro.utils.logging import log


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b",
                    choices=cfglib.ARCH_NAMES)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--requests", type=int, default=7)
    ap.add_argument("--gen", type=int, default=6)
    args = ap.parse_args()

    cfg = cfglib.get_config(args.arch, reduced=True)
    rng = jax.random.PRNGKey(0)
    params = decoder.model_init(rng, cfg)
    srv = BatchingServer(cfg, params, n_slots=args.slots, capacity=96)

    reqs = []
    for i in range(args.requests):
        prompt = jax.random.randint(jax.random.fold_in(rng, i),
                                    (8 + 3 * i,), 0, cfg.vocab)
        r = Request(uid=i, prompt=prompt,
                    max_new_tokens=args.gen + (i % 3))
        reqs.append(r)
        srv.submit(r)

    log(f"{args.requests} requests → {args.slots} slots "
        f"({args.arch}, reduced)")
    t0 = time.time()
    step = 0
    while srv.queue or any(a is not None for a in srv.active):
        n_active = srv.step()
        step += 1
        if step % 4 == 1:
            slots = ["·" if a is None else str(a.uid)
                     for a in srv.active]
            log(f"step {step:3d}  slots=[{' '.join(slots)}] "
                f"queued={len(srv.queue)} active={n_active}")
    dt = time.time() - t0
    total_toks = sum(len(r.generated) for r in reqs)
    assert all(r.done for r in reqs)
    log(f"served {total_toks} tokens across {args.requests} requests in "
        f"{step} decode steps ({1e3 * dt / max(1, step):.0f} ms/step); "
        f"fixed batch shape -> single compile.")
    for r in reqs[:3]:
        log(f"request {r.uid}: prompt_len={r.prompt.shape[-1]} "
            f"-> {r.generated}")


if __name__ == "__main__":
    main()
