"""Experiment API: spec round-tripping, Session/run_federated equivalence,
bit-identical resume, sweep runner, CLI, and full-state checkpointing.

The acceptance pins of the API redesign live here:

* the ``run_federated`` shim and a Session-driven run produce identical
  final params and metric streams;
* a session checkpointed at round t and restored produces the same state
  trajectory and metrics as an uninterrupted run — bit-identically — for
  ``cc``, ``fednova`` and ``s2`` under both executors.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (Callback, CheckpointCallback, ExperimentSpec,
                       ProbeCallback, Session, VerboseLogger, expand_grid,
                       format_table, run_sweep)
from repro.api.cli import main as cli_main
from repro.checkpoint.store import (CheckpointManager, FED_STATE_KEYS,
                                    POLICY_STATE_KEYS, load_fed_state,
                                    save_fed_state)
from repro.core.engine import run_federated
from repro.core.rounds import init_fed_state


def small_spec(**kw) -> ExperimentSpec:
    base = dict(dataset="gaussian", n_samples=256, dim=8, n_classes=4,
                n_clients=4, partition="gamma", gamma=0.5, budget="power",
                beta=2, model="mlp", width=4, strategy="cc", local_steps=2,
                batch_size=16, lr=0.1, schedule="adhoc", rounds=8,
                eval_every=4, seed=0)
    base.update(kw)
    return ExperimentSpec(**base)


def assert_states_equal(a, b, keys=FED_STATE_KEYS):
    for key in keys:
        for x, y in zip(jax.tree.leaves(a[key]), jax.tree.leaves(b[key])):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=key)


# ---------------------------------------------------------------------------
# spec: serialization round-trips
# ---------------------------------------------------------------------------


def test_spec_dict_round_trip():
    spec = small_spec(strategy="fednova", rounds=11, lr=0.07)
    back = ExperimentSpec.from_dict(spec.to_dict())
    assert back == spec


def test_spec_json_round_trip_through_file(tmp_path):
    spec = small_spec(budget="explicit", p=(1.0, 0.5, 0.5, 0.25))
    path = spec.save(str(tmp_path / "spec.json"))
    back = ExperimentSpec.load(path)
    assert back == spec
    assert back.budgets().tolist() == [1.0, 0.5, 0.5, 0.25]


def test_spec_rejects_unknown_fields_and_values():
    with pytest.raises(ValueError, match="unknown spec fields"):
        ExperimentSpec.from_dict({"no_such_field": 1})
    with pytest.raises(ValueError, match="dataset"):
        small_spec(dataset="cifar10")
    with pytest.raises(ValueError, match="unknown strategy"):
        small_spec(strategy="nope")
    with pytest.raises(ValueError, match="explicit"):
        small_spec(budget="explicit", p=None)


def test_spec_build_is_deterministic():
    a, b = small_spec().build(), small_spec().build()
    np.testing.assert_array_equal(np.asarray(a.data.x), np.asarray(b.data.x))
    np.testing.assert_array_equal(a.plan.training, b.plan.training)
    assert a.plan.rounds == 8


# ---------------------------------------------------------------------------
# acceptance: run_federated shim ≡ Session
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("executor", ["scan", "python"])
def test_shim_matches_session(executor):
    spec = small_spec(executor=executor)
    sess = Session.from_spec(spec).run()
    b = spec.build()
    state, metrics = run_federated(
        b.model, b.data, b.fed, b.plan, x_test=b.x_test, y_test=b.y_test,
        eval_every=spec.eval_every, executor=executor)
    assert metrics.history == sess.metrics.history
    assert_states_equal(state, sess.state)


def test_probe_client_does_not_perturb_trajectory():
    spec = small_spec(rounds=5, eval_every=2)
    b = spec.build()
    kw = dict(x_test=b.x_test, y_test=b.y_test, eval_every=2)
    s_plain, m_plain = run_federated(b.model, b.data, b.fed, b.plan, **kw)
    s_probe, m_probe = run_federated(b.model, b.data, b.fed, b.plan,
                                     probe_client=0, **kw)
    assert m_probe.history["test_acc"] == m_plain.history["test_acc"]
    assert_states_equal(s_plain, s_probe)
    # legacy cadence: probes at rounds 1..T-1, never after the final round
    assert [s for s, _ in m_probe.history["euclid_s3"]] == [1, 2, 3, 4]


# ---------------------------------------------------------------------------
# acceptance: kill-and-resume ≡ uninterrupted, bit-identically
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", ["cc", "fednova", "s2"])
@pytest.mark.parametrize("executor", ["scan", "python"])
def test_resume_matches_uninterrupted(tmp_path, strategy, executor):
    spec = small_spec(strategy=strategy, executor=executor, rounds=10,
                      eval_every=3)
    full = Session.from_spec(spec).run()

    part = Session.from_spec(spec, ckpt_dir=str(tmp_path))
    part.run(4)                       # mid-span interrupt (3 < 4 < 6)
    part.save()
    del part

    resumed = Session.restore_from(str(tmp_path))
    assert resumed.t == 4
    resumed.run()
    assert resumed.metrics.history == full.metrics.history
    assert_states_equal(resumed.state, full.state)


@pytest.mark.parametrize("policy", ["energy", "adaptive", "deadline"])
@pytest.mark.parametrize("executor", ["scan", "python"])
def test_resume_stateful_policy_matches_uninterrupted(tmp_path, policy,
                                                      executor):
    """Runtime policies carry live state (policy rows, device energy/load,
    ledger) in the round carry; a mid-span save/restore must continue
    bit-identically — including the books."""
    spec = small_spec(policy=policy, executor=executor, rounds=10,
                      eval_every=3, load_mean=0.3, load_jitter=0.2,
                      energy_init=1.0)
    full = Session.from_spec(spec).run()

    part = Session.from_spec(spec, ckpt_dir=str(tmp_path))
    part.run(4)                       # mid-span interrupt (3 < 4 < 6)
    part.save()
    del part

    resumed = Session.restore_from(str(tmp_path))
    assert resumed.t == 4
    resumed.run()
    assert resumed.metrics.history == full.metrics.history
    assert_states_equal(resumed.state, full.state,
                        keys=FED_STATE_KEYS + POLICY_STATE_KEYS)


def test_policy_state_rides_checkpoints(tmp_path):
    """The checkpoint file itself carries the policy/device/ledger rows
    (not just the base fed state), and save_fed_state refuses a policy-mode
    state that lost some of them."""
    spec = small_spec(policy="energy", rounds=4, eval_every=4)
    sess = Session.from_spec(spec, ckpt_dir=str(tmp_path))
    sess.run()
    path = sess.save()
    import numpy as _np
    with _np.load(path) as z:
        keys = set(z.files)
    assert any(k.startswith("ledger/") for k in keys)
    assert any(k.startswith("device/") for k in keys)
    state = dict(sess.state)
    state.pop("ledger")
    with pytest.raises(ValueError, match="policy-mode"):
        save_fed_state(str(tmp_path / "bad.npz"), state)


def test_resume_restores_metric_history(tmp_path):
    spec = small_spec(rounds=8, eval_every=2)
    sess = Session.from_spec(spec, ckpt_dir=str(tmp_path))
    sess.run(6)
    sess.save()
    resumed = Session.restore_from(str(tmp_path))
    # evals at 2, 4, 6 survive the round-trip with exact values
    assert resumed.metrics.history == sess.metrics.history
    resumed.run()
    assert [s for s, _ in resumed.metrics.history["test_acc"]] == [2, 4, 6, 8]


def test_step_equals_run(tmp_path):
    spec = small_spec(rounds=6, eval_every=6)
    by_run = Session.from_spec(spec).run()
    by_step = Session.from_spec(spec)
    while not by_step.done:
        by_step.step()
    assert_states_equal(by_run.state, by_step.state)
    assert by_step.t == 6
    with pytest.raises(RuntimeError, match="plan exhausted"):
        by_step.step()


def test_run_is_idempotent_after_completion():
    sess = Session.from_spec(small_spec()).run()
    n_evals = len(sess.metrics.history["test_acc"])
    sess.run()                        # no-op: no duplicate eval records
    assert len(sess.metrics.history["test_acc"]) == n_evals


# ---------------------------------------------------------------------------
# callbacks
# ---------------------------------------------------------------------------


class _Recorder(Callback):
    def __init__(self, sync_every=None):
        self.sync_every = sync_every
        self.round_ends, self.evals, self.ckpts = [], [], []

    def on_round_end(self, session, t):
        self.round_ends.append(t)

    def on_eval(self, session, t, acc):
        self.evals.append(t)

    def on_checkpoint(self, session, t, path):
        self.ckpts.append((t, path))


def test_callback_sync_every_splits_spans_without_changing_evals():
    rec = _Recorder(sync_every=5)
    spec = small_spec(rounds=12, eval_every=4)
    sess = Session.from_spec(spec, callbacks=[rec]).run()
    assert rec.round_ends == [4, 5, 8, 10, 12]       # eval ∪ sync points
    assert rec.evals == [4, 8, 12]                   # cadence unchanged
    assert [s for s, _ in sess.metrics.history["test_acc"]] == [4, 8, 12]


def test_checkpoint_callback_writes_full_state(tmp_path):
    rec = _Recorder()
    spec = small_spec(rounds=8, eval_every=4)
    sess = Session.from_spec(
        spec, callbacks=[CheckpointCallback(3), rec],
        ckpt_dir=str(tmp_path), keep=10)
    sess.run()
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.steps() == [3, 6]
    assert [t for t, _ in rec.ckpts] == [3, 6]
    like = init_fed_state(jax.random.PRNGKey(spec.seed),
                          spec.build().model, spec.n_clients)
    state, extra = load_fed_state(os.path.join(str(tmp_path),
                                               "ckpt_00000006.npz"), like)
    assert int(state["round"]) == 6
    assert extra["spec"]["strategy"] == "cc"


def test_verbose_logger_runs(capsys):
    Session.from_spec(small_spec(rounds=4, eval_every=2),
                      callbacks=[VerboseLogger()]).run()
    err = capsys.readouterr().err
    assert "round 2/4" in err and "round 4/4" in err


# ---------------------------------------------------------------------------
# full-state checkpoint helpers
# ---------------------------------------------------------------------------


def test_save_fed_state_rejects_params_only(tmp_path):
    with pytest.raises(ValueError, match="missing"):
        save_fed_state(str(tmp_path / "x.npz"),
                       {"params": {"w": jnp.ones((2,))}})


def test_manager_read_extra(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, {"w": jnp.ones((2,))}, extra={"note": "hi"})
    assert mgr.read_extra()["note"] == "hi"
    assert mgr.read_extra()["step"] == 3


# ---------------------------------------------------------------------------
# sweep runner
# ---------------------------------------------------------------------------


def test_expand_grid_cartesian_product():
    cells = expand_grid(small_spec(), {"strategy": ["cc", "s2"],
                                       "beta": [1, 2]})
    assert len(cells) == 4
    assert cells[0][0] == {"strategy": "cc", "beta": 1}
    assert {c[1].strategy for c in cells} == {"cc", "s2"}
    assert expand_grid(small_spec(), {})[0][0] == {}


def test_run_sweep_emits_table_and_costs():
    result = run_sweep(small_spec(rounds=4, eval_every=4),
                       {"strategy": ["cc", "s1"]}, verbose=False)
    assert set(result["cells"]) == {"strategy=cc", "strategy=s1"}
    for cell in result["cells"].values():
        assert 0.0 <= cell["acc"] <= 1.0
        assert "compute_saved_frac" in cell["cost"]
    assert result["ranking"][0] in result["cells"]
    table = format_table(result)
    assert "strategy=cc" in table and "compute saved" in table


def test_sweep_cell_matches_direct_session():
    spec = small_spec(rounds=4, eval_every=4)
    result = run_sweep(spec, {"strategy": ["cc"]}, verbose=False)
    direct = Session.from_spec(spec).run()
    assert result["cells"]["strategy=cc"]["acc"] == \
        direct.metrics.last("test_acc")


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_init_run_resume(tmp_path, capsys):
    spec_path = str(tmp_path / "spec.json")
    ckpt_dir = str(tmp_path / "ckpt")
    assert cli_main(["init", spec_path, "--set", "rounds=4",
                     "--set", "eval_every=2", "--set", "n_samples=256",
                     "--set", "dim=8", "--set", "n_classes=4",
                     "--set", "n_clients=4", "--set", "width=4",
                     "--set", "local_steps=2"]) == 0
    spec = ExperimentSpec.load(spec_path)
    assert spec.rounds == 4 and spec.eval_every == 2

    out_path = str(tmp_path / "run.json")
    assert cli_main(["run", spec_path, "--ckpt-dir", ckpt_dir,
                     "--out", out_path, "--quiet"]) == 0
    summary = json.loads(capsys.readouterr().out.strip().splitlines()[-1])
    assert summary["rounds_done"] == 4
    with open(out_path) as f:
        dumped = json.load(f)
    assert dumped["spec"]["rounds"] == 4
    assert [s for s, _ in dumped["metrics"]["test_acc"]] == [2, 4]

    assert cli_main(["resume", ckpt_dir, "--quiet"]) == 0
    summary = json.loads(capsys.readouterr().out.strip().splitlines()[-1])
    assert summary["rounds_done"] == 4      # plan already finished


def test_cli_sweep(tmp_path, capsys):
    spec_path = str(tmp_path / "spec.json")
    cli_main(["init", spec_path, "--set", "rounds=2",
              "--set", "eval_every=2", "--set", "n_samples=256",
              "--set", "dim=8", "--set", "n_classes=4",
              "--set", "n_clients=4", "--set", "width=4",
              "--set", "local_steps=2"])
    capsys.readouterr()
    assert cli_main(["sweep", spec_path, "--grid", "strategy=cc,s1",
                     "--quiet"]) == 0
    out = capsys.readouterr().out
    assert "strategy=cc" in out and "strategy=s1" in out
