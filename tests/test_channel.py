"""Properties of the uplink-channel abstraction and the FedDyn dual.

Property tests (real hypothesis when installed; the deterministic replay
shim otherwise):

* the noiseless channel is a literal identity — ``fade``/``corrupt``
  return the INPUT OBJECT, so executors guarded on ``uplink_channel()``
  returning None can never diverge from exact aggregation;
* aircomp AWGN lands at the configured receive SNR: the measured noise
  power over a large tree is within 10% of ``10^(−snr_db/10)`` of the
  signal power;
* Rayleigh gains are cohort/shard-invariant: slicing any id subset out
  of the full-federation draw equals drawing and indexing — the property
  the sharded/hierarchical executors rely on for equivalence;
* the FedDyn dual roll is mask-idempotent: clients outside
  ``sel ∧ train`` keep their dual rows BIT-unchanged.

Plus the spec-v6/deadlock regressions and the FedDyn checkpoint/resume
bit-identity pin the ISSUE requires.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.channel import (CHANNEL_KINDS, TAG_C2E, TAG_UPLINK,
                                UplinkChannel, uplink_channel)
from repro.core.rounds import FedConfig
from repro.core.strategies import RoundCtx, get_strategy
from repro.utils.pytree import tree_broadcast_clients


def _tree(seed=0, n=4):
    k = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(k)
    return {"w": jax.random.normal(k1, (n, 6, 3)),
            "b": jax.random.normal(k2, (n, 3))}


# ---------------------------------------------------------------------------
# channel properties
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2 ** 16), rnd=st.integers(0, 1000),
       snr=st.floats(min_value=-10.0, max_value=40.0),
       fading=st.booleans())
def test_noiseless_channel_is_identity(seed, rnd, snr, fading):
    ch = UplinkChannel(kind="noiseless", snr_db=snr, fading=fading,
                       seed=seed)
    t = _tree(seed % 7)
    ids = jnp.arange(4, dtype=jnp.int32)
    assert ch.fade(t, rnd, ids, 4, TAG_UPLINK) is t
    assert ch.corrupt(t, rnd, TAG_UPLINK) is t


def test_uplink_channel_returns_none_for_noiseless():
    assert uplink_channel(FedConfig(strategy="cc")) is None
    ch = uplink_channel(FedConfig(strategy="cc", channel="aircomp",
                                  channel_snr_db=7.0, channel_fading=True,
                                  seed=3))
    assert isinstance(ch, UplinkChannel)
    assert (ch.kind, ch.snr_db, ch.fading, ch.seed) == ("aircomp", 7.0,
                                                        True, 3)


@settings(max_examples=6, deadline=None)
@given(snr=st.sampled_from([0.0, 10.0, 20.0]),
       seed=st.integers(0, 2 ** 10), rnd=st.integers(0, 100))
def test_aircomp_noise_power_tracks_snr(snr, seed, rnd):
    """Measured noise power within 10% of 10^(−snr/10) × signal power.

    A constant-ones tree has unit rms, so sigma² IS the relative noise
    power; 40000 samples put the empirical variance well inside ±10%."""
    ch = UplinkChannel(kind="aircomp", snr_db=snr, seed=seed)
    t = {"a": jnp.ones((200, 100)), "b": jnp.ones((200, 100))}
    out = ch.corrupt(t, rnd, TAG_UPLINK)
    noise = np.concatenate([
        (np.asarray(out[k]) - 1.0).ravel() for k in ("a", "b")])
    measured = float((noise ** 2).mean())
    expected = 10.0 ** (-snr / 10.0)
    assert abs(measured - expected) <= 0.1 * expected, (measured, expected)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2 ** 16), rnd=st.integers(0, 1000),
       tag=st.sampled_from([TAG_UPLINK, TAG_C2E]))
def test_gains_are_cohort_invariant(seed, rnd, tag):
    """Slicing a subset of clients out of the full draw == indexing —
    sharded cohorts and edge shards see the flat executor's gains."""
    ch = UplinkChannel(kind="aircomp", fading=True, seed=seed)
    n = 16
    full = ch.gains(rnd, jnp.arange(n, dtype=jnp.int32), n, tag)
    sub = jnp.asarray([3, 7, 11], dtype=jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(ch.gains(rnd, sub, n, tag)),
        np.asarray(full)[np.asarray(sub)])
    # unit mean power (Rayleigh with E[h²]=1) — loose sanity bound
    assert 0.3 < float((full ** 2).mean()) < 3.0


def test_gains_differ_across_rounds_and_tags():
    ch = UplinkChannel(kind="aircomp", fading=True, seed=0)
    ids = jnp.arange(8, dtype=jnp.int32)
    g0 = np.asarray(ch.gains(0, ids, 8, TAG_UPLINK))
    assert not np.array_equal(g0, np.asarray(ch.gains(1, ids, 8,
                                                      TAG_UPLINK)))
    assert not np.array_equal(g0, np.asarray(ch.gains(0, ids, 8, TAG_C2E)))


def test_channel_rejects_unknown_kind():
    with pytest.raises(ValueError, match="channel kind"):
        UplinkChannel(kind="quantum")
    assert CHANNEL_KINDS == ("noiseless", "aircomp")


# ---------------------------------------------------------------------------
# FedDyn dual properties
# ---------------------------------------------------------------------------


def _ctx(sel, train, n):
    z = {"w": jnp.zeros((n, 2))}
    return RoundCtx(sel_mask=jnp.asarray(sel), train_mask=jnp.asarray(train),
                    k_active=jnp.full((n,), 2, jnp.int32),
                    round=jnp.asarray(0, jnp.int32), tau=100,
                    stale_delta=z, trained_delta=z)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2 ** 16), mask_seed=st.integers(0, 2 ** 16))
def test_feddyn_dual_update_is_mask_idempotent(seed, mask_seed):
    """h_i ← h_i − α·Δ_i only where sel ∧ train; idle clients' dual rows
    stay BIT-unchanged (the invariant that makes mid-span resume and the
    cohort executors exact)."""
    n = 6
    strat = dataclasses.replace(get_strategy("feddyn"), alpha=0.3)
    km, kd, kh = jax.random.split(jax.random.PRNGKey(seed), 3)
    sel = jax.random.bernoulli(jax.random.PRNGKey(mask_seed), 0.5, (n,))
    train = jax.random.bernoulli(kd, 0.5, (n,))
    dual = {"w": jax.random.normal(km, (n, 2))}
    delta = {"w": jax.random.normal(kh, (n, 2))}
    state = {"dual": dual}
    out = strat.update_extra_history(state, _ctx(sel, train, n), delta,
                                     None, None)["dual"]
    upd = np.asarray(sel & train)
    got, before = np.asarray(out["w"]), np.asarray(dual["w"])
    np.testing.assert_array_equal(got[~upd], before[~upd])
    np.testing.assert_allclose(
        got[upd], before[upd] - 0.3 * np.asarray(delta["w"])[upd],
        rtol=1e-6)


def test_feddyn_alpha_zero_is_inert():
    """α=0 (the default wiring for non-feddyn runs): no dual gradient
    correction and the dual roll is the identity carry."""
    strat = get_strategy("feddyn")
    assert strat.alpha == 0.0 and strat.prox_coeff() == 0.0
    n = 3
    dual = tree_broadcast_clients({"w": jnp.ones((2,))}, n)
    state = {"dual": dual}
    assert strat.local_dual(state) is None
    out = strat.update_extra_history(
        state, _ctx(jnp.ones(n, bool), jnp.ones(n, bool), n),
        {"w": jnp.ones((n, 2))}, None, None)
    assert out["dual"] is dual


def test_feddyn_configure_threads_fed_fields():
    fed = FedConfig(strategy="feddyn", feddyn_alpha=0.25)
    strat = fed.resolve()
    assert strat.name == "feddyn" and strat.alpha == 0.25
    assert strat.prox_coeff() == 0.25
    fedprox = FedConfig(strategy="fedprox", prox_mu=0.5).resolve()
    assert fedprox.mu == 0.5 and fedprox.prox_coeff() == 0.5
    # default configs resolve to the registered singletons (plugin pin)
    assert FedConfig(strategy="feddyn").resolve() is get_strategy("feddyn")


# ---------------------------------------------------------------------------
# FedDyn checkpoint/resume: the dual rides the checkpoint bit-for-bit
# ---------------------------------------------------------------------------


def test_feddyn_checkpoint_resume_is_bit_identical(tmp_path):
    from repro.api import ExperimentSpec, Session
    spec = ExperimentSpec(
        dataset="gaussian", n_samples=256, dim=8, n_classes=4, n_clients=4,
        model="mlp", width=4, strategy="feddyn", feddyn_alpha=0.1,
        local_steps=2, batch_size=16, lr=0.1, rounds=6, eval_every=2,
        seed=0)
    full = Session.from_spec(spec).run()
    assert "dual" in full.state

    part = Session.from_spec(spec, ckpt_dir=str(tmp_path))
    part.run(3)
    part.save()
    resumed = Session.restore_from(str(tmp_path)).run()
    assert resumed.metrics.series("test_acc") == \
        full.metrics.series("test_acc")
    for key in ("params", "dual", "deltas", "trained_ever"):
        for a, b in zip(jax.tree.leaves(resumed.state[key]),
                        jax.tree.leaves(full.state[key])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=key)


# ---------------------------------------------------------------------------
# spec v6 + async validation regressions
# ---------------------------------------------------------------------------


def test_spec_rejects_channel_fields_without_aircomp():
    from repro.api import ExperimentSpec
    with pytest.raises(ValueError, match="aircomp"):
        ExperimentSpec(channel_snr_db=5.0)
    with pytest.raises(ValueError, match="aircomp"):
        ExperimentSpec(channel_fading=True)
    with pytest.raises(ValueError, match="channel"):
        ExperimentSpec(channel="quantum")


def test_spec_rejects_mismatched_strategy_hyperparams():
    from repro.api import ExperimentSpec
    with pytest.raises(ValueError, match="fedprox"):
        ExperimentSpec(prox_mu=0.1)
    with pytest.raises(ValueError, match="feddyn"):
        ExperimentSpec(feddyn_alpha=0.1, strategy="cc")
    with pytest.raises(ValueError, match=">= 0"):
        ExperimentSpec(strategy="fedprox", prox_mu=-0.1)


def test_async_cohort_smaller_than_buffer_deadlocks_eagerly():
    """cohort_size < async_buffer can never fill the merge buffer — both
    the spec and a directly-constructed Session reject it eagerly instead
    of hanging the merge loop."""
    from repro.api import ExperimentSpec
    with pytest.raises(ValueError, match="deadlock"):
        ExperimentSpec(executor="async", async_buffer=3, cohort_size=2)
    # and below the spec layer (Session wiring)
    from repro.api import Session
    from repro.core.async_rounds import AsyncConfig
    from repro.core.schedules import make_plan
    from repro.data.federated import build_federated
    from repro.data.partition import partition_gamma
    from repro.data.synthetic import make_dataset, train_test_split
    from repro.models.simple import make_classifier
    ds = make_dataset("gaussian", n=64, dim=8, n_classes=4, seed=0)
    tr, _ = train_test_split(ds)
    fd = build_federated(tr, partition_gamma(tr, 4, gamma=0.5, seed=0))
    model = make_classifier("mlp", input_shape=(8,), n_classes=4, width=4)
    with pytest.raises(ValueError, match="deadlock"):
        Session(model, fd,
                FedConfig(strategy="cc", cohort_size=2),
                make_plan("full", np.ones(4), 2), executor="async",
                async_cfg=AsyncConfig(buffer_size=3))


def test_async_cohort_size_thins_arrivals():
    """executor='async' + cohort_size: only sampled cohort members may
    dispatch each round, so realized arrivals shrink vs full async."""
    from repro.api import ExperimentSpec, Session
    base = ExperimentSpec(
        dataset="gaussian", n_samples=256, dim=8, n_classes=4, n_clients=4,
        model="mlp", width=4, strategy="cc", local_steps=2, batch_size=16,
        rounds=6, eval_every=6, seed=0, executor="async", async_buffer=2,
        async_latency=1.0)
    full = Session.from_spec(base).run()
    thin = Session.from_spec(base.replace(cohort_size=2)).run()
    assert thin.staleness_summary()["arrivals"] < \
        full.staleness_summary()["arrivals"]
