"""Async executor + sharded int8 history store.

Covers what the executor matrix doesn't: input validation of the async
knobs (spec, config, arrival simulator), the arrival process's structural
invariants (one in-flight update per client, delivery ⊆ dispatch, K-merge
cadence), the int8 history store's layout/round-trip/memory math, the
int8-vs-dense numerical budget under real staleness, mid-run checkpoint
resume bit-identity (including the in-flight buffer), and the
ledger-driven arrival accounting behind ``Session.cost_report`` /
``Session.staleness_summary``.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentSpec, Session
from repro.checkpoint.store import save_fed_state
from repro.core.async_rounds import AsyncConfig, staleness_weights
from repro.core.compress import dequantize_rows, quantize_rows
from repro.core.history_store import TILE, HistoryStore, padded_width
from repro.system.devices import make_profile, simulate_arrivals

N = 4


def _spec(**kw) -> ExperimentSpec:
    base = dict(dataset="gaussian", n_samples=256, dim=8, n_classes=4,
                n_clients=N, budget="power", beta=2, model="mlp", width=4,
                local_steps=2, batch_size=16, lr=0.1, schedule="adhoc",
                rounds=6, eval_every=2, seed=0, executor="async")
    base.update(kw)
    return ExperimentSpec(**base)


# ---------------------------------------------------------------------------
# satellite: input validation + regression errors
# ---------------------------------------------------------------------------


def test_async_config_rejects_bad_values():
    with pytest.raises(ValueError, match="buffer size"):
        AsyncConfig(buffer_size=0)
    with pytest.raises(ValueError, match="buffer size"):
        AsyncConfig(buffer_size=1.5)
    with pytest.raises(ValueError, match="staleness_decay"):
        AsyncConfig(staleness_decay=0.0)
    with pytest.raises(ValueError, match="staleness_decay"):
        AsyncConfig(staleness_decay=1.2)
    with pytest.raises(ValueError, match="schedule"):
        AsyncConfig(schedule="exponential")
    with pytest.raises(ValueError, match="latency"):
        AsyncConfig(latency=-1.0)
    with pytest.raises(ValueError, match="jitter"):
        AsyncConfig(jitter=-0.1)
    with pytest.raises(ValueError, match="history_store"):
        AsyncConfig(history_store="f16")
    # the boundary values are legal
    AsyncConfig(buffer_size=1, staleness_decay=1.0, latency=0.0, jitter=0.0)


def test_spec_validates_async_fields():
    with pytest.raises(ValueError, match="buffer size"):
        _spec(async_buffer=0)
    with pytest.raises(ValueError, match="staleness_decay"):
        _spec(staleness_decay=2.0)
    with pytest.raises(ValueError, match="latency"):
        _spec(async_latency=-1.0)
    with pytest.raises(ValueError, match="use_fused"):
        _spec(use_fused=True)
    with pytest.raises(ValueError, match="history_store"):
        _spec(history_store="f16")
    # async knobs on a synchronous executor are a config error, not a
    # silent no-op
    with pytest.raises(ValueError, match="executor='async'"):
        _spec(executor="scan", async_buffer=4)
    with pytest.raises(ValueError, match="executor='async'"):
        _spec(executor="python", history_store="int8")


def test_spec_round_trips_async_fields():
    spec = _spec(async_buffer=3, staleness_decay=0.7,
                 staleness_schedule="polynomial", async_latency=2.0,
                 async_jitter=0.5, history_store="int8")
    again = ExperimentSpec.from_json(spec.to_json())
    assert again == spec
    cfg = again.async_config()
    assert cfg == AsyncConfig(buffer_size=3, staleness_decay=0.7,
                              schedule="polynomial", latency=2.0,
                              jitter=0.5, history_store="int8")
    assert _spec().replace(executor="scan").async_config() is None


def test_simulate_arrivals_rejects_bad_values():
    prof = make_profile("budget", np.full(N, 0.5))
    sel = np.ones((3, N), bool)
    with pytest.raises(ValueError, match="buffer size"):
        simulate_arrivals(prof, sel, buffer_size=0)
    with pytest.raises(ValueError, match="latency"):
        simulate_arrivals(prof, sel, latency=-1.0)
    with pytest.raises(ValueError, match="jitter"):
        simulate_arrivals(prof, sel, jitter=-0.5)
    with pytest.raises(ValueError, match="bool table"):
        simulate_arrivals(prof, np.ones(N, bool))
    with pytest.raises(ValueError, match="clients"):
        simulate_arrivals(prof, np.ones((3, N + 1), bool))


def test_session_rejects_async_cfg_on_sync_executor():
    from repro.core.rounds import FedConfig
    from repro.core.schedules import make_plan
    from repro.data.federated import build_federated
    from repro.data.partition import partition_gamma
    from repro.data.synthetic import make_dataset, train_test_split
    from repro.models.simple import make_classifier
    ds = make_dataset("gaussian", n=64, dim=8, n_classes=4, seed=0)
    tr, _ = train_test_split(ds)
    fd = build_federated(tr, partition_gamma(tr, N, gamma=0.5, seed=0))
    model = make_classifier("mlp", input_shape=(8,), n_classes=4, width=4)
    with pytest.raises(ValueError, match="executor='async'"):
        Session(model, fd, FedConfig(strategy="cc"),
                make_plan("full", np.ones(N), 2), executor="scan",
                async_cfg=AsyncConfig())


# ---------------------------------------------------------------------------
# arrival-process simulator invariants
# ---------------------------------------------------------------------------


def test_zero_lag_collapses_to_selection():
    prof = make_profile("budget", np.full(N, 0.5), seed=3)
    rng = np.random.default_rng(0)
    sel = rng.random((12, N)) < 0.6
    sched = simulate_arrivals(prof, sel, buffer_size=1)
    np.testing.assert_array_equal(sched.dispatch, sel)
    np.testing.assert_array_equal(sched.deliver, sel)
    np.testing.assert_array_equal(sched.merge, sel.any(axis=1))


def test_one_in_flight_update_per_client():
    """Between a dispatch and its delivery the client never re-dispatches,
    and every delivery has a matching earlier (or same-round) dispatch."""
    prof = make_profile("budget", np.full(N, 0.5), load_mean=0.3,
                        load_jitter=0.2, seed=3)
    sel = np.ones((30, N), bool)
    sched = simulate_arrivals(prof, sel, buffer_size=2, latency=2.0,
                              jitter=1.0)
    in_flight = np.zeros(N, bool)
    pending = np.zeros(N, bool)
    for t in range(30):
        assert not (sched.dispatch[t] & (in_flight | pending)).any()
        in_flight |= sched.dispatch[t]
        assert (sched.deliver[t] <= in_flight).all()
        in_flight &= ~sched.deliver[t]
        pending |= sched.deliver[t]
        if sched.merge[t]:
            assert pending.sum() >= 2          # the K-arrival trigger
            pending[:] = False
    # cumulative conservation: every delivery was dispatched
    assert sched.deliver.sum() <= sched.dispatch.sum()


def test_latency_scales_with_device_speed():
    """Slow devices (small flops_rate) deliver later than fast ones under
    the same nominal latency — the arrival process is profile-driven."""
    p = np.array([1.0, 1.0, 0.25, 0.25])
    prof = make_profile("budget", p, seed=0)
    sel = np.ones((40, N), bool)
    sched = simulate_arrivals(prof, sel, buffer_size=1, latency=2.0)
    arrivals = sched.deliver.sum(axis=0)
    assert arrivals[0] > arrivals[2], (
        f"fast client delivered {arrivals[0]}x vs slow {arrivals[2]}x")


def test_merge_cadence_respects_buffer_size():
    prof = make_profile("budget", np.full(N, 0.5), seed=1)
    full = np.ones((20, N), bool)
    for k in (1, 3, N):
        # zero-lag full participation: N arrivals land every round, ≥ any
        # legal K, so the buffer flushes every round
        assert simulate_arrivals(prof, full, buffer_size=k).merge.all()
    # one arrival per round (round-robin singletons): merges every K-th
    sel = np.zeros((20, N), bool)
    sel[np.arange(20), np.arange(20) % N] = True
    sched = simulate_arrivals(prof, sel, buffer_size=3)
    np.testing.assert_array_equal(sched.merge,
                                  np.arange(1, 21) % 3 == 0)
    # a buffer larger than the federation could never fill — rejected
    with pytest.raises(ValueError, match="n_clients"):
        simulate_arrivals(prof, full, buffer_size=N + 1)
    with pytest.raises(ValueError, match="n_clients"):
        _spec(async_buffer=N + 1)


# ---------------------------------------------------------------------------
# staleness-decay schedules
# ---------------------------------------------------------------------------


def test_staleness_weights_shapes_and_monotonicity():
    s = jnp.arange(6, dtype=jnp.int32)
    for schedule in ("geometric", "polynomial"):
        w = np.asarray(staleness_weights(schedule, 0.8, s))
        assert w[0] == 1.0                     # exact — the collapse pin
        assert (np.diff(w) < 0).all()          # strictly decaying
        assert (w > 0).all()
    # decay=1.0 means no decay at all, any staleness
    w = np.asarray(staleness_weights("geometric", 1.0, s))
    np.testing.assert_array_equal(w, 1.0)
    with pytest.raises(ValueError, match="schedule"):
        staleness_weights("exponential", 0.9, s)


# ---------------------------------------------------------------------------
# history store: layout, round-trip, memory math
# ---------------------------------------------------------------------------


def test_history_store_validation():
    with pytest.raises(ValueError, match="kind"):
        HistoryStore(4, 512, kind="f16")
    with pytest.raises(ValueError, match="n_clients"):
        HistoryStore(0, 512)
    with pytest.raises(ValueError, match="width"):
        HistoryStore(4, 0)
    store = HistoryStore(4, 512, kind="int8")
    with pytest.raises(ValueError, match="carry"):
        store.like({"rows": None})
    HistoryStore(4, 512, kind="dense").like({"rows": None})


def test_padded_width_tiles():
    assert padded_width(1) == TILE
    assert padded_width(TILE) == TILE
    assert padded_width(TILE + 1) == 2 * TILE


@pytest.mark.parametrize("kind", ["dense", "int8"])
def test_history_store_read_write_round_trip(kind):
    store = HistoryStore(6, TILE, kind=kind)
    carry = store.init()
    store.like(carry)
    np.testing.assert_array_equal(np.asarray(store.read(carry)), 0.0)
    rng = np.random.default_rng(0)
    rows = jnp.asarray(rng.standard_normal((6, TILE)), jnp.float32)
    mask = jnp.asarray([True, False, True, True, False, False])
    new = store.write(carry, mask, rows)
    got = np.asarray(store.read(new))
    atol = 0.0 if kind == "dense" else np.abs(rows).max() / 127 + 1e-6
    np.testing.assert_allclose(got[np.asarray(mask)],
                               np.asarray(rows)[np.asarray(mask)],
                               atol=atol)
    np.testing.assert_array_equal(got[~np.asarray(mask)], 0.0)
    # cohort gather matches the full read
    idx = jnp.asarray([0, 3])
    np.testing.assert_array_equal(np.asarray(store.read(new, idx)),
                                  got[np.asarray(idx)])
    # cohort scatter lands only at idx
    upd = jnp.ones((2, TILE), jnp.float32)
    scattered = store.scatter(new, idx, upd)
    got2 = np.asarray(store.read(scattered))
    np.testing.assert_allclose(got2[np.asarray(idx)], 1.0,
                               atol=atol if kind == "int8" else 0.0)
    np.testing.assert_array_equal(got2[1], got[1])


def test_int8_masked_write_keeps_unmasked_bits_verbatim():
    """The bit-identity contract behind checkpoint resume: rows OUTSIDE
    the write mask keep their stored payload/scale bits exactly — no
    requantization drift for clients that didn't deliver."""
    store = HistoryStore(4, TILE, kind="int8")
    rng = np.random.default_rng(1)
    carry = store.write(store.init(), jnp.ones(4, bool),
                        jnp.asarray(rng.standard_normal((4, TILE)),
                                    jnp.float32))
    mask = jnp.asarray([True, False, False, True])
    new = store.write(carry, mask,
                      jnp.asarray(rng.standard_normal((4, TILE)),
                                  jnp.float32))
    keep = ~np.asarray(mask)
    np.testing.assert_array_equal(np.asarray(new["payload"])[keep],
                                  np.asarray(carry["payload"])[keep])
    np.testing.assert_array_equal(np.asarray(new["scales"])[keep],
                                  np.asarray(carry["scales"])[keep])


def test_history_store_memory_math():
    """The acceptance bound: at P = 1024 the int8 store holds ≤ 30% of the
    dense f32 bytes — N·P + 4·N vs 4·N·P."""
    for n in (100, 10_000, 100_000):
        dense = HistoryStore(n, 1024, kind="dense")
        q8 = HistoryStore(n, 1024, kind="int8")
        assert dense.nbytes() == 4 * n * 1024
        assert q8.nbytes() == n * 1024 + 4 * n
        assert q8.nbytes() / dense.nbytes() <= 0.30
    # carry_bytes agrees with the layout math on materialized carries
    store = HistoryStore(8, TILE, kind="int8")
    assert HistoryStore.carry_bytes(store.init()) == store.nbytes()
    dense = HistoryStore(8, TILE, kind="dense")
    assert HistoryStore.carry_bytes(dense.init()) == dense.nbytes()


def test_q8_gather_scatter_ops_match_reference():
    from repro.kernels.ops import q8_gather_rows, q8_scatter_rows
    rng = np.random.default_rng(2)
    rows = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    payload, scales = quantize_rows(rows)
    idx = jnp.asarray([1, 5, 2])
    got = q8_gather_rows(payload, scales, idx)
    want = dequantize_rows(payload, scales)[idx]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    upd = jnp.asarray(rng.standard_normal((3, 64)), jnp.float32)
    new_p, new_s = q8_scatter_rows(payload, scales, idx, upd)
    ref_p, ref_s = quantize_rows(upd)
    np.testing.assert_array_equal(np.asarray(new_p[idx]), np.asarray(ref_p))
    np.testing.assert_array_equal(np.asarray(new_s[idx]), np.asarray(ref_s))
    keep = np.setdiff1d(np.arange(8), np.asarray(idx))
    np.testing.assert_array_equal(np.asarray(new_p)[keep],
                                  np.asarray(payload)[keep])


def test_history_store_shard_requires_divisibility():
    store = HistoryStore(len(jax.devices()) * 2 + 1, TILE, kind="int8")
    if len(jax.devices()) > 1:
        with pytest.raises(ValueError, match="divide"):
            store.shard(store.init())
    even = HistoryStore(len(jax.devices()) * 2, TILE, kind="int8")
    sharded = even.shard(even.init())
    assert set(sharded) == {"payload", "scales"}


# ---------------------------------------------------------------------------
# int8 store vs dense under real staleness (the non-collapse regime)
# ---------------------------------------------------------------------------


def test_int8_store_matches_dense_within_q8_bounds():
    spec = dict(async_buffer=2, async_latency=1.0, async_jitter=0.5,
                staleness_decay=0.8)
    dense = Session.from_spec(_spec(**spec)).run()
    q8 = Session.from_spec(_spec(**spec, history_store="int8")).run()
    # identical arrival process, near-identical numerics (q8 error only)
    assert dense.staleness_summary() == q8.staleness_summary()
    for a, b in zip(jax.tree.leaves(dense.state["params"]),
                    jax.tree.leaves(q8.state["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-2)
    np.testing.assert_allclose(dense.metrics.series("test_acc"),
                               q8.metrics.series("test_acc"), atol=2.5e-2)
    assert set(q8.state["deltas"]) == {"payload", "scales"}


# ---------------------------------------------------------------------------
# checkpoint: mid-run resume bit-identity (async carry + int8 store)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("store", ["dense", "int8"])
def test_mid_run_resume_is_bit_identical(store):
    """Kill the run mid-span — with updates in flight AND buffered — and
    the restored session must finish with bit-identical state + metrics."""
    spec = _spec(async_buffer=3, async_latency=2.0, async_jitter=1.0,
                 staleness_decay=0.7, history_store=store, rounds=8)
    with tempfile.TemporaryDirectory() as d:
        s1 = Session.from_spec(spec, ckpt_dir=d)
        s1.run(3)
        carry = s1.state["async"]
        s1.save()
        s1.run()
        s2 = Session.restore_from(d)
        # the in-flight/buffer machinery really was mid-work at the save
        np.testing.assert_array_equal(
            np.asarray(carry["pending_mask"]) |
            np.asarray(carry["pull_round"]) >= 0, True)
        s2.run()
        assert s1.metrics.series("test_acc") == s2.metrics.series("test_acc")
        for key in s1.state:
            for a, b in zip(jax.tree.leaves(s1.state[key]),
                            jax.tree.leaves(s2.state[key])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                              err_msg=f"{store}/{key}")


def test_save_refuses_partial_async_carry():
    spec = _spec()
    s = Session.from_spec(spec)
    s.run(2)
    crippled = dict(s.state)
    crippled["async"] = {k: v for k, v in s.state["async"].items()
                         if k != "pending"}
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(ValueError, match="async carry is missing"):
            save_fed_state(f"{d}/x.npz", crippled)


# ---------------------------------------------------------------------------
# satellite: per-arrival cost accounting + staleness summary
# ---------------------------------------------------------------------------


def test_cost_report_accounts_uploads_per_arrival():
    """The ledger books one upload per REALIZED arrival — a stale update
    counts exactly once, at its delivery round; in-flight work isn't an
    upload yet."""
    sess = Session.from_spec(_spec(async_buffer=2, async_latency=2.0,
                                   async_jitter=1.0, rounds=10)).run()
    led = sess.ledger()
    decided = int(led["train_rounds"].sum() + led["est_rounds"].sum())
    summ = sess.staleness_summary()
    assert decided == summ["arrivals"], (
        "ledger rows must equal realized arrivals, not dispatches")
    dispatches = int(sess._sched.dispatch.sum())
    in_flight_or_buffered = dispatches - summ["arrivals"]
    assert in_flight_or_buffered >= 0
    rep = sess.cost_report()
    assert rep["arrivals"] == summ["arrivals"]
    assert rep["merges"] == summ["merges"]
    assert rep["upload_bytes"] >= 0


def test_staleness_summary_reports_realized_staleness():
    sess = Session.from_spec(_spec(async_buffer=2, async_latency=2.0,
                                   async_jitter=1.0, rounds=10)).run()
    summ = sess.staleness_summary()
    assert summ["arrivals"] > 0 and summ["merges"] > 0
    assert summ["max_staleness"] >= 1          # latency 2.0 ⇒ real lag
    assert 0.0 < summ["mean_staleness"] <= summ["max_staleness"]
    assert summ["mean_buffer_occupancy"] >= 2  # K=2 merges wait for 2
    assert summ["pending_now"] >= 0
    # synchronous sessions have no arrival process to summarize
    sync = Session.from_spec(_spec(executor="scan"))
    with pytest.raises(ValueError, match="async"):
        sync.staleness_summary()
