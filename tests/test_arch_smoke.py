"""Per-architecture smoke tests (deliverable f): each assigned arch's
REDUCED variant (2 layers, d_model≤256, ≤4 experts) runs one forward/train
step plus a prefill→decode round-trip on CPU; asserts output shapes and
finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, is_subquadratic
from repro.models import decoder
from repro.models.steps import (init_train_state, make_decode_step,
                                make_prefill_step, make_train_step)
from repro.optim.optimizers import sgd
from repro.optim.schedules import constant_lr
from repro.utils.pytree import tree_all_finite

B, S = 2, 32


def _batch(cfg, key, b=B, s=S):
    batch = {"tokens": jax.random.randint(
        key, ((b, cfg.n_codebooks, s) if cfg.n_codebooks else (b, s)),
        0, cfg.vocab)}
    if cfg.n_vision_tokens:
        batch["vision_embeds"] = jax.random.normal(
            key, (b, cfg.n_vision_tokens, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))
        batch["pos3"] = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32),
                                         (3, b, s))
    elif cfg.mrope_sections:
        batch["pos3"] = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32),
                                         (3, b, s))
    return batch


@pytest.fixture(scope="module", params=ARCH_NAMES)
def arch(request):
    return request.param


def test_reduced_config_is_reduced(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4


def test_train_step(arch, rng):
    cfg = get_config(arch, reduced=True)
    opt = sgd()
    state = init_train_state(rng, cfg, opt)
    step = jax.jit(make_train_step(cfg, opt, constant_lr(0.01)))
    batch = _batch(cfg, jax.random.fold_in(rng, 1))
    new_state, metrics = step(state, batch)
    assert float(metrics["loss"]) > 0.0
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert bool(tree_all_finite(new_state["params"]))
    assert int(new_state["step"]) == 1
    # params actually moved
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))),
        state["params"], new_state["params"]))
    assert max(moved) > 0.0


def test_prefill_then_decode(arch, rng):
    cfg = get_config(arch, reduced=True)
    params = decoder.model_init(rng, cfg)
    batch = _batch(cfg, jax.random.fold_in(rng, 2))
    capacity = S + 4
    prefill = jax.jit(make_prefill_step(cfg, capacity=capacity))
    caches, logits = prefill(params, batch)
    vshape = (B, 1, cfg.n_codebooks, cfg.vocab) if cfg.n_codebooks \
        else (B, 1, cfg.vocab)
    assert logits.shape == vshape
    assert bool(jnp.all(jnp.isfinite(logits)))
    serve = jax.jit(make_decode_step(cfg))
    tok = jnp.ones((B, cfg.n_codebooks, 1), jnp.int32) if cfg.n_codebooks \
        else jnp.ones((B, 1), jnp.int32)
    logits2, caches = serve(params, caches, tok, jnp.asarray(S, jnp.int32))
    assert logits2.shape == vshape
    assert bool(jnp.all(jnp.isfinite(logits2)))


def test_decode_consistency_with_full_forward(arch, rng):
    """Greedy decode logits from the cache path match re-running the
    whole prefix through prefill (teacher-forcing equivalence)."""
    cfg = get_config(arch, reduced=True)
    if cfg.sliding_window:
        cfg = cfg.replace(sliding_window=64)   # window > test seq
    if cfg.moe is not None:
        # lossless dispatch: capacity drops differ between the 12-token
        # prefill and the 1-token decode groups, so remove them
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    params = decoder.model_init(rng, cfg)
    key = jax.random.fold_in(rng, 3)
    s0, s1 = 8, 12
    full = _batch(cfg, key, b=1, s=s1)
    prefix = jax.tree.map(
        lambda x: x[..., :s0] if x.dtype == jnp.int32 and x.shape[-1] == s1
        else (x[:, :, :s0] if x.ndim == 3 and x.shape[-1] == s1 else x),
        full)
    if "pos3" in full:
        prefix["pos3"] = full["pos3"][:, :, :s0]
    caches, _ = decoder.prefill(params, cfg, prefix, capacity=s1 + 1)
    # feed tokens s0..s1-1 one by one; compare logits to full prefill
    logits_steps = []
    for t in range(s0, s1):
        tok = (full["tokens"][:, :, t][:, :, None] if cfg.n_codebooks
               else full["tokens"][:, t][:, None])
        lg, caches = decoder.decode_step(params, cfg, tok,
                                         jnp.asarray(t, jnp.int32), caches)
        logits_steps.append(lg)
    _, logits_full = decoder.prefill(params, cfg, full, capacity=s1 + 1)
    a = np.asarray(logits_steps[-1], np.float32)
    b = np.asarray(logits_full, np.float32)
    # bf16 attention probs make the chunk-scan (prefill) and single-chunk
    # (decode) paths differ in the last bit; ≥98% of logits must agree
    # tightly and none wildly (MoE top-k routing amplifies the bf16 noise
    # slightly — moonshot sits at 98.8% with max |Δ| ≈ 0.03, while a real
    # cache-path bug shows up below 10%)
    close = np.isclose(a, b, atol=2e-2, rtol=2e-2)
    assert close.mean() > 0.98, f"only {close.mean():.1%} of logits agree"
    np.testing.assert_allclose(a, b, atol=0.25, rtol=0.5)


def test_long_context_rule(arch):
    cfg = get_config(arch)
    from repro.configs import decode_window, shape_supported
    from repro.models.config import INPUT_SHAPES
    long = INPUT_SHAPES["long_500k"]
    assert shape_supported(cfg, long)
    w = decode_window(cfg, long)
    if is_subquadratic(cfg):
        assert w == 0          # native sub-quadratic path
    else:
        assert w > 0           # sliding-window carve-out


def test_moe_router_load_balance(arch, rng):
    cfg = get_config(arch, reduced=True)
    if cfg.moe is None:
        pytest.skip("dense arch")
    from repro.models import moe as moe_mod
    x = jax.random.normal(rng, (2, 16, cfg.d_model))
    p = moe_mod.moe_init(rng, cfg, jnp.float32)
    out, aux = moe_mod.moe_apply(p, cfg, x)
    assert out.shape == x.shape
    assert np.isfinite(float(aux))
    # balanced-router aux loss lower-bounded by 1 (E · Σ f·P ≥ 1)
    assert float(aux) >= 0.99


def test_fp8_kv_cache_roundtrip(rng):
    """fp8 cache storage (the HBM-fit knob for the big MHA decode caches):
    prefill→decode still produces sane, finite logits close to bf16."""
    cfg = get_config("phi3-mini-3.8b", reduced=True)
    cfg8 = cfg.replace(kv_cache_dtype="float8_e4m3fn")
    params = decoder.model_init(rng, cfg)
    batch = _batch(cfg, jax.random.fold_in(rng, 5))
    caches16, lg16 = decoder.prefill(params, cfg, batch, capacity=S + 2)
    caches8, lg8 = decoder.prefill(params, cfg8, batch, capacity=S + 2)
    k_leaf = jax.tree.leaves(caches8)[0]
    tok = jnp.ones((B, 1), jnp.int32)
    d16, _ = decoder.decode_step(params, cfg, tok,
                                 jnp.asarray(S, jnp.int32), caches16)
    d8, _ = decoder.decode_step(params, cfg8, tok,
                                jnp.asarray(S, jnp.int32), caches8)
    assert bool(jnp.all(jnp.isfinite(d8)))
    # fp8 is coarse; require agreement in the bulk, not the tail
    close = np.isclose(np.asarray(d8, np.float32),
                       np.asarray(d16, np.float32), atol=0.5, rtol=0.5)
    assert close.mean() > 0.9


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "recurrentgemma-9b"])
def test_pallas_serve_path_matches_jnp(arch, rng):
    """cfg.use_pallas routes prefill through the Pallas kernels (flash
    attention / RG-LRU scan, interpret mode on CPU); logits must match
    the jnp path."""
    cfg = get_config(arch, reduced=True)
    params = decoder.model_init(rng, cfg)
    s = 128
    batch = _batch(cfg, jax.random.fold_in(rng, 7), b=1, s=s)
    _, lg_jnp = decoder.prefill(params, cfg, batch, capacity=s + 1)
    _, lg_pl = decoder.prefill(params, cfg.replace(use_pallas=True), batch,
                               capacity=s + 1)
    np.testing.assert_allclose(np.asarray(lg_pl, np.float32),
                               np.asarray(lg_jnp, np.float32),
                               atol=3e-2, rtol=3e-2)
