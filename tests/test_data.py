"""Data pipeline: γ-partitioner, budget laws, federated stacking."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.federated import CohortSampler, build_federated
from repro.data.partition import (budget_law, partition_classes,
                                  partition_gamma, skewed_budget_assignment,
                                  two_group_budget)
from repro.data.synthetic import make_dataset, train_test_split


@pytest.fixture(scope="module")
def ds():
    return make_dataset("gaussian", n=2000, dim=16, n_classes=10, seed=0)


def test_gamma_partition_covers_everything(ds):
    parts = partition_gamma(ds, 8, gamma=0.5, seed=0)
    allidx = np.sort(np.concatenate(parts))
    assert (allidx == np.arange(len(ds))).all()


def test_gamma_zero_is_label_sorted_shards(ds):
    parts = partition_gamma(ds, 10, gamma=0.0, seed=0)
    # each client should see very few classes (~1-2 of 10)
    n_classes_seen = [len(np.unique(ds.y[p])) for p in parts]
    assert np.mean(n_classes_seen) <= 3.0


def test_gamma_one_is_iid(ds):
    parts = partition_gamma(ds, 10, gamma=1.0, seed=0)
    n_classes_seen = [len(np.unique(ds.y[p])) for p in parts]
    assert min(n_classes_seen) >= 8     # nearly all classes everywhere


@given(gamma=st.floats(0.0, 1.0), n=st.integers(2, 16))
@settings(max_examples=20, deadline=None)
def test_gamma_partition_property(gamma, n):
    ds = make_dataset("gaussian", n=400, dim=4, n_classes=4, seed=1)
    parts = partition_gamma(ds, n, gamma=gamma, seed=0)
    assert len(parts) == n
    assert sum(len(p) for p in parts) == len(ds)
    assert len(np.unique(np.concatenate(parts))) == len(ds)


def test_partition_classes_exact_ownership(ds):
    parts = partition_classes(ds, 100, classes_per_client=2, seed=0)
    for p in parts[:20]:
        if len(p):
            assert len(np.unique(ds.y[p])) <= 2


def test_budget_law_matches_paper():
    """p_i = (1/2)^⌊β·i/N⌋ with β=4, N=8 → pairs at 1, .5, .25, .125."""
    p = budget_law(8, 4)
    assert list(p) == [1.0, 1.0, 0.5, 0.5, 0.25, 0.25, 0.125, 0.125]


def test_two_group_budget():
    p = two_group_budget(10, r=0.3, w=4)
    assert (p[:7] == 1.0).all() and (p[7:] == 0.25).all()


def test_skewed_budget_modes(ds):
    for skew in ("random", "high", "moderate"):
        parts, p = skewed_budget_assignment(ds, 20, 2, beta=4, skew=skew)
        assert len(parts) == 20 and len(p) == 20
        assert set(np.round(np.log2(1 / p)).astype(int)) <= {0, 1, 2, 3}
    # 'high': clients sharing a dominant class share a budget level
    parts, p = skewed_budget_assignment(ds, 20, 2, beta=4, skew="high",
                                        seed=3)
    dom = np.array([np.bincount(ds.y[ix], minlength=10).argmax()
                    for ix in parts])
    for c in np.unique(dom):
        levels = np.unique(p[dom == c])
        assert len(levels) <= 2


def test_build_federated_padding(ds):
    parts = partition_gamma(ds, 5, gamma=0.3, seed=0)
    fd = build_federated(ds, parts)
    assert fd.n_clients == 5
    assert int(fd.sizes.sum()) == len(ds)
    # padded region cycles real samples (no zeros rows beyond size)
    import jax
    xb, yb = fd.client_batch(jax.random.PRNGKey(0), 16)
    assert xb.shape == (5, 16, 16) and yb.shape == (5, 16)


def test_train_test_split_disjoint(ds):
    tr, te = train_test_split(ds, test_frac=0.25, seed=0)
    assert len(tr) + len(te) == len(ds)
    assert abs(len(te) - 0.25 * len(ds)) < 2


# ---------------------------------------------------------------------------
# CohortSampler (sharded executor participant sampling)
# ---------------------------------------------------------------------------


def test_cohort_sampler_uniform_without_replacement():
    s = CohortSampler(50, 10, seed=0)
    counts = np.zeros(50, int)
    for t in range(200):
        idx = s.indices_for(t)
        assert len(np.unique(idx)) == 10            # no replacement
        assert (np.sort(idx) == idx).all()          # sorted for gather
        assert idx.min() >= 0 and idx.max() < 50
        counts[idx] += 1
    # every client participates and rates are roughly uniform (±50%)
    assert counts.min() > 0
    assert counts.max() < 2.0 * 200 * 10 / 50


def test_cohort_sampler_deterministic_and_round_keyed():
    a = CohortSampler(30, 6, seed=5)
    b = CohortSampler(30, 6, seed=5)
    np.testing.assert_array_equal(a.indices_for(17), b.indices_for(17))
    # different rounds and seeds draw different cohorts
    assert not np.array_equal(a.indices_for(0), a.indices_for(1)) or \
        not np.array_equal(a.indices_for(1), a.indices_for(2))
    c = CohortSampler(30, 6, seed=6)
    assert any(not np.array_equal(a.indices_for(t), c.indices_for(t))
               for t in range(5))


def test_cohort_sampler_table_matches_per_round():
    s = CohortSampler(20, 4, seed=1)
    tab = s.indices(8, start=2)
    assert tab.shape == (8, 4) and tab.dtype == np.int32
    for t in range(8):
        np.testing.assert_array_equal(tab[t], s.indices_for(2 + t))


def test_cohort_sampler_validates():
    with pytest.raises(ValueError):
        CohortSampler(10, 0)
    with pytest.raises(ValueError):
        CohortSampler(10, 11)
