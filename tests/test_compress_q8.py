"""int8 Δ-history carry (``compress="int8"``) — config validation, the
dropped/kept ``prev_local`` rule, measured wire bytes, and bit-identical
checkpoint resume of the quantized state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentSpec, Session
from repro.core import strategies as strat_mod
from repro.core.compress import BYTES_PER_PARAM_F32
from repro.core.rounds import FedConfig, init_fed_state
from repro.core.schedules import make_plan
from repro.core.strategies import Strategy, get_strategy
from repro.data.federated import build_federated
from repro.data.partition import partition_gamma
from repro.data.synthetic import make_dataset, train_test_split
from repro.models.simple import make_classifier

N = 4


def _spec(strategy="cc", **kw) -> ExperimentSpec:
    base = dict(dataset="gaussian", n_samples=256, dim=8, n_classes=4,
                n_clients=N, model="mlp", width=4, strategy=strategy,
                local_steps=2, batch_size=16, lr=0.1, schedule="adhoc",
                budget="power", beta=2, rounds=6, eval_every=2, seed=0,
                executor="scan", use_fused=True, compress="int8")
    base.update(kw)
    return ExperimentSpec(**base)


@pytest.fixture(scope="module")
def setup():
    ds = make_dataset("gaussian", n=256, dim=8, n_classes=4, seed=0)
    tr, _ = train_test_split(ds)
    fd = build_federated(tr, partition_gamma(tr, N, gamma=0.5, seed=0))
    model = make_classifier("mlp", input_shape=(8,), n_classes=4, width=4)
    return model, fd


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------


def test_fedconfig_rejects_unknown_compress():
    with pytest.raises(ValueError, match="compress"):
        FedConfig(strategy="cc", compress="fp8")


def test_fedconfig_rejects_int8_for_non_fused_capable_strategy():
    """A strategy without a ``FusedEpilogue`` has no int8 kernel path —
    the config must refuse up front, not fail inside a traced round."""
    register_name = "_tmp_treeops_only"
    strat_mod.register(Strategy(name=register_name))
    try:
        assert not get_strategy(register_name).fused_capable
        with pytest.raises(ValueError, match="fused"):
            FedConfig(strategy=register_name, compress="int8")
    finally:
        del strat_mod._REGISTRY[register_name]


def test_spec_rejects_bad_compress():
    with pytest.raises(ValueError, match="compress"):
        _spec(compress="fp8")
    with pytest.raises(ValueError, match="use_fused"):
        _spec(use_fused=False)


def test_session_rejects_int8_without_fused(setup):
    model, fd = setup
    with pytest.raises(ValueError, match="use_fused"):
        Session(model, fd,
                FedConfig(strategy="cc", local_steps=2, compress="int8"),
                make_plan("full", np.ones(N), 2), executor="scan",
                use_fused=False)


def test_init_fed_state_rejects_unknown_compress(setup):
    model, _ = setup
    with pytest.raises(ValueError, match="compress"):
        init_fed_state(jax.random.PRNGKey(0), model, N, compress="fp8")


# ---------------------------------------------------------------------------
# carry shape: quantized history, prev_local dropped only for replay
# ---------------------------------------------------------------------------


def test_quantized_carry_drops_prev_local_for_replay_strategies():
    sess = Session.from_spec(_spec("cc"))
    q = sess.state["deltas"]
    assert set(q) == {"payload", "scales"}
    assert q["payload"].dtype == jnp.int8
    assert q["payload"].shape[0] == N and q["scales"].shape == (N,)
    assert q["payload"].shape[1] % 512 == 0          # tile-padded flat P
    assert "prev_local" not in sess.state


@pytest.mark.parametrize("strategy", ["s2", "ccc"])
def test_quantized_carry_keeps_prev_local_for_stale_strategies(strategy):
    """s2/ccc estimate from the stale model — the f32 ``prev_local`` tree
    must stay in the carry even with the int8 Δ history."""
    assert get_strategy(strategy).needs_stale
    sess = Session.from_spec(_spec(strategy))
    assert set(sess.state["deltas"]) == {"payload", "scales"}
    assert "prev_local" in sess.state


# ---------------------------------------------------------------------------
# cost report: measured int8 bytes vs f32 accounting
# ---------------------------------------------------------------------------


def test_cost_report_measures_int8_wire_bytes():
    sess = Session.from_spec(_spec("cc")).run()
    rep = sess.cost_report()
    assert rep["upload_bytes_int8_measured"] is True
    # one quantized upload = int8 payload row + one f32 scale: strictly
    # between 1/4 of f32 (scales add) and, say, 30% of it (tile padding)
    assert 0 < rep["upload_bytes_int8"] < rep["upload_bytes"]
    assert rep["upload_bytes_int8"] >= rep["upload_bytes"] // 4 // 2


def test_cost_report_accounted_without_compression():
    sess = Session.from_spec(_spec("cc", compress="none")).run()
    rep = sess.cost_report()
    assert rep["upload_bytes_int8_measured"] is False
    assert rep["upload_bytes_int8"] == (rep["upload_bytes"]
                                        // BYTES_PER_PARAM_F32)


# ---------------------------------------------------------------------------
# checkpoint: quantized state resumes bit-identically
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", ["cc", "ccc"])
def test_save_restore_resumes_bit_identical(tmp_path, strategy):
    spec = _spec(strategy)
    sess = Session.from_spec(spec, ckpt_dir=str(tmp_path))
    sess.run(3)
    sess.save()
    sess.run()
    final = sess.state

    sess2 = Session.restore_from(str(tmp_path))
    assert sess2.t == 3
    sess2.run()
    resumed = sess2.state
    assert set(final) == set(resumed)

    def _flat(state):
        return {".".join(str(p) for p in path):
                np.asarray(jax.random.key_data(leaf)
                           if jnp.issubdtype(leaf.dtype, jax.dtypes.prng_key)
                           else leaf)
                for path, leaf
                in jax.tree_util.tree_flatten_with_path(state)[0]}

    fa, fb = _flat(final), _flat(resumed)
    assert set(fa) == set(fb)
    for k in fa:
        np.testing.assert_array_equal(fa[k], fb[k], err_msg=k)


def test_q8_run_tracks_f32_run():
    """End-to-end sanity on top of the matrix pins: the quantized run's
    final params stay within the ISSUE's 1e-2 of the exact fused run."""
    q8 = Session.from_spec(_spec("cc")).run()
    f32 = Session.from_spec(_spec("cc", compress="none")).run()
    for a, b in zip(jax.tree.leaves(q8.state["params"]),
                    jax.tree.leaves(f32.state["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-2)
