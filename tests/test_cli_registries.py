"""CLI choices must be derived from the owning registries (ISSUE 10
satellite): a newly registered strategy/executor/kind must be reachable
from ``python -m repro`` without touching the CLI — duplicated literals
silently drift.
"""
import pytest

from repro.api import spec as spec_mod
from repro.api.cli import build_parser
from repro.core.budget import POLICY_KINDS
from repro.core.channel import CHANNEL_KINDS
from repro.core.history_store import STORE_KINDS
from repro.core.rounds import COMPRESS_KINDS, EXECUTORS
from repro.core.strategies import available_strategies
from repro.system.devices import PROFILE_KINDS


def _flag_choices(sub: str):
    ap = build_parser()
    sub_actions = next(a for a in ap._actions
                       if hasattr(a, "choices") and sub in (a.choices or {}))
    parser = sub_actions.choices[sub]
    return {a.option_strings[0]: a.choices for a in parser._actions
            if a.option_strings and a.choices is not None}


_REGISTRY_FLAGS = {
    "--strategy": tuple(available_strategies()),
    "--executor": tuple(EXECUTORS),
    "--channel": tuple(CHANNEL_KINDS),
    "--policy": tuple(POLICY_KINDS),
    "--device-profile": tuple(PROFILE_KINDS),
    "--compress": tuple(COMPRESS_KINDS),
    "--history-store": tuple(STORE_KINDS),
}


@pytest.mark.parametrize("sub", ("run", "sweep"))
@pytest.mark.parametrize("flag", sorted(_REGISTRY_FLAGS))
def test_cli_choices_match_registry(sub, flag):
    choices = _flag_choices(sub)
    assert flag in choices, f"{sub} is missing {flag}"
    assert tuple(choices[flag]) == _REGISTRY_FLAGS[flag]


def test_every_registry_strategy_is_spec_reachable():
    """FedConfig accepts every registered strategy name — the CLI's
    --strategy choices and the engine agree on the registry."""
    from repro.core.rounds import FedConfig
    for name in available_strategies():
        kw = {"fedprox": {"prox_mu": 0.1},
              "feddyn": {"feddyn_alpha": 0.1}}.get(name, {})
        FedConfig(strategy=name, **kw)


def test_spec_choice_tables_are_the_registries():
    """The spec's private choice tables alias the registries rather than
    restating them."""
    assert spec_mod._EXECUTORS is EXECUTORS
    assert spec_mod._COMPRESS is COMPRESS_KINDS
    assert spec_mod._DEVICE_PROFILES is PROFILE_KINDS


def test_executor_flag_overrides_spec(tmp_path):
    from repro.api.cli import _load_spec
    from repro.api.spec import ExperimentSpec
    path = str(tmp_path / "s.json")
    ExperimentSpec(rounds=2, eval_every=1).save(path)
    spec = _load_spec(path, [], executor="python")
    assert spec.executor == "python"
