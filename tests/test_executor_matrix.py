"""Cross-executor differential matrix.

Eight numerically-interchangeable executor cells now run the same round
semantics — {python, scan, fused, sharded} plus the two collapse
configurations of the hierarchical two-tier executor (single edge /
per-round sync) plus the async executor at its collapse point (zero
latency, merge every arrival) — so equivalence is pinned systematically:
every executor × every registered strategy × every algorithm variant
must reproduce the python-loop oracle's final params and metric stream
to ≤1e-5. The oracle
runs once per strategy and is shared across cells (the variant axis
provably never enters round numerics — it drives the Appendix-A cost
accounting, which every cell smoke-checks instead).

The sharded executor is additionally pinned on its own semantics: a
sampled cohort round equals a full round whose masks are zeroed outside
the cohort (clients keep their global training keys), and cohort/mesh
validation errors fire eagerly.

The hierarchical executor carries two pins of its own: its collapse
configurations (one edge, or ``edge_period=1``) reproduce the flat scan
executor BIT-FOR-BIT (``assert_array_equal``, not allclose) for
cc/fedavg/fednova, and a multi-edge multi-period run is bit-identical on
a 1-shard and a multi-shard edge mesh — intra-edge aggregation reads each
edge's own block only, and sync rounds all-gather before reducing.

The async executor's acceptance pin mirrors it: zero latency/jitter with
``buffer_size=1`` makes every update deliver in its dispatch round with
staleness 0 and ``w(0) = 1.0`` exactly, so the async run reproduces the
scan executor BIT-FOR-BIT — params, full history and metric stream — for
cc/fedavg/fednova. Its PrecompiledPolicy pin runs a NON-collapse config
(buffered merges, real latency) so the decide-at-dispatch path is
exercised where staleness is nonzero.

This file must pass both on the default 1-device CPU and under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the CI
executor-matrix and hierarchy-matrix jobs), where ``shard_map`` really
splits the client/edge axes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentSpec, Session
from repro.core.async_rounds import AsyncConfig, make_async_span_runner
from repro.core.budget import EnergyAware, PrecompiledPolicy
from repro.core.hierarchy import EdgeTopology
from repro.core.rounds import (FedConfig, init_fed_state,
                               make_hierarchical_span_runner,
                               make_policy_round_fn,
                               make_policy_span_runner, make_round_fn,
                               make_sharded_span_runner, make_span_runner)
from repro.core.schedules import make_plan
from repro.system.devices import make_profile, simulate_arrivals
from repro.core.strategies import available_strategies, get_strategy
from repro.data.federated import CohortSampler, build_federated
from repro.data.partition import budget_law, partition_gamma
from repro.data.synthetic import make_dataset, train_test_split
from repro.launch.mesh import (best_client_shards, best_edge_shards,
                               make_client_mesh, make_edge_mesh)
from repro.models.simple import make_classifier

N = 4
EXECUTORS = ("python", "scan", "fused", "fused_q8", "sharded",
             "hier_single_edge", "hier_sync_every_round", "async")
VARIANTS = ("client", "server", "mixed")
ATOL = 1e-5
#: the quantized fused cells carry int8 Δ history — vs the exact f32
#: oracle the params budget is the ISSUE's 1e-2; the metric stream gets
#: 2.5e-2 because the 51-sample test set quantizes accuracy in steps of
#: 1/51 ≈ 0.0196 (a single flipped prediction would breach 1e-2)
Q8_ATOL_PARAMS = 1e-2
Q8_ATOL_ACCS = 2.5e-2

#: the hierarchical collapse configurations: a single edge running 3-round
#: periods, and N single-client edges syncing every round
HIER_CELLS = {"hier_single_edge": dict(n_edges=1, edge_period=3),
              "hier_sync_every_round": dict(n_edges=N, edge_period=1)}


def _spec(strategy: str, executor: str) -> ExperimentSpec:
    use_fused = executor in ("fused", "fused_q8")
    compress = "int8" if executor == "fused_q8" else "none"
    extra = {}
    # the extension strategies run the matrix with their regularizers ON
    # (at 0 they are literally fedavg and the cells prove nothing)
    if strategy == "fedprox":
        extra = dict(prox_mu=0.1)
    elif strategy == "feddyn":
        extra = dict(feddyn_alpha=0.1)
    if executor in HIER_CELLS:
        extra = dict(topology="contiguous", **HIER_CELLS[executor], **extra)
        executor = "hierarchical"
    return ExperimentSpec(
        dataset="gaussian", n_samples=256, dim=8, n_classes=4,
        n_clients=N, budget="power", beta=2, model="mlp", width=4,
        strategy=strategy, local_steps=2, batch_size=16, lr=0.1,
        schedule="adhoc", rounds=6, eval_every=2, seed=0,
        executor="scan" if use_fused else executor, use_fused=use_fused,
        compress=compress, **extra)


_RUNS: dict = {}


def _run(strategy: str, executor: str):
    """Final params + metric stream for one cell (memoized: the variant
    axis never enters round numerics, so cells share runs)."""
    key = (strategy, executor)
    if key not in _RUNS:
        sess = Session.from_spec(_spec(strategy, executor)).run()
        _RUNS[key] = (jax.tree.map(np.asarray, sess.state["params"]),
                      sess.metrics.series("test_acc"), sess)
    return _RUNS[key]


@pytest.mark.parametrize("variant", VARIANTS)
@pytest.mark.parametrize("strategy", available_strategies())
@pytest.mark.parametrize("executor", EXECUTORS)
def test_matrix_matches_python_oracle(executor, strategy, variant):
    if (executor in ("fused", "fused_q8")
            and not get_strategy(strategy).fused_capable):
        pytest.skip(f"{strategy} is not fused-capable")
    q8 = executor == "fused_q8"
    atol_params = Q8_ATOL_PARAMS if q8 else ATOL
    atol_accs = Q8_ATOL_ACCS if q8 else ATOL
    oracle_params, oracle_accs, _ = _run(strategy, "python")
    params, accs, sess = _run(strategy, executor)
    np.testing.assert_allclose(accs, oracle_accs, atol=atol_accs,
                               err_msg=f"{executor}/{strategy} metric "
                                       "stream diverged")
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(oracle_params)):
        np.testing.assert_allclose(a, b, atol=atol_params,
                                   err_msg=f"{executor}/{strategy} params")
    # the variant axis: identical numerics, distinct cost accounting
    rep = sess.cost_report(variant=variant)
    assert rep["upload_bytes"] >= 0


def test_matrix_covers_every_registered_strategy():
    """The matrix parametrizes over the live registry — a new strategy is
    covered the moment it registers."""
    assert set(available_strategies()) >= {
        "fedavg", "dropout", "s1", "s2", "cc", "ccc", "fednova", "cc_decay"}


def test_fused_columns_skip_at_most_four_cells():
    """The fused-coverage satellite pin: with every registered strategy
    carrying a ``FusedEpilogue``, the matrix's two fused columns may skip
    at most 4 cells total (they skipped 21 when only cc was capable)."""
    non_capable = [s for s in available_strategies()
                   if not get_strategy(s).fused_capable]
    skipped_cells = len(non_capable) * len(VARIANTS) * 2   # fused + fused_q8
    assert skipped_cells <= 4, (
        f"{non_capable} lack fused epilogues → {skipped_cells} skipped cells")


# ---------------------------------------------------------------------------
# budget-policy engine: PrecompiledPolicy ≡ legacy masks, bit-for-bit
# ---------------------------------------------------------------------------

SCHEDULE_KINDS = ("round_robin", "adhoc", "sync", "dropout", "full")


@pytest.fixture(scope="module")
def policy_setup():
    ds = make_dataset("gaussian", n=256, dim=8, n_classes=4, seed=0)
    tr, _ = train_test_split(ds)
    parts = partition_gamma(tr, N, gamma=0.5, seed=0)
    fd = build_federated(tr, parts)
    model = make_classifier("mlp", input_shape=(8,), n_classes=4, width=4)
    return model, fd


@pytest.mark.parametrize("kind", SCHEDULE_KINDS)
@pytest.mark.parametrize("executor", EXECUTORS)
def test_precompiled_policy_bit_for_bit(policy_setup, kind, executor):
    """The acceptance pin of the budget-policy engine: replaying a legacy
    plan through ``PrecompiledPolicy`` reproduces the mask-mode executor
    EXACTLY (``assert_array_equal``, not allclose) for every schedule kind
    under every executor — the static-plan era is a strict special case."""
    model, fd = policy_setup
    compress = "int8" if executor == "fused_q8" else "none"
    fed = FedConfig(strategy="cc", local_steps=2, batch_size=16, lr=0.1,
                    compress=compress)
    p = budget_law(N, beta=2)
    rounds = 6
    plan = make_plan(kind, p, rounds, seed=2)
    k = jnp.full((N,), fed.local_steps, jnp.int32)
    sel, train = jnp.asarray(plan.selection), jnp.asarray(plan.training)
    policy = PrecompiledPolicy.from_plan(plan)
    profile = make_profile("budget", p, seed=0)

    def fresh(**kw):
        if compress == "int8":       # cc's replay estimate never reads
            kw.update(compress=compress,   # the stale model
                      needs_stale=fed.resolve().needs_stale)
        return init_fed_state(jax.random.PRNGKey(0), model, N, **kw)

    if executor == "python":
        rf = make_round_fn(model, fd, fed)
        s_mask = fresh()
        for t in range(rounds):
            s_mask = rf(s_mask, sel[t], train[t], k)
        prf = make_policy_round_fn(model, fd, fed, policy, profile)
        s_pol = fresh(policy=policy, profile=profile)
        for t in range(rounds):
            s_pol = prf(s_pol, sel[t], k)
    elif executor in ("scan", "fused", "fused_q8"):
        fused = executor in ("fused", "fused_q8")
        s_mask = make_span_runner(model, fd, fed, fused=fused)(
            fresh(), sel, train, k)
        s_pol = make_policy_span_runner(model, fd, fed, policy, profile,
                                        fused=fused)(
            fresh(policy=policy, profile=profile), sel, k)
    elif executor in HIER_CELLS:
        cell = HIER_CELLS[executor]
        topo = EdgeTopology.contiguous(N, cell["n_edges"],
                                       cell["edge_period"])
        s_mask = make_hierarchical_span_runner(model, fd, fed, topo)(
            fresh(topology=topo), sel, train, k)
        s_pol = make_hierarchical_span_runner(
            model, fd, fed, topo, policy=policy, profile=profile)(
            fresh(policy=policy, profile=profile, topology=topo), sel, k)
    elif executor == "async":
        # a NON-collapse config: buffered merges + device-dependent
        # latency, so the pin covers nonzero staleness, not just the
        # degenerate sync-equivalent point
        cfg = AsyncConfig(buffer_size=2, latency=1.0, jitter=0.5)
        sched = tuple(jnp.asarray(x) for x in simulate_arrivals(
            profile, np.asarray(plan.selection),
            buffer_size=cfg.buffer_size, latency=cfg.latency,
            jitter=cfg.jitter))
        s_mask = make_async_span_runner(model, fd, fed, cfg)(
            fresh(async_cfg=cfg), train, k, sched)
        s_pol = make_async_span_runner(
            model, fd, fed, cfg, policy=policy, profile=profile)(
            fresh(policy=policy, profile=profile, async_cfg=cfg), k, sched)
    else:                                        # sharded
        idx = jnp.asarray(CohortSampler(N, 2, seed=3).indices(rounds))
        s_mask = make_sharded_span_runner(model, fd, fed, cohort_size=2)(
            fresh(), sel, train, k, idx)
        s_pol = make_sharded_span_runner(
            model, fd, fed, cohort_size=2, policy=policy,
            profile=profile)(fresh(policy=policy, profile=profile),
                             sel, k, idx)

    # the q8 replay carry drops prev_local — compare the keys present
    # (the async cell also pins its buffer/staleness carry)
    for key in ("params", "deltas", "prev_local", "trained_ever", "async"):
        if key not in s_mask:
            assert key not in s_pol, f"{key} only in policy-mode state"
            continue
        for a, b in zip(jax.tree.leaves(s_mask[key]),
                        jax.tree.leaves(s_pol[key])):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"{kind}/{executor}/{key} not bit-identical")


@pytest.mark.parametrize("policy_name", ["energy", "adaptive"])
def test_sharded_stateful_policy_equals_masked_full_round(policy_setup,
                                                          policy_name):
    """A sampled-cohort *policy* round must equal the full-federation
    policy round whose selection mask is zeroed outside the cohort —
    including the carried device state, policy rows and ledger: off-cohort
    devices keep harvesting and their load keeps evolving (like unselected
    clients of a full round), they just never train or enter the books."""
    from repro.core.budget import make_policy
    model, fd = policy_setup
    fed = FedConfig(strategy="cc", local_steps=2, batch_size=16, lr=0.1)
    p = budget_law(N, beta=2)
    profile = make_profile("budget", p, load_jitter=0.2, load_mean=0.3,
                           init_energy=1.0, seed=1)
    policy = make_policy(policy_name)
    rounds = 6
    k = jnp.full((N,), fed.local_steps, jnp.int32)
    sel = jnp.ones((rounds, N), bool)
    idx_tab = CohortSampler(N, 2, seed=3).indices(rounds)

    run = make_sharded_span_runner(model, fd, fed, cohort_size=2,
                                   policy=policy, profile=profile)
    s_cohort = run(init_fed_state(jax.random.PRNGKey(0), model, N,
                                  policy=policy, profile=profile),
                   sel, k, jnp.asarray(idx_tab))

    member = np.zeros((rounds, N), bool)
    for t in range(rounds):
        member[t, idx_tab[t]] = True
    ref_run = make_policy_span_runner(model, fd, fed, policy, profile)
    s_ref = ref_run(init_fed_state(jax.random.PRNGKey(0), model, N,
                                   policy=policy, profile=profile),
                    jnp.asarray(member), k)

    for key in ("params", "deltas", "prev_local", "trained_ever",
                "policy", "device", "ledger"):
        for a, b in zip(jax.tree.leaves(s_cohort[key]),
                        jax.tree.leaves(s_ref[key])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=key)
    led = jax.device_get(s_cohort["ledger"])
    # off-cohort rounds never enter the books
    assert (led["train_rounds"] + led["est_rounds"]
            == member.sum(axis=0)).all()


def test_sharded_rejects_half_policy_mode(policy_setup):
    model, fd = policy_setup
    with pytest.raises(ValueError, match="policy"):
        make_sharded_span_runner(model, fd, FedConfig(strategy="cc"),
                                 policy=EnergyAware())


# ---------------------------------------------------------------------------
# sharded-executor cohort semantics
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def setup():
    ds = make_dataset("gaussian", n=256, dim=8, n_classes=4, seed=0)
    tr, _ = train_test_split(ds)
    parts = partition_gamma(tr, N, gamma=0.5, seed=0)
    fd = build_federated(tr, parts)
    model = make_classifier("mlp", input_shape=(8,), n_classes=4, width=4)
    return model, fd


@pytest.mark.parametrize("strategy", ["cc", "s2", "fednova"])
def test_cohort_round_equals_masked_full_round(setup, strategy):
    """A sampled M-cohort round must equal the full-federation round whose
    sel/train masks are False outside the cohort: client keys are derived
    globally, history scatter leaves non-members untouched, and the
    aggregation denominator only counts members either way."""
    model, fd = setup
    fed = FedConfig(strategy=strategy, local_steps=2, batch_size=16, lr=0.1)
    k = jnp.full((N,), fed.local_steps, jnp.int32)
    plan = make_plan("adhoc", budget_law(N, beta=2), 6, seed=1)
    sel, train = jnp.asarray(plan.selection), jnp.asarray(plan.training)

    sharded = make_sharded_span_runner(model, fd, fed, cohort_size=2)
    sampler = CohortSampler(N, 2, seed=3)
    idx_tab = sampler.indices(plan.rounds)
    s_cohort = sharded(init_fed_state(jax.random.PRNGKey(0), model, N),
                       sel, train, k, jnp.asarray(idx_tab))

    rf = make_round_fn(model, fd, fed)
    s_ref = init_fed_state(jax.random.PRNGKey(0), model, N)
    for t in range(plan.rounds):
        member = np.zeros(N, bool)
        member[idx_tab[t]] = True
        s_ref = rf(s_ref, jnp.asarray(plan.selection[t] & member),
                   jnp.asarray(plan.training[t] & member), k)

    for key in ("params", "deltas", "prev_local"):
        for a, b in zip(jax.tree.leaves(s_cohort[key]),
                        jax.tree.leaves(s_ref[key])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=ATOL, err_msg=key)
    np.testing.assert_array_equal(np.asarray(s_cohort["trained_ever"]),
                                  np.asarray(s_ref["trained_ever"]))


def test_cohort_sampler_is_absolute_round_keyed():
    s = CohortSampler(100, 10, seed=7)
    np.testing.assert_array_equal(s.indices(5, start=3)[0], s.indices_for(3))
    # full participation degenerates to arange
    full = CohortSampler(8, 8, seed=7)
    np.testing.assert_array_equal(full.indices_for(42), np.arange(8))


def test_sharded_rejects_bad_cohorts(setup):
    model, fd = setup
    fed = FedConfig(strategy="cc", local_steps=2)
    with pytest.raises(ValueError, match="cohort_size"):
        make_sharded_span_runner(model, fd, fed, cohort_size=N + 1)
    with pytest.raises(ValueError, match="cohort_size"):
        make_sharded_span_runner(model, fd, fed, cohort_size=0)
    with pytest.raises(ValueError, match="clients"):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        make_sharded_span_runner(model, fd, fed, mesh=mesh)


def test_best_client_shards_divides():
    n_dev = len(jax.devices())
    for m in (1, 2, 3, 4, 6, 8, 64):
        d = best_client_shards(m)
        assert m % d == 0 and 1 <= d <= n_dev
    assert best_client_shards(6, max_shards=4) == 3


def test_client_mesh_axis():
    mesh = make_client_mesh()
    assert mesh.axis_names == ("clients",)
    assert mesh.devices.size == len(jax.devices())
    with pytest.raises(ValueError):
        make_client_mesh(len(jax.devices()) + 1)


def test_sharded_session_rejects_fused(setup):
    model, fd = setup
    with pytest.raises(ValueError, match="use_fused"):
        Session(model, fd, FedConfig(strategy="cc"),
                make_plan("full", np.ones(N), 2), executor="sharded",
                use_fused=True)


# ---------------------------------------------------------------------------
# hierarchical two-tier executor: flat collapse + shard-count invariance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("collapse", sorted(HIER_CELLS))
@pytest.mark.parametrize("strategy", ["cc", "fedavg", "fednova"])
def test_hierarchy_collapse_is_bit_for_bit_flat(strategy, collapse):
    """The acceptance pin of the two-tier executor: a single-edge topology
    (the edge IS the server) and an ``edge_period=1`` topology (every
    round syncs, edge displacement exactly zero) reproduce the flat scan
    executor EXACTLY — params, full history and metric stream — on any
    device count."""
    flat_params, flat_accs, flat_sess = _run(strategy, "scan")
    hier_params, hier_accs, hier_sess = _run(strategy, collapse)
    assert hier_accs == flat_accs
    for a, b in zip(jax.tree.leaves(hier_params),
                    jax.tree.leaves(flat_params)):
        np.testing.assert_array_equal(a, b,
                                      err_msg=f"{collapse}/{strategy}")
    for key in ("deltas", "prev_local", "trained_ever"):
        for a, b in zip(jax.tree.leaves(hier_sess.state[key]),
                        jax.tree.leaves(flat_sess.state[key])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{collapse}/{key}")


@pytest.mark.parametrize("strategy", ["cc", "fedavg", "fednova"])
def test_async_collapse_is_bit_for_bit_scan(strategy):
    """The acceptance pin of the async executor: zero latency/jitter with
    ``buffer_size=1`` (the spec defaults) delivers every update in its
    dispatch round with staleness exactly 0, so the buffered-async run
    reproduces the synchronous scan executor EXACTLY — params, full Δ
    history, stale-model cache, trained_ever and metric stream."""
    flat_params, flat_accs, flat_sess = _run(strategy, "scan")
    async_params, async_accs, async_sess = _run(strategy, "async")
    assert async_accs == flat_accs
    for a, b in zip(jax.tree.leaves(async_params),
                    jax.tree.leaves(flat_params)):
        np.testing.assert_array_equal(a, b, err_msg=f"async/{strategy}")
    for key in ("deltas", "prev_local", "trained_ever"):
        for a, b in zip(jax.tree.leaves(async_sess.state[key]),
                        jax.tree.leaves(flat_sess.state[key])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"async/{key}")
    summ = async_sess.staleness_summary()
    assert summ["max_staleness"] == 0 and summ["mean_staleness"] == 0.0


def test_async_session_rejects_fused():
    ds = make_dataset("gaussian", n=64, dim=8, n_classes=4, seed=0)
    tr, _ = train_test_split(ds)
    fd = build_federated(tr, partition_gamma(tr, N, gamma=0.5, seed=0))
    model = make_classifier("mlp", input_shape=(8,), n_classes=4, width=4)
    with pytest.raises(ValueError, match="use_fused"):
        Session(model, fd, FedConfig(strategy="cc"),
                make_plan("full", np.ones(N), 2), executor="async",
                use_fused=True)


@pytest.mark.parametrize("strategy", ["cc", "s2", "fednova"])
def test_hierarchy_bit_identical_across_shard_counts(setup, strategy):
    """E=4 edges, multi-round periods: the span must be bit-identical on a
    1-shard and a multi-shard ``("edges",)`` mesh — intra-edge aggregation
    reads exactly its own edge's block, and sync rounds all-gather the
    uploads so every shard computes the identical merge. On a 1-device
    host both meshes degenerate to one shard and the test is a tautology;
    the CI hierarchy-matrix job runs it under 4 virtual devices."""
    model, _ = setup
    n = 8                      # 4 edges × 2 clients, shardable 1/2/4 ways
    ds = make_dataset("gaussian", n=256, dim=8, n_classes=4, seed=0)
    tr, _ = train_test_split(ds)
    fd = build_federated(tr, partition_gamma(tr, n, gamma=0.5, seed=0))
    fed = FedConfig(strategy=strategy, local_steps=2, batch_size=16,
                    lr=0.1)
    plan = make_plan("adhoc", budget_law(n, beta=2), 6, seed=1)
    k = jnp.full((n,), fed.local_steps, jnp.int32)
    sel, train = jnp.asarray(plan.selection), jnp.asarray(plan.training)
    topo = EdgeTopology.contiguous(n, 4, edge_period=3)

    states = []
    for shards in (1, best_edge_shards(topo.n_edges)):
        run = make_hierarchical_span_runner(model, fd, fed, topo,
                                            mesh=make_edge_mesh(shards))
        states.append(run(init_fed_state(jax.random.PRNGKey(0), model, n,
                                         topology=topo), sel, train, k))
    a_state, b_state = states
    for key in ("params", "edge_params", "deltas", "prev_local",
                "trained_ever"):
        for a, b in zip(jax.tree.leaves(a_state[key]),
                        jax.tree.leaves(b_state[key])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=key)


def test_hierarchy_policy_mode_equals_mask_mode_multi_period(setup):
    """Beyond the matrix's collapse cells: a PrecompiledPolicy hierarchical
    run over a multi-edge multi-period topology must equal the mask-mode
    hierarchical run bit-for-bit (same pin the flat executors carry)."""
    model, fd = setup
    fed = FedConfig(strategy="cc", local_steps=2, batch_size=16, lr=0.1)
    p = budget_law(N, beta=2)
    plan = make_plan("adhoc", p, 6, seed=2)
    topo = EdgeTopology.contiguous(N, 2, edge_period=3)
    k = jnp.full((N,), fed.local_steps, jnp.int32)
    sel, train = jnp.asarray(plan.selection), jnp.asarray(plan.training)
    policy = PrecompiledPolicy.from_plan(plan)
    profile = make_profile("budget", p, seed=0)

    s_mask = make_hierarchical_span_runner(model, fd, fed, topo)(
        init_fed_state(jax.random.PRNGKey(0), model, N, topology=topo),
        sel, train, k)
    s_pol = make_hierarchical_span_runner(
        model, fd, fed, topo, policy=policy, profile=profile)(
        init_fed_state(jax.random.PRNGKey(0), model, N, policy=policy,
                       profile=profile, topology=topo), sel, k)
    for key in ("params", "edge_params", "deltas", "prev_local",
                "trained_ever"):
        for a, b in zip(jax.tree.leaves(s_mask[key]),
                        jax.tree.leaves(s_pol[key])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=key)


def test_hierarchical_rejects_bad_meshes(setup):
    model, fd = setup
    fed = FedConfig(strategy="cc", local_steps=2)
    topo = EdgeTopology.contiguous(N, 2, edge_period=2)
    with pytest.raises(ValueError, match="edges"):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        make_hierarchical_span_runner(model, fd, fed, topo, mesh=mesh)
    with pytest.raises(ValueError, match="policy"):
        make_hierarchical_span_runner(model, fd, fed, topo,
                                      policy=EnergyAware())
    if len(jax.devices()) >= 3:
        with pytest.raises(ValueError, match="divide"):
            make_hierarchical_span_runner(model, fd, fed, topo,
                                          mesh=make_edge_mesh(3))
    if len(jax.devices()) >= 2:
        striped = EdgeTopology.striped(N, 2, edge_period=2)
        with pytest.raises(ValueError, match="contiguous-uniform"):
            make_hierarchical_span_runner(model, fd, fed, striped,
                                          mesh=make_edge_mesh(2))


def test_best_edge_shards_divides():
    n_dev = len(jax.devices())
    for e in (1, 2, 3, 4, 6, 8):
        d = best_edge_shards(e)
        assert e % d == 0 and 1 <= d <= n_dev
    assert best_edge_shards(6, max_shards=4) == 3


def test_edge_mesh_axis():
    mesh = make_edge_mesh()
    assert mesh.axis_names == ("edges",)
    assert mesh.devices.size == len(jax.devices())
    with pytest.raises(ValueError):
        make_edge_mesh(len(jax.devices()) + 1)


# ---------------------------------------------------------------------------
# uplink channel: noiseless ≡ exact pins + aircomp cross-executor
# equivalence
# ---------------------------------------------------------------------------

#: aircomp applies fading to the stacked uploads and AWGN to the
#: aggregated delta from draws keyed only on (seed, tag, round, ids) —
#: the flat executors therefore see IDENTICAL channel realizations
AIRCOMP = dict(channel="aircomp", channel_snr_db=20.0, channel_fading=True)
_AIRCOMP_RUNS: dict = {}


def _run_aircomp(executor: str):
    key = executor
    if key not in _AIRCOMP_RUNS:
        sess = Session.from_spec(
            _spec("cc", executor).replace(**AIRCOMP)).run()
        _AIRCOMP_RUNS[key] = (
            jax.tree.map(np.asarray, sess.state["params"]),
            sess.metrics.series("test_acc"))
    return _AIRCOMP_RUNS[key]


@pytest.mark.parametrize("executor", ["scan", "sharded", "async",
                                      "hier_single_edge"])
def test_noiseless_channel_is_bit_for_bit_exact(executor):
    """An explicit ``channel='noiseless'`` cell is bit-identical to the
    matrix cell: ``uplink_channel()`` returns None and the executors skip
    the channel path entirely, so the noisy-uplink extension cannot
    perturb exact aggregation even by one ulp."""
    base_params, base_accs, _ = _run("cc", executor)
    sess = Session.from_spec(
        _spec("cc", executor).replace(channel="noiseless")).run()
    assert sess.metrics.series("test_acc") == base_accs
    for a, b in zip(jax.tree.leaves(sess.state["params"]),
                    jax.tree.leaves(base_params)):
        np.testing.assert_array_equal(np.asarray(a), b, err_msg=executor)


@pytest.mark.parametrize("executor", ["scan", "sharded"])
def test_aircomp_matches_python_oracle(executor):
    """Fading gains are drawn for the full federation and indexed by
    absolute client ids, and AWGN lands post-aggregation (post-psum) from
    a shard-independent key — so python, scan and sharded see the SAME
    channel realization and stay within the matrix tolerance."""
    oracle_params, oracle_accs = _run_aircomp("python")
    params, accs = _run_aircomp(executor)
    np.testing.assert_allclose(accs, oracle_accs, atol=ATOL,
                               err_msg=f"aircomp/{executor} metrics")
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(oracle_params)):
        np.testing.assert_allclose(a, b, atol=ATOL,
                                   err_msg=f"aircomp/{executor} params")


@pytest.mark.parametrize("executor", ["fused", "hier_sync_every_round",
                                      "async"])
def test_aircomp_runs_and_perturbs(executor):
    """The cells whose channel realization legitimately differs from the
    flat oracle (fused: noise re-derived on the unraveled tree;
    hierarchical: independent per-tier draws; async: merge-round keying)
    still run, produce finite params, and actually differ from the
    noiseless cell — the channel is not silently a no-op there."""
    params, accs = _run_aircomp(executor)
    clean_params, _, _ = _run("cc", executor)
    assert all(np.isfinite(x).all() for x in jax.tree.leaves(params))
    assert any(
        not np.array_equal(a, b)
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(clean_params))), (
        f"aircomp/{executor} is numerically identical to noiseless")


def test_aircomp_is_deterministic():
    """Same spec, fresh session: the channel stream is a pure function of
    (seed, tag, round), so a rerun reproduces the noisy run bit-for-bit."""
    params, accs = _run_aircomp("scan")
    sess = Session.from_spec(_spec("cc", "scan").replace(**AIRCOMP)).run()
    assert sess.metrics.series("test_acc") == accs
    for a, b in zip(jax.tree.leaves(sess.state["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), b)


# ---------------------------------------------------------------------------
# zoo decoder cells (ISSUE 10 satellite): LoRA adapter federation on a
# tiny transformer decoder through the production executors. The engine
# never learns it is training adapters — the same 1e-5 oracle budget as
# the simple-model matrix applies. Runs on 1 visible device under tier-1
# and on 4 under the CI fed-lora-matrix job.
# ---------------------------------------------------------------------------

ZOO_EXECUTORS = ("scan", "sharded", "async")
ZOO_STRATEGIES = ("cc", "fedavg", "fedprox")


def _zoo_spec(strategy: str, executor: str) -> ExperimentSpec:
    extra = dict(prox_mu=0.1) if strategy == "fedprox" else {}
    return ExperimentSpec(
        dataset="gaussian", n_samples=128, dim=8, n_classes=4,
        n_clients=N, budget="power", beta=2, model="decoder", width=2,
        lora_rank=4, strategy=strategy, local_steps=2, batch_size=16,
        lr=0.1, schedule="adhoc", rounds=4, eval_every=2, seed=0,
        executor=executor, **extra)


_ZOO_RUNS: dict = {}


def _zoo_run(strategy: str, executor: str):
    key = (strategy, executor)
    if key not in _ZOO_RUNS:
        sess = Session.from_spec(_zoo_spec(strategy, executor)).run()
        _ZOO_RUNS[key] = (jax.tree.map(np.asarray, sess.state["params"]),
                          sess.metrics.series("test_acc"))
    return _ZOO_RUNS[key]


@pytest.mark.parametrize("strategy", ZOO_STRATEGIES)
@pytest.mark.parametrize("executor", ZOO_EXECUTORS)
def test_zoo_decoder_matrix_matches_python_oracle(executor, strategy):
    oracle_params, oracle_accs = _zoo_run(strategy, "python")
    params, accs = _zoo_run(strategy, executor)
    np.testing.assert_allclose(accs, oracle_accs, atol=ATOL,
                               err_msg=f"decoder/{executor}/{strategy} "
                                       "metric stream diverged")
    for a, b in zip(jax.tree.leaves(params),
                    jax.tree.leaves(oracle_params)):
        np.testing.assert_allclose(a, b, atol=ATOL,
                                   err_msg=f"decoder/{executor}/{strategy}")


def test_zoo_decoder_trains_only_adapters():
    params, _ = _zoo_run("cc", "scan")
    assert set(params) == {"lora"}
    assert all(set(ab) == {"lora_a", "lora_b"}
               for ab in params["lora"].values())
