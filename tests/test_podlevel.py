"""Pod-level CC-FedAvg (pods-as-clients) numerics on a reduced config."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.podlevel import (init_pod_fed_state, make_cc_pod_round,
                                 make_estimation_only_round)

N_CLIENTS, K, B, S = 2, 2, 2, 16


@pytest.fixture(scope="module")
def setup(rng):
    cfg = get_config("qwen3-1.7b", reduced=True)
    state = init_pod_fed_state(rng, cfg, N_CLIENTS)
    batches = {"tokens": jax.random.randint(
        jax.random.fold_in(rng, 1), (N_CLIENTS, K, B, S), 0, cfg.vocab)}
    return cfg, state, batches


def test_round_trains_and_aggregates(setup):
    cfg, state, batches = setup
    rd = jax.jit(make_cc_pod_round(cfg, lr=1e-2, local_steps=K,
                                   n_clients=N_CLIENTS))
    mask = jnp.ones((N_CLIENTS,))
    out = rd(state, batches, mask)
    assert int(out["round"]) == 1
    # global params moved and stay finite
    moved = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree.leaves(out["global_params"]),
        jax.tree.leaves(state["global_params"])))
    assert moved > 0
    assert all(bool(jnp.all(jnp.isfinite(x)))
               for x in jax.tree.leaves(out["global_params"]))


def test_skipping_pod_replays_delta(setup):
    """With mask=[1,0], pod 1's contribution is exactly its stored Δ and
    its stored Δ is unchanged afterwards (Strategy 3 at pod scale)."""
    cfg, state, batches = setup
    rd = jax.jit(make_cc_pod_round(cfg, lr=1e-2, local_steps=K,
                                   n_clients=N_CLIENTS))
    # seed nonzero deltas so the replay is observable
    state = dict(state)
    state["deltas"] = jax.tree.map(
        lambda d: d + 0.01 * jnp.ones_like(d), state["deltas"])
    out = rd(state, batches, jnp.asarray([1.0, 0.0]))
    for a, b in zip(jax.tree.leaves(state["deltas"]),
                    jax.tree.leaves(out["deltas"])):
        np.testing.assert_allclose(np.asarray(a[1], np.float32),
                                   np.asarray(b[1], np.float32), atol=1e-6)


def test_all_skip_equals_estimation_round(setup):
    """mask = all-zeros must equal the dedicated estimation-only program
    (the skip-round cost asymmetry the dry-run documents)."""
    cfg, state, batches = setup
    state = dict(state)
    state["deltas"] = jax.tree.map(
        lambda d: d + 0.02 * jnp.ones_like(d), state["deltas"])
    rd = jax.jit(make_cc_pod_round(cfg, lr=1e-2, local_steps=K,
                                   n_clients=N_CLIENTS))
    est = jax.jit(make_estimation_only_round(cfg))
    out1 = rd(state, batches, jnp.zeros((N_CLIENTS,)))
    out2 = est(state)
    for a, b in zip(jax.tree.leaves(out1["global_params"]),
                    jax.tree.leaves(out2["global_params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)
