"""HistoryStore tile-padding regressions (ISSUE 10 satellite).

The store's row width is padded to the 512-lane TILE; every flat parameter
count that is NOT a tile multiple (prime, < 512, == 1) must round-trip
through gather/scatter and masked writes without bit drift:

* the padded tail quantizes to payload 0 and stays exactly zero through
  arbitrarily many write round-trips;
* unmasked rows keep their stored bits verbatim (no requantization drift);
* ``read_logical`` crops back to exactly the pre-padding columns.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.history_store import TILE, HistoryStore, padded_width

N = 6

#: widths that historically only worked by accident of P % 512 == 0:
#: P = 1, tiny, prime < TILE, prime > TILE, and an exact multiple
WIDTHS = (1, 7, 509, 521, 1024)


def _rows(seed, p):
    return jax.random.normal(jax.random.PRNGKey(seed), (N, p),
                             dtype=jnp.float32)


@pytest.mark.parametrize("kind", ("dense", "int8"))
@pytest.mark.parametrize("p", WIDTHS)
def test_for_flat_geometry(kind, p):
    store = HistoryStore.for_flat(N, p, kind)
    assert store.width == padded_width(p)
    assert store.width % TILE == 0
    assert store.p_logical == p
    carry = store.init()
    store.like(carry)
    out = store.read_logical(carry)
    assert out.shape == (N, p)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


@pytest.mark.parametrize("kind", ("dense", "int8"))
@pytest.mark.parametrize("p", WIDTHS)
def test_masked_write_round_trip(kind, p):
    store = HistoryStore.for_flat(N, p, kind)
    carry = store.init()
    rows = _rows(0, p)
    mask = jnp.arange(N) % 2 == 0
    carry = store.write(carry, mask, store.pad_rows(rows))

    got = np.asarray(store.read_logical(carry))
    want = np.asarray(rows)
    m = np.asarray(mask)
    if kind == "dense":
        np.testing.assert_array_equal(got[m], want[m])
    else:
        # per-row symmetric int8: error <= scale/2 on written rows
        scale = np.abs(np.asarray(store.pad_rows(rows))).max(axis=1) / 127.0
        err = np.abs(got[m] - want[m])
        assert (err <= scale[m][:, None] * 0.5 * (1 + 1e-5)).all()
    # unmasked rows stay exactly zero
    np.testing.assert_array_equal(got[~m], 0.0)
    # the padded tail is exactly zero — in bits, not just approximately
    full = np.asarray(store.read(carry))
    np.testing.assert_array_equal(full[:, p:], 0.0)
    if kind == "int8":
        np.testing.assert_array_equal(
            np.asarray(carry["payload"])[:, p:], 0)


@pytest.mark.parametrize("kind", ("dense", "int8"))
@pytest.mark.parametrize("p", (1, 7, 509, 521))
def test_unmasked_rows_keep_bits_across_writes(kind, p):
    """A second write with a disjoint mask must not perturb previously
    written rows — the masked-`where` keeps stored bits verbatim."""
    store = HistoryStore.for_flat(N, p, kind)
    carry = store.init()
    mask_a = jnp.arange(N) % 2 == 0
    carry = store.write(carry, mask_a, store.pad_rows(_rows(0, p)))
    before = {k: np.asarray(v).copy() for k, v in carry.items()}

    carry = store.write(carry, ~mask_a, store.pad_rows(_rows(1, p)))
    m = np.asarray(mask_a)
    for k, v in carry.items():
        row_bits = np.asarray(v)
        np.testing.assert_array_equal(row_bits[m], before[k][m],
                                      err_msg=f"{kind}/{k} rows drifted")


@pytest.mark.parametrize("kind", ("dense", "int8"))
@pytest.mark.parametrize("p", (1, 7, 509, 521))
def test_scatter_gather_round_trip(kind, p):
    store = HistoryStore.for_flat(N, p, kind)
    carry = store.write(store.init(), jnp.ones(N, bool),
                        store.pad_rows(_rows(0, p)))
    before = {k: np.asarray(v).copy() for k, v in carry.items()}

    idx = jnp.asarray([0, 3])
    new = _rows(1, 2 * p)[:2, :p]
    carry = store.scatter(carry, idx, store.pad_rows(new))

    got = np.asarray(store.read_logical(carry, idx))
    want = np.asarray(new)
    if kind == "dense":
        np.testing.assert_array_equal(got, want)
    else:
        scale = np.abs(want).max(axis=1) / 127.0
        assert (np.abs(got - want)
                <= scale[:, None] * 0.5 * (1 + 1e-5) + 1e-12).all()
        np.testing.assert_array_equal(np.asarray(carry["payload"])[:, p:], 0)
    # rows outside the cohort keep their bits
    rest = np.asarray([i for i in range(N) if i not in (0, 3)])
    for k, v in carry.items():
        np.testing.assert_array_equal(np.asarray(v)[rest], before[k][rest])


def test_pad_rows_rejects_wider_rows():
    store = HistoryStore.for_flat(N, 7, "dense")
    with pytest.raises(ValueError, match="wider"):
        store.pad_rows(jnp.zeros((N, store.width + 1)))


def test_pad_rows_noop_at_tile_multiple():
    store = HistoryStore.for_flat(N, TILE, "dense")
    rows = _rows(0, TILE)
    assert store.pad_rows(rows) is rows
    assert store.width == TILE


def test_logical_width_validation():
    with pytest.raises(ValueError, match="logical_width"):
        HistoryStore(N, TILE, "dense", logical_width=TILE + 1)
    with pytest.raises(ValueError, match="logical_width"):
        HistoryStore(N, TILE, "dense", logical_width=0)
