"""Federated LoRA (ISSUE 10 tentpole): adapter-subtree training over the
model zoo, the full-rank ≡ dense identity, spec v7 gating and the 2-D
("clients", "model") federated mesh.

The wrapped model's trainable tree holds only rank-r factors, so the
*unchanged* federated core (every executor, the int8 HistoryStore, CC
replay) operates on O(N·r·d) state instead of O(N·P). The pins:

* round 0 is bit-exactly the frozen base (B zero-init);
* rank-r LoRA on the simple model matches the python oracle ≤ 1e-5 across
  the executor matrix (the acceptance criterion);
* full-rank identity LoRA (A = I frozen, scale 1, base trainable)
  reproduces the DENSE path's metric stream and test logits ≤ 1e-5 — the
  adapter machinery adds exactly zero numerics of its own;
* spec v7 gates zoo models behind ``lora_rank >= 1`` (dense federation of
  a zoo tree would silently blow the history store back up to O(N·P));
* ``make_fed_mesh`` + ``make_fed_rules`` place stacked per-client adapters
  on ``P("clients", "model", ...)`` and the sharded executor accepts the
  2-D mesh unchanged.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentSpec, Session
from repro.api.spec import SPEC_VERSION, _FIELD_INTRO
from repro.core.rounds import (FedConfig, init_fed_state,
                               make_sharded_span_runner)
from repro.core.schedules import make_plan
from repro.data.federated import CohortSampler, build_federated
from repro.data.partition import partition_gamma
from repro.data.synthetic import make_dataset, train_test_split
from repro.launch.mesh import make_fed_mesh
from repro.models.lora import (LORA_TARGETS, lora_classifier, lora_report,
                               _target_paths)
from repro.models.simple import make_classifier
from repro.models.zoo import ZOO_KINDS, make_zoo_classifier
from repro.sharding.api import ShardingContext
from repro.sharding.rules import make_fed_rules, params_pspecs

RNG = jax.random.PRNGKey(0)
ATOL = 1e-5


def _mlp():
    return make_classifier("mlp", input_shape=(8,), n_classes=4, width=4)


def _x(shape=(4, 8)):
    return jax.random.normal(jax.random.PRNGKey(7), shape)


# ---------------------------------------------------------------------------
# adapter construction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ("mlp",) + ZOO_KINDS)
def test_round0_is_bit_exactly_the_base(kind):
    """B is zero-initialized, so before any training the wrapped model IS
    the frozen base — to the bit, not within a tolerance."""
    if kind == "mlp":
        base = _mlp()
    else:
        base = make_zoo_classifier(kind, input_shape=(8,), n_classes=4,
                                   width=2, n_layers=1)
    wrapped = lora_classifier(base, RNG, 2)
    x = _x()
    np.testing.assert_array_equal(
        np.asarray(wrapped.apply(wrapped.init(jax.random.PRNGKey(5)), x)),
        np.asarray(base.apply(base.init(RNG), x)))


def test_adapter_tree_shape_and_freeze_semantics():
    base = make_zoo_classifier("decoder", input_shape=(8,), n_classes=4,
                               width=2, n_layers=1)
    wrapped = lora_classifier(base, RNG, 3)
    params = wrapped.init(jax.random.PRNGKey(1))
    assert set(params) == {"lora"}          # freeze_base: adapters only
    for path, ab in params["lora"].items():
        assert path.split("/")[-1] in LORA_TARGETS
        a, b = ab["lora_a"], ab["lora_b"]
        assert a.shape[-1] == b.shape[-2] <= 3      # rank dim
        assert not np.asarray(b).any()              # zero-init B
    # thawed base: the non-adapted leaves appear under "base", none of the
    # adapted kernels do (they are replaced by their factors)
    thawed = lora_classifier(base, RNG, 3, freeze_base=False)
    p2 = thawed.init(jax.random.PRNGKey(1))
    assert set(p2) == {"lora", "base"}
    assert set(p2["base"]).isdisjoint(set(p2["lora"]))
    assert any(path.endswith("final_norm/scale") for path in p2["base"])


def test_adapter_tree_is_small(capsys=None):
    base = make_zoo_classifier("decoder", input_shape=(8,), n_classes=4,
                               width=4, n_layers=2)
    wrapped = lora_classifier(base, RNG, 2)
    rep = lora_report(base.init(RNG), wrapped.init(RNG))
    assert rep["p_trainable"] < rep["p_dense"] / 5
    assert rep["trainable_frac"] == rep["p_trainable"] / rep["p_dense"]


def test_frozen_a_leaves_only_b_trainable():
    base = _mlp()
    wrapped = lora_classifier(base, RNG, 2, train_a=False)
    params = wrapped.init(jax.random.PRNGKey(2))
    for ab in params["lora"].values():
        assert set(ab) == {"lora_b"}
    # gradients flow into B through the frozen A
    from repro.models.simple import xent_loss
    x, y = _x(), jnp.zeros((4,), jnp.int32)
    g = jax.grad(lambda p: xent_loss(wrapped, p, x, y))(params)
    assert any(np.asarray(l).any() for l in jax.tree.leaves(g))


def test_identity_init_requires_matching_rank():
    with pytest.raises(ValueError, match="identity"):
        lora_classifier(_mlp(), RNG, 2, init_a="identity").init(RNG)


def test_bad_rank_rejected():
    with pytest.raises(ValueError, match="rank"):
        lora_classifier(_mlp(), RNG, 0)


# ---------------------------------------------------------------------------
# executor matrix on the simple model (the acceptance criterion)
# ---------------------------------------------------------------------------

_LORA_EXECUTORS = ("python", "scan", "sharded", "async")
_RUNS: dict = {}


def _lora_spec(executor: str) -> ExperimentSpec:
    return ExperimentSpec(
        dataset="gaussian", n_samples=256, dim=8, n_classes=4,
        n_clients=4, budget="power", beta=2, model="simple", width=4,
        lora_rank=2, strategy="cc", local_steps=2, batch_size=16, lr=0.1,
        schedule="adhoc", rounds=6, eval_every=2, seed=0,
        executor=executor)


def _run(executor: str):
    if executor not in _RUNS:
        sess = Session.from_spec(_lora_spec(executor)).run()
        _RUNS[executor] = (jax.tree.map(np.asarray, sess.state["params"]),
                           sess.metrics.series("test_acc"))
    return _RUNS[executor]


@pytest.mark.parametrize("executor", _LORA_EXECUTORS[1:])
def test_lora_matches_python_oracle(executor):
    """Rank-2 adapter federation on the simple model: every executor's
    final adapter tree and metric stream match the python oracle ≤ 1e-5."""
    o_params, o_accs = _run("python")
    params, accs = _run(executor)
    np.testing.assert_allclose(accs, o_accs, atol=ATOL)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(o_params)):
        np.testing.assert_allclose(a, b, atol=ATOL,
                                   err_msg=f"lora/{executor} params")


def test_lora_adapters_actually_train():
    params, _ = _run("python")
    b_leaves = [v["lora_b"] for v in params["lora"].values()]
    assert any(np.asarray(b).any() for b in b_leaves)


def test_history_state_is_adapter_sized():
    """The federated carry (Δ history) is the ADAPTER tree stacked over
    clients — O(N·r·d), not O(N·P)."""
    sess = Session.from_spec(_lora_spec("scan")).run()
    base = make_classifier("mlp", input_shape=(8,), n_classes=4, width=4)
    p_dense = sum(int(np.prod(l.shape))
                  for l in jax.tree.leaves(base.init(RNG)))
    p_hist = sum(int(np.prod(l.shape[1:]))
                 for l in jax.tree.leaves(sess.state["deltas"]))
    p_train = sum(int(np.prod(l.shape))
                  for l in jax.tree.leaves(sess.state["params"]))
    assert p_hist == p_train < p_dense


# ---------------------------------------------------------------------------
# full-rank identity LoRA ≡ the dense path
# ---------------------------------------------------------------------------


def test_full_rank_identity_lora_matches_dense():
    """With A = I frozen, scale 1 and a thawed base, W_eff = W + B and
    ∂L/∂B = ∂L/∂W: the wrapped model's SGD trajectory IS the dense path.
    The frozen base must come from PRNGKey(seed) — the same rng the
    Session hands to ``init_fed_state`` — so both runs start at the same
    point."""
    spec = _lora_spec("scan").replace(lora_rank=0)
    dense = Session.from_spec(spec).run()
    b = spec.build()
    wrapped = lora_classifier(b.model, jax.random.PRNGKey(spec.seed),
                              "full", init_a="identity", train_a=False,
                              freeze_base=False)
    sess = Session(wrapped, b.data, b.fed, b.plan, x_test=b.x_test,
                   y_test=b.y_test, eval_every=spec.eval_every,
                   executor="scan", policy=b.policy, profile=b.profile).run()
    np.testing.assert_allclose(sess.metrics.series("test_acc"),
                               dense.metrics.series("test_acc"), atol=ATOL)
    dense_logits = dense.model.apply(dense.state["params"], b.x_test)
    lora_logits = wrapped.apply(sess.state["params"], b.x_test)
    np.testing.assert_allclose(np.asarray(lora_logits),
                               np.asarray(dense_logits), atol=ATOL)


# ---------------------------------------------------------------------------
# spec v7 gating
# ---------------------------------------------------------------------------


def test_spec_v7_fields_are_versioned():
    assert SPEC_VERSION == 7
    assert _FIELD_INTRO["lora_rank"] == 7
    assert _FIELD_INTRO["freeze_base"] == 7


def test_zoo_model_requires_lora_rank():
    with pytest.raises(ValueError, match="lora_rank"):
        ExperimentSpec(model="decoder")
    ExperimentSpec(model="decoder", lora_rank=4)      # fine


def test_freeze_base_false_requires_adapters():
    with pytest.raises(ValueError, match="freeze_base"):
        ExperimentSpec(freeze_base=False)
    ExperimentSpec(freeze_base=False, lora_rank=2)    # fine


def test_negative_lora_rank_rejected():
    with pytest.raises(ValueError, match="lora_rank"):
        ExperimentSpec(lora_rank=-1)


def test_spec_round_trip_with_lora():
    spec = ExperimentSpec(model="decoder", lora_rank=4, freeze_base=True,
                          rounds=2, eval_every=1)
    assert ExperimentSpec.from_dict(spec.to_dict()) == spec


def test_simple_is_an_mlp_alias():
    a = ExperimentSpec(model="simple", rounds=2, eval_every=1).build()
    b = ExperimentSpec(model="mlp", rounds=2, eval_every=1).build()
    for u, v in zip(jax.tree.leaves(a.model.init(RNG)),
                    jax.tree.leaves(b.model.init(RNG))):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v))


# ---------------------------------------------------------------------------
# the 2-D ("clients", "model") federated mesh
# ---------------------------------------------------------------------------


def test_make_fed_mesh_validation():
    with pytest.raises(ValueError, match="clients"):
        make_fed_mesh(axes=("data", "model"))
    with pytest.raises(ValueError, match="shape"):
        make_fed_mesh(shape=(1,))
    ndev = len(jax.devices())
    with pytest.raises(ValueError, match="mesh size"):
        make_fed_mesh(shape=(ndev + 1, 2))
    mesh = make_fed_mesh()
    assert mesh.axis_names == ("clients", "model")
    assert mesh.devices.shape == (ndev, 1)


def test_fed_rules_place_stacked_adapters():
    """Stacked per-client adapters: leading dim on 'clients', the rank dim
    on 'model', factor dims replicated."""
    mesh = make_fed_mesh(shape=(1, 1))
    ctx = ShardingContext(mesh=mesh, rules=make_fed_rules())
    wrapped = lora_classifier(_mlp(), RNG, 2)
    stacked = jax.vmap(wrapped.init)(
        jax.random.split(jax.random.PRNGKey(0), 4))
    specs = params_pspecs(ctx, stacked, client_axis=True)
    flat = {p: s for p, s in
            ((path, spec) for path, spec in _flatten(specs))}
    b_specs = [s for p, s in flat.items() if p.endswith("lora_b")]
    assert b_specs, "no lora_b leaves in the stacked tree"
    for s in b_specs:
        assert tuple(s) == ("clients", "model", None)
    a_specs = [s for p, s in flat.items() if p.endswith("lora_a")]
    for s in a_specs:
        assert tuple(s) == ("clients", None, "model")


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _flatten(v, f"{prefix}{k}/")
    else:
        yield prefix[:-1], tree


def test_sharded_executor_on_fed_mesh():
    """The sharded span runner accepts the 2-D federated mesh and
    reproduces the default-mesh run bit-for-bit (specs never name 'model',
    so the extra axis only replicates)."""
    ds = make_dataset("gaussian", n=256, dim=8, n_classes=4, seed=0)
    tr, _ = train_test_split(ds)
    fd = build_federated(tr, partition_gamma(tr, 4, gamma=0.5, seed=0))
    model = lora_classifier(_mlp(), RNG, 2)
    fed = FedConfig(strategy="cc", local_steps=2, batch_size=16, lr=0.1)
    plan = make_plan("adhoc", np.ones(4), 4, seed=2)
    sel, train = jnp.asarray(plan.selection), jnp.asarray(plan.training)
    k = jnp.full((4,), fed.local_steps, jnp.int32)
    idx = jnp.asarray(CohortSampler(4, 2, seed=3).indices(4))

    def fresh():
        return init_fed_state(jax.random.PRNGKey(0), model, 4)

    s_1d = make_sharded_span_runner(model, fd, fed, cohort_size=2)(
        fresh(), sel, train, k, idx)
    s_2d = make_sharded_span_runner(
        model, fd, fed, cohort_size=2,
        mesh=make_fed_mesh(shape=(1, 1)))(fresh(), sel, train, k, idx)
    for a, b in zip(jax.tree.leaves(s_1d["params"]),
                    jax.tree.leaves(s_2d["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_target_paths_cover_zoo_attention_and_mlp():
    """Every zoo kind exposes ≥ 1 attention/MLP projection to adapt —
    including xLSTM, whose mixer leaves reuse the wq/wk/wv names."""
    for kind in ZOO_KINDS:
        base = make_zoo_classifier(kind, input_shape=(8,), n_classes=4,
                                   width=2, n_layers=1)
        paths = _target_paths(base.init(RNG), LORA_TARGETS)
        assert paths, f"{kind} has no adaptable leaves"
