"""The loop-aware HLO cost model (launch/hlo_cost.py) against programs
with analytically known FLOP counts."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import loop_aware_costs


def _costs(fn, *specs):
    return loop_aware_costs(jax.jit(fn).lower(*specs).compile().as_text())


def test_single_matmul_exact():
    m, k, n = 64, 128, 32
    t = _costs(lambda a, b: a @ b,
               jax.ShapeDtypeStruct((m, k), jnp.float32),
               jax.ShapeDtypeStruct((k, n), jnp.float32))
    assert t.flops == pytest.approx(2 * m * k * n, rel=1e-6)


def test_scan_multiplies_by_trip_count():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=13)
        return y

    t = _costs(f, jax.ShapeDtypeStruct((32, 32), jnp.float32),
               jax.ShapeDtypeStruct((32, 32), jnp.float32))
    assert t.flops == pytest.approx(13 * 2 * 32 ** 3, rel=0.05)


def test_nested_scan_composes():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=7)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    t = _costs(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
               jax.ShapeDtypeStruct((64, 64), jnp.float32))
    assert t.flops == pytest.approx(35 * 2 * 64 ** 3, rel=0.05)


def test_scanned_equals_unrolled():
    """The invariance XLA's own cost_analysis lacks."""
    def block(x, w1, w2):
        return x + jnp.maximum(x @ w1, 0) @ w2

    def scanned(x, w1s, w2s):
        def body(c, ws):
            return block(c, ws[0], ws[1]), None
        y, _ = jax.lax.scan(body, x, (w1s, w2s))
        return y

    def unrolled(x, w1s, w2s):
        for i in range(6):
            x = block(x, w1s[i], w2s[i])
        return x

    xs = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w1 = jax.ShapeDtypeStruct((6, 64, 128), jnp.float32)
    w2 = jax.ShapeDtypeStruct((6, 128, 64), jnp.float32)
    ts = _costs(scanned, xs, w1, w2)
    tu = _costs(unrolled, xs, w1, w2)
    assert ts.flops == pytest.approx(tu.flops, rel=0.02)
    exact = 6 * (2 * 32 * 64 * 128 * 2)
    assert ts.flops == pytest.approx(exact, rel=0.02)


def test_remat_counted():
    """jax.checkpoint recompute shows up as extra FLOPs in the backward."""
    def loss(x, w):
        h = jax.checkpoint(lambda a: jnp.tanh(a @ w))(x)
        return jnp.sum(h * h)

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    t_fwd = _costs(loss, x, w)
    t_grad = _costs(jax.grad(loss, argnums=(0, 1)), x, w)
    # grad ≥ fwd + 2 backward matmuls (recompute may be CSE'd for this
    # single-matmul body)
    assert t_grad.flops >= 2.9 * t_fwd.flops


def test_collectives_scale_with_loop(monkeypatch):
    """A psum inside a scanned shard_map body counts trip_count times."""
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))

    def inner(a):
        return jax.lax.psum(a, "x")

    def f(a):
        sm = shard_map(inner, mesh=mesh, in_specs=P("x"), out_specs=P())

        def body(c, _):
            return c + sm(c), None
        y, _ = jax.lax.scan(body, a, None, length=9)
        return y

    with mesh:
        t = _costs(f, jax.ShapeDtypeStruct((8,), jnp.float32))
    # 9 iterations × 8 floats × 4B = 288 bytes of all-reduce
    assert t.collective_bytes == pytest.approx(9 * 8 * 4, rel=0.1) or \
        t.collective_bytes == 0.0   # single-device AR may be elided
