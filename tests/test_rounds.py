"""Round executors: the refactored registry engine must reproduce the
pre-refactor monolith bit-for-bit, the scan executor must match the python
loop, and the fused Pallas path must match the tree-ops path ≤1e-5.

``_legacy_round_fn`` below is a verbatim copy of the pre-refactor
``engine.make_round_fn`` round body (the seven-way if/elif monolith) and is
the golden reference the equivalence tests compare against.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import (FedConfig, init_fed_state, make_round_fn,
                               run_federated)
from repro.core.rounds import make_span_runner, span_boundaries
from repro.core.schedules import make_plan
from repro.data.federated import build_federated
from repro.data.partition import budget_law, partition_gamma
from repro.data.synthetic import make_dataset, train_test_split
from repro.models.simple import make_classifier
from repro.utils.pytree import (tree_add, tree_broadcast_clients,
                                tree_masked_mean, tree_ravel,
                                tree_ravel_clients, tree_sub,
                                tree_zeros_like)

N = 4


@pytest.fixture(scope="module")
def setup():
    ds = make_dataset("gaussian", n=256, dim=8, n_classes=4, seed=0)
    tr, te = train_test_split(ds)
    parts = partition_gamma(tr, N, gamma=0.5, seed=0)
    fd = build_federated(tr, parts)
    model = make_classifier("mlp", input_shape=(8,), n_classes=4, width=4)
    return model, fd, te


# ---------------------------------------------------------------------------
# golden reference: the pre-refactor monolithic round function
# ---------------------------------------------------------------------------


def _mask_tree(mask, a, b):
    def sel(x, y):
        m = mask.reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.where(m, x, y)
    return jax.tree.map(sel, a, b)


def _legacy_round_fn(model, data, fed):
    """Verbatim pre-refactor round body (if/elif over strategy names)."""
    from repro.core.rounds import _local_train
    n = data.n_clients

    @functools.partial(jax.jit, static_argnames=())
    def round_fn(state, sel_mask, train_mask, k_active):
        key, *keys = jax.random.split(state["key"], n + 1)
        keys = jnp.stack(keys)
        broadcast = tree_broadcast_clients(state["params"], n)
        local = jax.vmap(
            lambda p, k, cx, cy, sz, ka: _local_train(
                model, p, k, cx, cy, sz, fed.local_steps, ka,
                fed.batch_size, fed.lr)
        )(broadcast, keys, data.x, data.y, data.sizes, k_active)
        trained_delta = tree_sub(local, broadcast)

        stale_delta = tree_sub(state["prev_local"], broadcast)
        stale_delta = _mask_tree(state["trained_ever"], stale_delta,
                                 tree_zeros_like(stale_delta))
        if fed.strategy == "cc":
            est = state["deltas"]
        elif fed.strategy == "ccc":
            use_s3 = state["round"] < fed.tau
            est = jax.tree.map(
                lambda a, b: jnp.where(use_s3, a, b),
                state["deltas"], stale_delta)
        elif fed.strategy == "s2":
            est = stale_delta
        else:  # s1 / fedavg / dropout / fednova never aggregate estimates
            est = tree_zeros_like(trained_delta)

        delta_i = _mask_tree(train_mask, trained_delta, est)

        if fed.strategy in ("s1", "fedavg", "dropout", "fednova"):
            agg_mask = sel_mask & train_mask
        else:
            agg_mask = sel_mask
        aggf = agg_mask.astype(jnp.float32)
        if fed.strategy == "fednova":
            ka = jnp.maximum(k_active.astype(jnp.float32), 1.0)
            d_norm = jax.tree.map(
                lambda x: x / ka.reshape((-1,) + (1,) * (x.ndim - 1)),
                delta_i)
            coeff = jnp.sum(aggf * ka) / jnp.maximum(jnp.sum(aggf), 1e-9)
            delta = jax.tree.map(
                lambda x: coeff * x, tree_masked_mean(d_norm, aggf))
        else:
            delta = tree_masked_mean(delta_i, aggf)
        new_params = tree_add(state["params"], delta)

        upd = sel_mask & train_mask
        deltas = _mask_tree(upd, trained_delta, state["deltas"])
        prev_local = _mask_tree(upd, local, state["prev_local"])
        return {
            "params": new_params,
            "deltas": deltas,
            "prev_local": prev_local,
            "trained_ever": state["trained_ever"] | upd,
            "round": state["round"] + 1,
            "key": key,
        }

    return round_fn


MASKS = [  # (sel, train) per round: mixed selection / skip patterns
    (np.array([1, 1, 1, 1], bool), np.array([1, 1, 1, 1], bool)),
    (np.array([1, 1, 1, 1], bool), np.array([1, 0, 1, 0], bool)),
    (np.array([1, 1, 0, 1], bool), np.array([0, 1, 0, 1], bool)),
    (np.array([1, 1, 1, 0], bool), np.array([1, 1, 0, 0], bool)),
]


@pytest.mark.parametrize("strategy",
                         ["fedavg", "s1", "s2", "cc", "ccc", "fednova",
                          "dropout"])
def test_registry_engine_matches_legacy_monolith(setup, strategy):
    """≥3 rounds of the new registry-dispatched round must reproduce the
    pre-refactor monolith exactly (same seed ⇒ same state trajectory)."""
    model, fd, _ = setup
    fed = FedConfig(strategy=strategy, local_steps=2, tau=2)
    k = jnp.full((N,), fed.local_steps, jnp.int32)
    if strategy == "fednova":
        k = jnp.asarray([2, 1, 2, 1], jnp.int32)
    new_rf = make_round_fn(model, fd, fed)
    old_rf = _legacy_round_fn(model, fd, fed)
    s_new = init_fed_state(jax.random.PRNGKey(0), model, N)
    s_old = init_fed_state(jax.random.PRNGKey(0), model, N)
    for sel, train in MASKS:
        s_new = new_rf(s_new, jnp.asarray(sel), jnp.asarray(train), k)
        s_old = old_rf(s_old, jnp.asarray(sel), jnp.asarray(train), k)
        for key in ("params", "deltas", "prev_local"):
            for a, b in zip(jax.tree.leaves(s_new[key]),
                            jax.tree.leaves(s_old[key])):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-7, err_msg=key)
        np.testing.assert_array_equal(np.asarray(s_new["trained_ever"]),
                                      np.asarray(s_old["trained_ever"]))


# ---------------------------------------------------------------------------
# scan executor ≡ python loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", ["fedavg", "s2", "cc", "ccc",
                                      "fednova"])
def test_scan_executor_matches_python_loop(setup, strategy):
    """run_federated(executor='scan') and (executor='python') must produce
    identical per-round test_acc trajectories and final state."""
    model, fd, te = setup
    p = budget_law(N, beta=2)
    plan = make_plan("adhoc", p, 12, seed=1)
    fed = FedConfig(strategy=strategy, local_steps=2, batch_size=16, lr=0.1)
    kw = dict(x_test=jnp.asarray(te.x), y_test=jnp.asarray(te.y),
              eval_every=4)
    s_py, m_py = run_federated(model, fd, fed, plan, executor="python", **kw)
    s_sc, m_sc = run_federated(model, fd, fed, plan, executor="scan", **kw)
    assert m_py.series("test_acc") == m_sc.series("test_acc")
    for a, b in zip(jax.tree.leaves(s_py["params"]),
                    jax.tree.leaves(s_sc["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


def test_span_runner_equals_repeated_rounds(setup):
    model, fd, _ = setup
    fed = FedConfig(strategy="cc", local_steps=2)
    k = jnp.full((N,), fed.local_steps, jnp.int32)
    sel = jnp.asarray(np.stack([m[0] for m in MASKS]))
    train = jnp.asarray(np.stack([m[1] for m in MASKS]))
    rf = make_round_fn(model, fd, fed)
    runner = make_span_runner(model, fd, fed)
    s_loop = init_fed_state(jax.random.PRNGKey(0), model, N)
    for t in range(sel.shape[0]):
        s_loop = rf(s_loop, sel[t], train[t], k)
    s_scan = runner(init_fed_state(jax.random.PRNGKey(0), model, N),
                    sel, train, k)
    for a, b in zip(jax.tree.leaves(s_loop["params"]),
                    jax.tree.leaves(s_scan["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)
    assert int(s_scan["round"]) == sel.shape[0]


def test_span_boundaries_match_legacy_eval_cadence():
    for rounds, every in [(80, 20), (30, 7), (5, 10), (1, 1), (12, 4)]:
        legacy = [t + 1 for t in range(rounds)
                  if (t + 1) % every == 0 or t == rounds - 1]
        assert span_boundaries(rounds, every) == sorted(set(legacy))


def test_span_boundaries_eval_every_beyond_rounds_is_one_span():
    # a cadence longer than the plan means exactly one span, ending at the
    # final round — no phantom boundaries
    assert span_boundaries(5, 10) == [5]
    assert span_boundaries(1, 100) == [1]
    assert span_boundaries(7, 7) == [7]


@pytest.mark.parametrize("bad", [0, -1, -100])
def test_span_boundaries_rejects_nonpositive_eval_every(bad):
    # regression: eval_every=0 used to emit a bogus round-0 boundary and
    # negative values produced negative stops
    with pytest.raises(ValueError, match="eval_every"):
        span_boundaries(10, bad)


@pytest.mark.parametrize("bad", [0, -1])
def test_span_boundaries_rejects_nonpositive_rounds(bad):
    with pytest.raises(ValueError, match="rounds"):
        span_boundaries(bad, 5)


def test_session_rejects_nonpositive_eval_every(setup):
    # the session guards eagerly (its python loop would otherwise die on a
    # modulo-by-zero mid-run)
    from repro.api import Session
    model, fd, te = setup
    plan = make_plan("full", np.ones(N), 2)
    with pytest.raises(ValueError, match="eval_every"):
        Session(model, fd, FedConfig(strategy="cc"), plan,
                x_test=jnp.asarray(te.x), y_test=jnp.asarray(te.y),
                eval_every=0)


def test_unknown_executor_raises(setup):
    model, fd, te = setup
    plan = make_plan("full", np.ones(N), 2)
    with pytest.raises(ValueError):
        run_federated(model, fd, FedConfig(strategy="cc"), plan,
                      x_test=jnp.asarray(te.x), y_test=jnp.asarray(te.y),
                      executor="warp")


# ---------------------------------------------------------------------------
# fused Pallas path ≡ tree-ops path
# ---------------------------------------------------------------------------


def test_fused_round_matches_tree_ops(setup):
    """The single-HBM-pass kernel round (interpret mode on CPU) matches the
    tree-ops round to ≤1e-5 over several rounds with mixed masks."""
    model, fd, _ = setup
    fed = FedConfig(strategy="cc", local_steps=2)
    k = jnp.full((N,), fed.local_steps, jnp.int32)
    rf_tree = make_round_fn(model, fd, fed)
    rf_fused = make_round_fn(model, fd, fed, fused=True)
    s_t = init_fed_state(jax.random.PRNGKey(0), model, N)
    s_f = init_fed_state(jax.random.PRNGKey(0), model, N)
    for sel, train in MASKS:
        s_t = rf_tree(s_t, jnp.asarray(sel), jnp.asarray(train), k)
        s_f = rf_fused(s_f, jnp.asarray(sel), jnp.asarray(train), k)
        for key in ("params", "deltas"):
            for a, b in zip(jax.tree.leaves(s_t[key]),
                            jax.tree.leaves(s_f[key])):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-5, err_msg=key)


def test_fused_end_to_end_matches(setup):
    model, fd, te = setup
    p = budget_law(N, beta=2)
    plan = make_plan("adhoc", p, 8, seed=2)
    fed = FedConfig(strategy="cc", local_steps=2, batch_size=16, lr=0.1)
    kw = dict(x_test=jnp.asarray(te.x), y_test=jnp.asarray(te.y),
              eval_every=4)
    s_a, m_a = run_federated(model, fd, fed, plan, executor="scan", **kw)
    s_b, m_b = run_federated(model, fd, fed, plan, executor="scan",
                             use_fused=True, **kw)
    for a, b in zip(jax.tree.leaves(s_a["params"]),
                    jax.tree.leaves(s_b["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    np.testing.assert_allclose(m_a.series("test_acc"),
                               m_b.series("test_acc"), atol=0.02)


def test_fused_requires_capable_strategy(setup):
    """Every built-in strategy carries a ``FusedEpilogue`` now, so the
    rejection path only triggers for custom strategies registered without
    one (``fused_capable`` defaults to False)."""
    from repro.core import strategies as strat_mod

    model, fd, _ = setup
    name = "_tmp_no_epilogue"
    strat_mod.register(strat_mod.Strategy(name=name))
    try:
        with pytest.raises(ValueError, match="not fused-capable"):
            make_round_fn(model, fd, FedConfig(strategy=name), fused=True)
    finally:
        del strat_mod._REGISTRY[name]
    for builtin in strat_mod.available_strategies():
        assert strat_mod.get_strategy(builtin).fused_capable


# ---------------------------------------------------------------------------
# flat raveling helpers
# ---------------------------------------------------------------------------


def test_tree_ravel_round_trip(rng):
    tree = {"a": jax.random.normal(rng, (3, 5)),
            "b": {"c": jax.random.normal(jax.random.fold_in(rng, 1), (7,)),
                  "d": jnp.ones((2, 2, 2), jnp.float32)}}
    flat, unravel = tree_ravel(tree)
    assert flat.shape == (3 * 5 + 7 + 8,)
    back = unravel(flat)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tree_ravel_clients_round_trip(rng):
    n = 3
    tree = {"w": jax.random.normal(rng, (n, 4, 2)),
            "b": jax.random.normal(jax.random.fold_in(rng, 1), (n, 5))}
    flat, unravel = tree_ravel_clients(tree)
    assert flat.shape == (n, 8 + 5)
    back = unravel(flat)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tree_ravel_layouts_agree(rng):
    """Per-client raveling of a broadcast tree stacks the single-tree
    raveling row-wise — the alignment contract of the fused kernel."""
    tree = {"w": jax.random.normal(rng, (4, 2)), "b": jnp.ones((3,))}
    flat, _ = tree_ravel(tree)
    stacked = tree_broadcast_clients(tree, 5)
    flat_c, _ = tree_ravel_clients(stacked)
    for i in range(5):
        np.testing.assert_array_equal(np.asarray(flat_c[i]),
                                      np.asarray(flat))
