"""Budget-policy engine: device simulator semantics, policy decisions,
ledger accounting, spec/CLI wiring.

The bit-for-bit PrecompiledPolicy × executor matrix lives in
``tests/test_executor_matrix.py``; stateful-policy resume pins live in
``tests/test_api.py``. This file covers the layer itself.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentSpec, Session
from repro.core.budget import (AdaptiveProbability, BudgetCtx,
                               DeadlineAware, EnergyAware,
                               PrecompiledPolicy, available_policies,
                               budget_ctx, make_policy)
from repro.core.rounds import (FedConfig, init_fed_state,
                               make_policy_round_fn,
                               make_policy_span_runner)
from repro.core.schedules import make_plan
from repro.data.federated import build_federated
from repro.data.partition import budget_law, partition_gamma
from repro.data.synthetic import make_dataset, train_test_split
from repro.models.simple import make_classifier
from repro.system.devices import (advance_devices, device_awake,
                                  init_device_state, init_ledger,
                                  make_profile, update_ledger)

N = 4


@pytest.fixture(scope="module")
def setup():
    ds = make_dataset("gaussian", n=256, dim=8, n_classes=4, seed=0)
    tr, _ = train_test_split(ds)
    parts = partition_gamma(tr, N, gamma=0.5, seed=0)
    fd = build_federated(tr, parts)
    model = make_classifier("mlp", input_shape=(8,), n_classes=4, width=4)
    return model, fd


# ---------------------------------------------------------------------------
# device simulator
# ---------------------------------------------------------------------------


def test_profile_budget_kind_maps_p_to_harvest():
    p = np.array([1.0, 0.5, 0.25, 0.125])
    prof = make_profile("budget", p, harvest_scale=1.0)
    np.testing.assert_allclose(np.asarray(prof.harvest), p)
    np.testing.assert_allclose(np.asarray(prof.flops_rate), p)
    np.testing.assert_allclose(np.asarray(prof.train_cost), 1.0)
    assert prof.n_clients == N
    rows = prof.rows()
    assert set(rows) >= {"budget", "train_cost", "harvest", "capacity"}


def test_profile_validation():
    with pytest.raises(ValueError, match="budgets"):
        make_profile("budget", np.array([0.0, 0.5]))
    with pytest.raises(ValueError, match="budgets"):
        make_profile("budget", np.array([np.nan]))
    with pytest.raises(ValueError, match="unknown device profile"):
        make_profile("solar", np.array([0.5]))
    with pytest.raises(ValueError, match="capacity"):
        make_profile("uniform", np.array([0.5]), capacity=0.0)
    with pytest.raises(ValueError, match="load_rho"):
        make_profile("uniform", np.array([0.5]), load_rho=1.0)
    with pytest.raises(ValueError, match="duty"):
        make_profile("uniform", np.array([0.5]), duty_period=2, duty_on=3)


def test_energy_drains_harvests_and_clips():
    p = np.array([1.0, 0.5])
    prof = make_profile("budget", p, capacity=2.0, init_energy=1.0)
    rows, ids = prof.rows(), jnp.arange(2)
    dev = init_device_state(prof)
    # round 0: client 0 trains (cost 1, harvest 1 -> back to 1.0);
    # client 1 idles (harvest 0.5 -> 1.5)
    dev = advance_devices(rows, dev, jnp.asarray([True, False]),
                          jnp.asarray(0), ids, prof.seed)
    np.testing.assert_allclose(np.asarray(dev["energy"]), [1.0, 1.5])
    # idle forever: reserves clip at capacity
    for t in range(1, 6):
        dev = advance_devices(rows, dev, jnp.zeros(2, bool),
                              jnp.asarray(t), ids, prof.seed)
    np.testing.assert_allclose(np.asarray(dev["energy"]), [2.0, 2.0])


def test_energy_never_negative():
    prof = make_profile("budget", np.array([0.1]), init_energy=0.2)
    dev = init_device_state(prof)
    dev = advance_devices(prof.rows(), dev, jnp.asarray([True]),
                          jnp.asarray(0), jnp.arange(1), prof.seed)
    assert float(dev["energy"][0]) >= 0.0


def test_load_noise_is_stateless_and_shard_consistent():
    """Noise keys on (seed, round, ABSOLUTE client id): advancing a gathered
    half-cohort produces exactly the rows of the full advance."""
    p = np.full(N, 0.5)
    prof = make_profile("budget", p, load_mean=0.3, load_jitter=0.2,
                        load_rho=0.5, seed=7)
    rows, dev = prof.rows(), init_device_state(prof)
    full = advance_devices(rows, dev, jnp.zeros(N, bool), jnp.asarray(3),
                           jnp.arange(N), prof.seed)
    idx = jnp.asarray([1, 3])
    take = lambda t: jax.tree.map(lambda x: x[idx], t)  # noqa: E731
    part = advance_devices(take(rows), take(dev), jnp.zeros(2, bool),
                           jnp.asarray(3), idx, prof.seed)
    np.testing.assert_array_equal(np.asarray(full["load"])[np.asarray(idx)],
                                  np.asarray(part["load"]))


def test_duty_cycle_mask():
    prof = make_profile("uniform", np.ones(2), duty_period=3, duty_on=1)
    rows = prof.rows()
    awake = [bool(device_awake(rows, jnp.asarray(t))[0]) for t in range(6)]
    assert awake == [True, False, False, True, False, False]


def test_ledger_accumulates_and_prices_energy():
    prof = make_profile("budget", np.array([1.0, 0.5]))
    rows = prof.rows()
    led = init_ledger(2)
    sel = jnp.asarray([True, True])
    led = update_ledger(led, rows, sel, jnp.asarray([True, False]))
    led = update_ledger(led, rows, sel, jnp.asarray([True, True]))
    led = update_ledger(led, rows, jnp.asarray([False, True]),
                        jnp.asarray([True, True]))     # 0 unselected
    np.testing.assert_array_equal(np.asarray(led["train_rounds"]), [2, 2])
    np.testing.assert_array_equal(np.asarray(led["est_rounds"]), [0, 1])
    np.testing.assert_allclose(np.asarray(led["energy_spent"]), [2.0, 2.0])


# ---------------------------------------------------------------------------
# policy decisions
# ---------------------------------------------------------------------------


def _ctx(prof, dev=None, rnd=0, sel=None):
    n = prof.n_clients
    return budget_ctx(prof.rows(), dev or init_device_state(prof),
                      jnp.asarray(rnd), jnp.arange(n),
                      jnp.ones(n, bool) if sel is None else sel,
                      prof.seed)


def test_precompiled_policy_replays_table():
    plan = make_plan("round_robin", np.array([1.0, 0.5, 0.25]), 8, seed=1)
    pol = PrecompiledPolicy.from_plan(plan)
    prof = make_profile("budget", plan.p)
    for t in range(8):
        mask, _ = pol.decide({}, _ctx(prof, rnd=t))
        np.testing.assert_array_equal(np.asarray(mask), plan.training[t])


def test_precompiled_policy_requires_table():
    with pytest.raises(ValueError, match="table"):
        PrecompiledPolicy()
    with pytest.raises(ValueError, match="plan"):
        make_policy("precompiled")


def test_energy_aware_trains_iff_reserve_covers_cost():
    prof = make_profile("budget", np.array([1.0, 0.5, 0.25]),
                        init_energy=1.0)
    pol = EnergyAware()
    dev = init_device_state(prof)
    mask, _ = pol.decide({}, _ctx(prof, dev=dev))
    np.testing.assert_array_equal(np.asarray(mask), [True, True, True])
    dev = {"energy": jnp.asarray([1.0, 0.5, 0.99]), "load": dev["load"]}
    mask, _ = pol.decide({}, _ctx(prof, dev=dev))
    np.testing.assert_array_equal(np.asarray(mask), [True, False, False])


def test_energy_aware_sustains_budget_fraction(setup):
    """With the 'budget' profile (harvest = p·cost), EnergyAware's realized
    training fraction over a long horizon approaches p_i — the energy
    translation of the paper's computational budget."""
    model, fd = setup
    p = np.array([1.0, 0.5, 0.25, 0.125])
    prof = make_profile("budget", p, init_energy=1.0)
    fed = FedConfig(strategy="cc", local_steps=1, batch_size=8, lr=0.05)
    run = make_policy_span_runner(model, fd, fed, EnergyAware(), prof)
    state = init_fed_state(jax.random.PRNGKey(0), model, N,
                           policy=EnergyAware(), profile=prof)
    t = 64
    state = run(state, jnp.ones((t, N), bool),
                jnp.full((N,), 1, jnp.int32))
    frac = np.asarray(state["ledger"]["train_rounds"]) / t
    np.testing.assert_allclose(frac, p, atol=0.05)


def test_deadline_aware_drops_slow_or_loaded_devices():
    p = np.array([1.0, 0.5, 1.0])
    prof = make_profile("budget", p)
    pol = DeadlineAware(deadline=1.5)
    dev = init_device_state(prof)
    # client 1's nominal time = 1/0.5 = 2 > 1.5; client 2 gets 60% load
    dev = {"energy": dev["energy"],
           "load": jnp.asarray([0.0, 0.0, 0.6])}
    mask, _ = pol.decide({}, _ctx(prof, dev=dev))
    np.testing.assert_array_equal(np.asarray(mask), [True, False, False])
    with pytest.raises(ValueError, match="deadline"):
        DeadlineAware(deadline=0.0)


def test_adaptive_probability_matches_budget_in_expectation():
    p = np.full(1, 0.4)
    prof = make_profile("budget", p, seed=5)
    pol = AdaptiveProbability(eta=0.5)
    rows = pol.init_rows(1)
    trained = 0
    t = 400
    for rnd in range(t):
        mask, rows = pol.decide(rows, _ctx(prof, rnd=rnd))
        trained += int(mask[0])
    assert abs(trained / t - 0.4) < 0.1
    # the rows carried the realized counts
    assert float(rows["seen"][0]) == t
    assert float(rows["trained"][0]) == trained
    with pytest.raises(ValueError, match="eta"):
        AdaptiveProbability(eta=-0.1)


def test_adaptive_catches_up_after_forced_skips():
    """Feedback: a client that slept below its budget raises its effective
    probability above a memoryless coin."""
    p = np.full(1, 0.5)
    prof = make_profile("budget", p, seed=3)
    pol = AdaptiveProbability(eta=10.0)       # aggressive feedback
    rows = {"trained": jnp.zeros((1,)), "seen": jnp.full((1,), 10.0)}
    mask, _ = pol.decide(rows, _ctx(prof, rnd=0))
    assert bool(mask[0])                      # p_eff clipped to 1 ⇒ trains


def test_make_policy_factory_and_registry():
    assert set(available_policies()) == {"precompiled", "energy",
                                         "deadline", "adaptive"}
    assert make_policy("energy").name == "energy"
    assert make_policy("deadline", deadline=1.0).deadline == 1.0
    assert make_policy("adaptive", eta=0.2).eta == 0.2
    with pytest.raises(ValueError, match="unknown policy"):
        make_policy("psychic")


# ---------------------------------------------------------------------------
# executor integration
# ---------------------------------------------------------------------------


def test_policy_mode_requires_matching_profile(setup):
    model, fd = setup
    small = make_profile("budget", np.array([0.5]))
    with pytest.raises(ValueError, match="profile"):
        make_policy_round_fn(model, fd, FedConfig(strategy="cc"),
                             EnergyAware(), small)
    with pytest.raises(ValueError, match="policy"):
        init_fed_state(jax.random.PRNGKey(0), model, N,
                       policy=EnergyAware())


def test_energy_policy_session_end_to_end():
    """An EnergyAware session runs under every non-sharded executor and its
    ledger/device state are self-consistent."""
    spec = ExperimentSpec(
        dataset="gaussian", n_samples=256, dim=8, n_classes=4, n_clients=N,
        budget="power", beta=2, model="mlp", width=4, strategy="cc",
        local_steps=2, batch_size=16, lr=0.1, schedule="adhoc", rounds=8,
        eval_every=4, seed=0, policy="energy", energy_init=1.0)
    sess = Session.from_spec(spec).run()
    led = sess.ledger()
    decided = led["train_rounds"] + led["est_rounds"]
    np.testing.assert_array_equal(decided, np.full(N, 8))
    np.testing.assert_allclose(led["energy_spent"], led["train_rounds"])
    s = sess.summary()
    assert s["policy"] == "energy"
    assert 0.0 < s["train_fraction"] <= 1.0
    assert 0.0 <= s["test_acc"] <= 1.0


def test_policy_decisions_respect_selection_mask(setup):
    """Unselected clients never train, never pay energy, never enter the
    ledger — under any policy."""
    model, fd = setup
    p = np.ones(N)
    prof = make_profile("uniform", p)
    fed = FedConfig(strategy="cc", local_steps=1, batch_size=8, lr=0.05)
    run = make_policy_span_runner(model, fd, fed, EnergyAware(), prof)
    state = init_fed_state(jax.random.PRNGKey(0), model, N,
                           policy=EnergyAware(), profile=prof)
    sel = jnp.asarray(np.tile([True, True, False, False], (6, 1)))
    state = run(state, sel, jnp.full((N,), 1, jnp.int32))
    led = jax.device_get(state["ledger"])
    np.testing.assert_array_equal(led["train_rounds"], [6, 6, 0, 0])
    np.testing.assert_array_equal(led["est_rounds"], [0, 0, 0, 0])
    np.testing.assert_allclose(led["energy_spent"], [6, 6, 0, 0])


# ---------------------------------------------------------------------------
# spec / CLI wiring
# ---------------------------------------------------------------------------


def test_spec_policy_fields_round_trip():
    spec = ExperimentSpec(policy="deadline", deadline=1.25,
                          device_profile="uniform", load_mean=0.2,
                          load_jitter=0.1)
    back = ExperimentSpec.from_dict(spec.to_dict())
    assert back == spec
    assert back.policy == "deadline" and back.deadline == 1.25


def test_spec_rejects_bad_policy_fields():
    with pytest.raises(ValueError, match="policy"):
        ExperimentSpec(policy="psychic")
    with pytest.raises(ValueError, match="device_profile"):
        ExperimentSpec(device_profile="solar")
    with pytest.raises(ValueError, match="energy_capacity"):
        ExperimentSpec(energy_capacity=0.0)
    with pytest.raises(ValueError, match="deadline"):
        ExperimentSpec(deadline=-1.0)
    with pytest.raises(ValueError, match="adapt_eta"):
        ExperimentSpec(adapt_eta=-0.5)


def test_spec_v1_dicts_still_load():
    """Pre-policy (v1) spec files carry no policy fields; defaults apply."""
    d = ExperimentSpec().to_dict()
    for k in ("policy", "device_profile", "energy_capacity", "energy_init",
              "harvest_scale", "load_mean", "load_rho", "load_jitter",
              "deadline", "adapt_eta"):
        d.pop(k)
    d["spec_version"] = 1
    spec = ExperimentSpec.from_dict(d)
    assert spec.policy == "precompiled"


def test_cli_policy_flag(tmp_path, capsys):
    import json
    from repro.api.cli import main as cli_main
    spec_path = str(tmp_path / "spec.json")
    cli_main(["init", spec_path, "--set", "rounds=3",
              "--set", "eval_every=3", "--set", "n_samples=256",
              "--set", "dim=8", "--set", "n_classes=4",
              "--set", "n_clients=4", "--set", "width=4",
              "--set", "local_steps=2"])
    capsys.readouterr()
    assert cli_main(["run", spec_path, "--policy", "energy",
                     "--device-profile", "budget", "--quiet"]) == 0
    summary = json.loads(capsys.readouterr().out.strip().splitlines()[-1])
    assert summary["policy"] == "energy"
    assert summary["rounds_done"] == 3
