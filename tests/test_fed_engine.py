"""CC-FedAvg engine semantics — the paper's Algorithm 1/2/3 invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.engine import (FedConfig, cost_report, init_fed_state,
                               make_round_fn, run_federated)
from repro.core.schedules import Plan, fednova_local_steps, make_plan
from repro.data.federated import build_federated
from repro.data.partition import budget_law, partition_gamma
from repro.data.synthetic import make_dataset, train_test_split
from repro.models.simple import make_classifier

N = 4


@pytest.fixture(scope="module")
def setup():
    ds = make_dataset("gaussian", n=256, dim=8, n_classes=4, seed=0)
    tr, te = train_test_split(ds)
    parts = partition_gamma(tr, N, gamma=0.5, seed=0)
    fd = build_federated(tr, parts)
    model = make_classifier("mlp", input_shape=(8,), n_classes=4, width=4)
    return model, fd, te


def _run_rounds(model, fd, fed, sel, train, rounds=3):
    state = init_fed_state(jax.random.PRNGKey(fed.seed), model, N)
    rf = make_round_fn(model, fd, fed)
    k_act = jnp.full((N,), fed.local_steps, jnp.int32)
    for _ in range(rounds):
        state = rf(state, jnp.asarray(sel), jnp.asarray(train), k_act)
    return state


def test_cc_with_full_training_equals_fedavg(setup):
    """p_i = 1 for all i ⇒ CC-FedAvg IS FedAvg (paper §III-C)."""
    model, fd, _ = setup
    all_on = np.ones(N, bool)
    s_cc = _run_rounds(model, fd, FedConfig(strategy="cc"), all_on, all_on)
    s_fa = _run_rounds(model, fd, FedConfig(strategy="fedavg"),
                       all_on, all_on)
    for a, b in zip(jax.tree.leaves(s_cc["params"]),
                    jax.tree.leaves(s_fa["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_cc_skip_replays_previous_delta(setup):
    """A skipping client contributes exactly its stored Δ_{t−1} (Strategy 3,
    Alg. 1 line 15)."""
    model, fd, _ = setup
    fed = FedConfig(strategy="cc", local_steps=2)
    state = init_fed_state(jax.random.PRNGKey(0), model, N)
    rf = make_round_fn(model, fd, fed)
    k = jnp.full((N,), fed.local_steps, jnp.int32)
    all_on = jnp.ones(N, bool)
    state = rf(state, all_on, all_on, k)          # round 0: everyone trains
    deltas_before = jax.tree.map(lambda x: x.copy(), state["deltas"])
    train = jnp.asarray([True, False, True, True])
    state2 = rf(state, all_on, train, k)
    # client 1's stored delta must be unchanged (it replayed, not trained)
    for a, b in zip(jax.tree.leaves(deltas_before),
                    jax.tree.leaves(state2["deltas"])):
        np.testing.assert_allclose(np.asarray(a)[1], np.asarray(b)[1],
                                   atol=1e-7)


def test_aggregation_is_unbiased_mean(setup):
    """x_{t+1} − x_t == mean over selected clients of Δ_t^i."""
    model, fd, _ = setup
    fed = FedConfig(strategy="cc", local_steps=1)
    state = init_fed_state(jax.random.PRNGKey(0), model, N)
    rf = make_round_fn(model, fd, fed)
    k = jnp.full((N,), 1, jnp.int32)
    all_on = jnp.ones(N, bool)
    state1 = rf(state, all_on, all_on, k)
    delta_global = jax.tree.map(lambda a, b: a - b,
                                state1["params"], state["params"])
    mean_deltas = jax.tree.map(lambda d: jnp.mean(d, axis=0),
                               state1["deltas"])
    for a, b in zip(jax.tree.leaves(delta_global),
                    jax.tree.leaves(mean_deltas)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_strategy1_ignores_skippers(setup):
    """Strategy 1 aggregates only training clients — a skipping client's
    state must not affect the global model."""
    model, fd, _ = setup
    fed = FedConfig(strategy="s1", local_steps=1)
    state = init_fed_state(jax.random.PRNGKey(0), model, N)
    rf = make_round_fn(model, fd, fed)
    k = jnp.full((N,), 1, jnp.int32)
    all_on = jnp.ones(N, bool)
    # poison client 0's stored delta; s1 must ignore it when 0 skips
    state["deltas"] = jax.tree.map(
        lambda d: d.at[0].set(1e6), state["deltas"])
    train = jnp.asarray([False, True, True, True])
    out = rf(state, all_on, train, k)
    assert bool(jnp.all(jnp.isfinite(
        jnp.concatenate([l.ravel() for l in
                         jax.tree.leaves(out["params"])]))))
    assert float(max(jnp.max(jnp.abs(l))
                     for l in jax.tree.leaves(out["params"]))) < 1e3


def test_s2_uses_stale_model(setup):
    """Strategy 2: a skipping client contributes x_{t−1,K} − x_t (the stale
    model re-expressed as a delta)."""
    model, fd, _ = setup
    fed = FedConfig(strategy="s2", local_steps=1)
    state = init_fed_state(jax.random.PRNGKey(0), model, N)
    rf = make_round_fn(model, fd, fed)
    k = jnp.full((N,), 1, jnp.int32)
    all_on = jnp.ones(N, bool)
    state1 = rf(state, all_on, all_on, k)
    train = jnp.asarray([False, True, True, True])
    state2 = rf(state1, all_on, train, k)
    # reconstruct client 0's contribution: prev_local − x_t
    contrib = jax.tree.map(
        lambda pl, g: pl[0] - g, state1["prev_local"], state1["params"])
    # client 0's delta this round (stored deltas unchanged for skipper in s2,
    # so recompute from aggregation): Δ_t = mean_i Δ_t^i
    trained_deltas = jax.tree.map(
        lambda loc, g: loc - g[None], state2["prev_local"], state1["params"])
    # for trained clients prev_local was updated; verify global update uses
    # contrib for client 0
    delta_global = jax.tree.map(lambda a, b: a - b, state2["params"],
                                state1["params"])
    manual = jax.tree.map(
        lambda c, td: (c + td[1] + td[2] + td[3]) / 4.0,
        contrib, trained_deltas)
    for a, b in zip(jax.tree.leaves(delta_global), jax.tree.leaves(manual)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_fednova_normalized_aggregation(setup):
    """FedNova with uniform k_active reduces to FedAvg's round exactly."""
    model, fd, _ = setup
    all_on = np.ones(N, bool)
    s_nova = _run_rounds(model, fd, FedConfig(strategy="fednova",
                                              local_steps=3),
                         all_on, all_on)
    s_fa = _run_rounds(model, fd, FedConfig(strategy="fedavg",
                                            local_steps=3),
                       all_on, all_on)
    for a, b in zip(jax.tree.leaves(s_nova["params"]),
                    jax.tree.leaves(s_fa["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_fednova_local_steps_scale_with_budget():
    p = np.array([1.0, 0.5, 0.25, 0.125])
    k = fednova_local_steps(p, 8)
    assert list(k) == [8, 4, 2, 1]


def test_fednova_local_steps_validates_inputs():
    """Same contract as make_plan: budgets in (0, 1] (NaN rejected), at
    least one full local step."""
    with pytest.raises(ValueError, match="budgets"):
        fednova_local_steps(np.array([0.0, 0.5]), 8)
    with pytest.raises(ValueError, match="budgets"):
        fednova_local_steps(np.array([1.5]), 8)
    with pytest.raises(ValueError, match="budgets"):
        fednova_local_steps(np.array([np.nan]), 8)
    with pytest.raises(ValueError, match="1-D"):
        fednova_local_steps(np.array([]), 8)
    with pytest.raises(ValueError, match="k_full"):
        fednova_local_steps(np.array([0.5]), 0)
    with pytest.raises(ValueError, match="k_full"):
        fednova_local_steps(np.array([0.5]), -3)


@pytest.mark.slow
def test_end_to_end_cc_learns(setup):
    model, fd, te = setup
    p = budget_law(N, beta=2)
    plan = make_plan("adhoc", p, 30, seed=1)
    fed = FedConfig(strategy="cc", local_steps=3, batch_size=16, lr=0.1)
    _, metrics = run_federated(model, fd, fed, plan,
                               x_test=jnp.asarray(te.x),
                               y_test=jnp.asarray(te.y), eval_every=30)
    assert metrics.last("test_acc") > 0.4   # well above 0.25 chance


# ---------------------------------------------------------------------------
# plans (hypothesis property tests)
# ---------------------------------------------------------------------------


@given(w=st.integers(1, 8), t=st.integers(8, 64), seed=st.integers(0, 99))
@settings(max_examples=25, deadline=None)
def test_round_robin_budget_exact(w, t, seed):
    """Round-robin: a p=1/W client trains exactly ⌈/⌉ once per W selected
    rounds (§VI-A 'round-robin' schedule)."""
    p = np.array([1.0 / w])
    plan = make_plan("round_robin", p, t, seed=seed)
    trained = int(plan.training[:, 0].sum())
    assert abs(trained - t / w) <= 1.0 + t % w / w


@given(pi=st.floats(0.05, 1.0), seed=st.integers(0, 20))
@settings(max_examples=25, deadline=None)
def test_adhoc_budget_in_expectation(pi, seed):
    t = 400
    plan = make_plan("adhoc", np.array([pi]), t, seed=seed)
    frac = plan.training[:, 0].mean()
    assert abs(frac - pi) < 0.12      # 4σ for t=400


@given(pi=st.floats(0.1, 1.0), t=st.integers(10, 100),
       seed=st.integers(0, 20))
@settings(max_examples=25, deadline=None)
def test_dropout_quota_never_exceeded(pi, t, seed):
    plan = make_plan("dropout", np.array([pi, 1.0]), t, seed=seed)
    quota = max(1, round(pi * t))
    assert plan.training[:, 0].sum() <= quota
    # dropout clients leave selection after exhausting quota
    assert (plan.selection == plan.training).all()


@given(ratio=st.floats(0.1, 1.0), seed=st.integers(0, 10))
@settings(max_examples=20, deadline=None)
def test_server_selection_count(ratio, seed):
    n, t = 20, 50
    plan = make_plan("full", np.ones(n), t, participation_ratio=ratio,
                     seed=seed)
    k = max(1, round(ratio * n))
    assert (plan.selection.sum(axis=1) == k).all()


def test_plan_compute_fraction():
    p = np.array([1.0, 0.5])
    plan = make_plan("round_robin", p, 100, seed=0)
    frac = plan.compute_fraction()
    assert 0.7 <= frac <= 0.8          # (1 + 0.5)/2


ALL_KINDS = ("round_robin", "adhoc", "sync", "dropout", "full")


@pytest.mark.parametrize("kind", ALL_KINDS)
@pytest.mark.parametrize("seed", [0, 1, 7])
def test_plan_invariants_all_kinds(kind, seed):
    """Every schedule kind: training ⊆ selection, shapes match, and
    compute_fraction stays within [0, 1]."""
    p = np.array([1.0, 0.5, 0.25, 0.125, 1.0])
    plan = make_plan(kind, p, 40, seed=seed)
    assert plan.selection.shape == plan.training.shape == (40, 5)
    assert not (plan.training & ~plan.selection).any()
    assert 0.0 <= plan.compute_fraction() <= 1.0


@pytest.mark.parametrize("kind", ALL_KINDS)
@pytest.mark.parametrize("seed", range(8))
def test_full_budget_clients_always_train_when_selected(kind, seed):
    """Regression for the round-robin offsets draw: p_i = 1 ⇒ W_i = 1 ⇒ the
    only reachable offset is 0, so a full-budget client must train on EVERY
    selected round under every schedule kind (an inclusive-endpoint offset
    draw would break this)."""
    p = np.array([1.0, 0.25, 1.0])
    plan = make_plan(kind, p, 60, participation_ratio=0.67, seed=seed)
    for i in (0, 2):
        np.testing.assert_array_equal(plan.training[:, i],
                                      plan.selection[:, i])


@pytest.mark.parametrize("seed", range(8))
def test_round_robin_every_client_eventually_trains(seed):
    """With full selection, any client whose window W_i fits in the horizon
    trains at least once (offsets live in [0, W_i), never beyond)."""
    p = np.array([1.0, 0.5, 0.25, 0.2])
    t = 8   # >= max W_i = 5
    plan = make_plan("round_robin", p, t, seed=seed)
    assert (plan.training.sum(axis=0) >= 1).all()


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_plan_compute_fraction_tracks_budget(kind):
    """compute_fraction bounds per schedule semantics: ≈ mean budget for the
    budget-tracking kinds, exactly 1 when training == selection (full;
    dropout after quota-exhausted clients leave selection too), and ≤ mean
    budget for sync (everyone throttled to the slowest window)."""
    p = np.array([1.0, 0.5, 0.5, 0.25])
    plan = make_plan(kind, p, 400, seed=3)
    frac = plan.compute_fraction()
    if kind in ("full", "dropout"):
        assert frac == 1.0
    elif kind == "sync":
        assert 0.0 < frac <= p.mean() + 1e-9
    else:
        assert abs(frac - p.mean()) < 0.12


# ---------------------------------------------------------------------------
# vectorized plans == seed-era per-round loops (bit-for-bit, across seeds)
# ---------------------------------------------------------------------------


def _loop_server_selection(rng, t_rounds, n, ratio):
    """Per-round loop formulation of ``server_selection``: one uniform row
    per round, k smallest selected. ``Generator.random((T, N))`` fills
    row-major, so the loop consumes the identical stream. (This pins the
    vectorization against its own loop form; the seed-era ``rng.choice``
    loop drew a different stream — see the ``server_selection`` note.)"""
    if ratio >= 1.0:
        return np.ones((t_rounds, n), bool)
    k = max(1, int(round(ratio * n)))
    sel = np.zeros((t_rounds, n), bool)
    for t in range(t_rounds):
        u = rng.random(n)
        kth = np.partition(u, k - 1)[k - 1]
        sel[t] = u <= kth
    return sel


def _loop_round_robin(sel, w, offsets):
    """Seed-era counter loop (verbatim pre-vectorization logic)."""
    t_rounds, n = sel.shape
    train = np.zeros((t_rounds, n), bool)
    counters = np.zeros(n, int)
    for t in range(t_rounds):
        due = (counters % w) == offsets
        train[t] = sel[t] & due
        counters += sel[t].astype(int)
    return train


def _loop_dropout(sel, quota):
    """Seed-era quota loop (verbatim pre-vectorization logic)."""
    t_rounds, n = sel.shape
    used = np.zeros(n, int)
    train = np.zeros((t_rounds, n), bool)
    for t in range(t_rounds):
        active = used < quota
        train[t] = sel[t] & active
        used += train[t].astype(int)
    return train


@pytest.mark.parametrize("seed", range(12))
@pytest.mark.parametrize("ratio", [0.3, 0.5, 0.9])
def test_vectorized_server_selection_equals_loop(seed, ratio):
    from repro.core.schedules import server_selection
    n, t = 17, 40
    vec = server_selection(np.random.default_rng(seed), t, n, ratio)
    loop = _loop_server_selection(np.random.default_rng(seed), t, n, ratio)
    np.testing.assert_array_equal(vec, loop)
    k = max(1, round(ratio * n))
    assert (vec.sum(axis=1) == k).all()


@pytest.mark.parametrize("seed", range(12))
@pytest.mark.parametrize("ratio", [1.0, 0.6])
def test_vectorized_round_robin_equals_loop(seed, ratio):
    """The cumulative-sum formulation reproduces the per-round counter loop
    exactly — same selection, same offsets draw, same training bits."""
    from repro.core.schedules import _w_of, server_selection
    p = np.array([1.0, 0.5, 0.25, 0.2, 0.125])
    t = 50
    plan = make_plan("round_robin", p, t, participation_ratio=ratio,
                     seed=seed)
    # replay the rng consumption order of make_plan: selection, then offsets
    rng = np.random.default_rng(seed)
    sel = server_selection(rng, t, len(p), ratio)
    w = _w_of(p)
    offsets = rng.integers(0, w)
    np.testing.assert_array_equal(plan.training,
                                  _loop_round_robin(sel, w, offsets))
    np.testing.assert_array_equal(plan.selection, sel)


@pytest.mark.parametrize("seed", range(12))
@pytest.mark.parametrize("ratio", [1.0, 0.6])
def test_vectorized_dropout_equals_loop(seed, ratio):
    from repro.core.schedules import server_selection
    p = np.array([1.0, 0.5, 0.25, 0.07])
    t = 60
    plan = make_plan("dropout", p, t, participation_ratio=ratio, seed=seed)
    rng = np.random.default_rng(seed)
    sel = server_selection(rng, t, len(p), ratio)
    quota = np.maximum(1, np.round(p * t)).astype(int)
    train = _loop_dropout(sel, quota)
    np.testing.assert_array_equal(plan.training, train)
    # dropout: exhausted clients leave selection too
    np.testing.assert_array_equal(plan.selection, train)


def test_compute_fraction_per_client_breakdown():
    p = np.array([1.0, 0.5, 0.25])
    plan = make_plan("round_robin", p, 200, seed=0)
    per_client = plan.compute_fraction(per_client=True)
    assert per_client.shape == (3,)
    np.testing.assert_allclose(per_client, p, atol=0.05)
    # the scalar is the selection-weighted aggregate of the breakdown
    total = plan.compute_fraction()
    sel_counts = plan.selection.sum(axis=0)
    np.testing.assert_allclose(
        total, (per_client * sel_counts).sum() / sel_counts.sum())


def test_cost_report_carries_per_client_breakdown():
    p = np.array([1.0, 0.25])
    plan = make_plan("round_robin", p, 100, seed=1)
    rep = cost_report(plan, 1000)
    np.testing.assert_allclose(rep["compute_frac_per_client"],
                               plan.compute_fraction(per_client=True))


def test_make_plan_validates_inputs():
    with pytest.raises(ValueError):
        make_plan("round_robin", np.array([0.0, 0.5]), 10)
    with pytest.raises(ValueError):
        make_plan("round_robin", np.array([np.nan, 0.5]), 10)
    with pytest.raises(ValueError):
        make_plan("round_robin", np.array([1.5]), 10)
    with pytest.raises(ValueError):
        make_plan("round_robin", np.array([0.5]), 0)
    with pytest.raises(ValueError):
        make_plan("no_such_kind", np.array([0.5]), 10)


# ---------------------------------------------------------------------------
# Appendix-A variants: storage/communication accounting
# ---------------------------------------------------------------------------


def test_cost_report_variants():
    p = np.array([1.0, 0.5, 0.25, 0.125])
    plan = make_plan("round_robin", p, 80, seed=0)
    mb = 1000
    client = cost_report(plan, mb, variant="client")
    server = cost_report(plan, mb, variant="server")
    mixed = cost_report(plan, mb, variant="mixed")
    # Alg.2 uploads strictly less than Alg.1 (skip = 1 bit not a model)
    assert server["upload_bytes"] < client["upload_bytes"]
    assert client["server_storage_bytes"] == 0
    assert server["client_storage_bytes"] == 0
    assert server["server_storage_bytes"] == 4 * mb
    assert client["upload_bytes"] >= mixed["upload_bytes"] \
        >= server["upload_bytes"]
    # compute saved matches the plan
    assert abs(client["compute_saved_frac"]
               - (1 - plan.compute_fraction())) < 1e-9


def test_cc_round_client_permutation_invariance(setup):
    """Aggregation (Eq. 3) is a mean — permuting clients (data, masks,
    per-client state) must leave the global model unchanged."""
    model, fd, _ = setup
    from repro.data.federated import FederatedData
    fed = FedConfig(strategy="cc", local_steps=1)
    state = init_fed_state(jax.random.PRNGKey(0), model, N)
    rf = make_round_fn(model, fd, fed)
    k = jnp.full((N,), 1, jnp.int32)
    all_on = jnp.ones(N, bool)
    state = rf(state, all_on, all_on, k)           # warm: deltas filled
    train = jnp.asarray([True, False, True, False])

    perm = jnp.asarray([2, 0, 3, 1])
    fd_p = FederatedData(fd.x[perm], fd.y[perm], fd.sizes[perm],
                         fd.n_classes)
    state_p = {
        "params": state["params"],
        "deltas": jax.tree.map(lambda d: d[perm], state["deltas"]),
        "prev_local": jax.tree.map(lambda d: d[perm], state["prev_local"]),
        "trained_ever": state["trained_ever"][perm],
        "round": state["round"],
        "key": state["key"],
    }
    rf_p = make_round_fn(model, fd_p, fed)
    out = rf(state, all_on, train, k)
    out_p = rf_p(state_p, all_on, train[perm], k)
    # training uses per-client RNG streams, so compare the DETERMINISTIC
    # part: the estimated contributions of the skipping clients
    # original skippers {1, 3} sit at permuted positions {3, 2}
    est = jax.tree.map(lambda d: d[jnp.asarray([1, 3])], out["deltas"])
    est_p = jax.tree.map(lambda d: d[jnp.asarray([3, 2])],
                         out_p["deltas"])
    for a, b in zip(jax.tree.leaves(est), jax.tree.leaves(est_p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_strategy3_delta_constant_across_consecutive_skips(setup):
    """Paper §III-C: consecutive skips give Δ_t = Δ_{t−1} = Δ_{t−2} = …"""
    model, fd, _ = setup
    fed = FedConfig(strategy="cc", local_steps=1)
    state = init_fed_state(jax.random.PRNGKey(0), model, N)
    rf = make_round_fn(model, fd, fed)
    k = jnp.full((N,), 1, jnp.int32)
    all_on = jnp.ones(N, bool)
    state = rf(state, all_on, all_on, k)
    d0 = jax.tree.map(lambda d: np.asarray(d[0]), state["deltas"])
    skip0 = jnp.asarray([False, True, True, True])
    for _ in range(3):
        state = rf(state, all_on, skip0, k)
        for a, b in zip(jax.tree.leaves(d0),
                        jax.tree.leaves(state["deltas"])):
            np.testing.assert_allclose(a, np.asarray(b)[0], atol=1e-7)


@given(w=st.integers(2, 6), seed=st.integers(0, 30))
@settings(max_examples=20, deadline=None)
def test_round_robin_trains_once_per_window(w, seed):
    """Stronger than budget counts: in EVERY window of W consecutive
    selected rounds, a p=1/W round-robin client trains exactly once."""
    plan = make_plan("round_robin", np.array([1.0 / w]), 12 * w, seed=seed)
    t = plan.training[:, 0].astype(int)
    for start in range(0, len(t) - w, w):
        assert t[start:start + w].sum() == 1
