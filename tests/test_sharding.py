"""Sharding layer: logical-axis assignment, divisibility fallback, rule
coverage over real model parameter trees, and a 1-device end-to-end jit."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config
from repro.models import decoder
from repro.sharding.api import ShardingContext, constrain, use_sharding
from repro.sharding.rules import (cache_logical_axes, make_rules,
                                  param_logical_axes, params_pspecs)
from repro.utils.pytree import tree_map_with_path


@pytest.fixture(scope="module")
def host_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _ctx(mesh, mode="train", **kw):
    return ShardingContext(mesh=mesh,
                           rules=make_rules(multi_pod=False, mode=mode, **kw))


def test_spec_divisibility_fallback(host_mesh):
    ctx = ShardingContext(
        mesh=host_mesh,
        rules={"a": ["model"], "b": [("data", "model"), "data"]})
    # everything divides on a 1×1 mesh
    assert ctx.spec(("a", None), (8, 3)) == P("model", None)


def test_spec_skips_nondivisible():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = ShardingContext(mesh=mesh, rules={"x": ["model"]})
    # 1-way axis divides everything; now simulate 16-way via fake rule check
    spec = ctx.spec(("x",), (5,))
    assert spec == P("model")      # 5 % 1 == 0


def test_spec_never_reuses_mesh_axis(host_mesh):
    ctx = ShardingContext(mesh=host_mesh,
                          rules={"r": ["model"], "s": ["model", "data"]})
    spec = ctx.spec(("r", "s"), (4, 4))
    assert spec == P("model", "data")   # s falls to data: model taken


def test_param_logical_axes_known_names():
    leaf2 = jnp.zeros((8, 4))
    assert param_logical_axes("blocks/mixer/wq", leaf2) == \
        ("embed", "heads_flat")
    leaf3 = jnp.zeros((2, 8, 4))      # layer-stacked
    assert param_logical_axes("segments/0/mixer/wq", leaf3) == \
        (None, "embed", "heads_flat")
    moe = jnp.zeros((4, 8, 16))
    assert param_logical_axes("ffn/w_gate", moe) == \
        ("experts", "embed", "expert_ffn")
    shared = jnp.zeros((8, 16))
    assert param_logical_axes("ffn/shared/w_gate", shared) == \
        ("embed", "ffn")


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_rule_coverage_all_big_params_shardable(arch, rng, host_mesh):
    """Every ≥2-D parameter leaf of every architecture must map to at least
    one sharded logical axis — unmapped big tensors would silently
    replicate on the production mesh."""
    cfg = get_config(arch, reduced=True)
    params = decoder.model_init(rng, cfg)

    problems = []
    small = ("scale", "bias", "lam", "b_a", "b_x", "b_if", "b_in", "conv_b",
             "conv_w", "r")

    def check(path, leaf):
        name = path.split("/")[-1]
        if leaf.ndim >= 2 and leaf.size >= 4096 and name not in small:
            axes = param_logical_axes(path, leaf)
            if all(a is None for a in axes):
                problems.append((path, leaf.shape))
        return leaf

    tree_map_with_path(check, params)
    assert not problems, f"unsharded params: {problems}"


def test_cache_logical_axes():
    k = jnp.zeros((2, 128, 4, 32))
    assert cache_logical_axes("caches/k", k) == \
        (None, ) * 0 + ("batch", None, "kv_heads", "kv_head_dim")
    ckv = jnp.zeros((2, 128, 32))
    assert cache_logical_axes("c/ckv", ckv) == ("batch", None, "kv_lora")
    stacked = jnp.zeros((4, 2, 128, 4, 32))   # layer-stacked
    axes = cache_logical_axes("k", stacked)
    assert axes[0] is None and axes[1] == "batch"


def test_constrain_is_identity_without_context(rng):
    x = jax.random.normal(rng, (4, 4))
    np.testing.assert_array_equal(np.asarray(constrain(x, (None, None))),
                                  np.asarray(x))


def test_constrain_rank_mismatch_raises(host_mesh):
    ctx = _ctx(host_mesh)
    with use_sharding(ctx):
        with pytest.raises(ValueError):
            constrain(jnp.zeros((2, 2)), ("batch",))


def test_train_step_jits_under_mesh(host_mesh, rng):
    """End-to-end: the sharded code path (with constrains active) runs on
    a 1-device mesh and matches the unsharded result."""
    from repro.models.steps import init_train_state, make_train_step
    from repro.optim.optimizers import sgd
    from repro.optim.schedules import constant_lr

    cfg = get_config("qwen3-1.7b", reduced=True)
    opt = sgd()
    state = init_train_state(rng, cfg, opt)
    batch = {"tokens": jax.random.randint(rng, (2, 16), 0, cfg.vocab)}
    step = make_train_step(cfg, opt, constant_lr(0.01))
    plain_state, plain_metrics = jax.jit(step)(state, batch)
    ctx = _ctx(host_mesh)
    with host_mesh, use_sharding(ctx):
        sh_state, sh_metrics = jax.jit(step)(state, batch)
    assert float(plain_metrics["loss"]) == pytest.approx(
        float(sh_metrics["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(plain_state["params"]),
                    jax.tree.leaves(sh_state["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_params_pspecs_builds_for_all_archs(host_mesh, rng):
    ctx = _ctx(host_mesh)
    for arch in ("olmoe-1b-7b", "recurrentgemma-9b", "xlstm-125m"):
        cfg = get_config(arch, reduced=True)
        params = decoder.model_init(rng, cfg)
        specs = params_pspecs(ctx, params)
        assert len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(
            x, P))) == len(jax.tree.leaves(params))
