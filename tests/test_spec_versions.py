"""Spec schema-version compatibility pins.

Every committed ``examples/specs/vN.json`` must keep loading after the
v6 bump — old spec files are a public surface — and ``from_dict`` must
reject version/field mismatches with the precise "introduced in spec vY"
message instead of an opaque constructor TypeError.
"""
import glob
import json
import os

import pytest

from repro.api.spec import SPEC_VERSION, _FIELD_INTRO, ExperimentSpec

_SPEC_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "examples", "specs")


def _example_paths():
    paths = sorted(glob.glob(os.path.join(_SPEC_DIR, "v*.json")))
    assert len(paths) >= 6, f"missing committed example specs in {_SPEC_DIR}"
    return paths


@pytest.mark.parametrize("path", _example_paths(),
                         ids=[os.path.basename(p) for p in _example_paths()])
def test_committed_example_specs_round_trip(path):
    """Load → to_dict → from_dict is a fixed point for every committed
    version example (v1 through the current version)."""
    with open(path) as f:
        d = json.load(f)
    spec = ExperimentSpec.from_dict(d)
    again = ExperimentSpec.from_dict(spec.to_dict())
    assert again == spec
    # declared fields survive the round trip at their file values
    for k, v in d.items():
        if k == "spec_version":
            continue
        got = getattr(spec, k)
        got = list(got) if isinstance(got, tuple) else got
        assert got == v, f"{os.path.basename(path)}:{k}"


def test_from_dict_rejects_future_versions():
    with pytest.raises(ValueError, match="newer than supported"):
        ExperimentSpec.from_dict({"spec_version": SPEC_VERSION + 1})
    # unknown fields riding a future version are named in the error
    with pytest.raises(ValueError, match="warp_factor"):
        ExperimentSpec.from_dict({"spec_version": SPEC_VERSION + 1,
                                  "warp_factor": 9})


def test_from_dict_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown spec fields"):
        ExperimentSpec.from_dict({"no_such_field": 1})


def test_from_dict_names_the_introducing_version():
    """A non-default v6 field in a spec declaring an older version gets
    the 'introduced in spec v6' message."""
    with pytest.raises(ValueError,
                       match="'channel' was introduced in spec v6"):
        ExperimentSpec.from_dict({"spec_version": 5, "channel": "aircomp"})
    with pytest.raises(ValueError,
                       match="'async_buffer' was introduced in spec v5"):
        ExperimentSpec.from_dict({"spec_version": 4, "executor": "async",
                                  "async_buffer": 2})


def test_from_dict_tolerates_late_fields_at_defaults():
    """A newer writer's round-trip (all fields present, defaults intact)
    loads under an older declared version — default == absent."""
    d = ExperimentSpec().to_dict()
    d["spec_version"] = 1
    assert ExperimentSpec.from_dict(d) == ExperimentSpec()


def test_field_intro_covers_exactly_the_post_v1_fields():
    """Every field the map names exists on the dataclass, and the map's
    version range is [2, SPEC_VERSION]."""
    import dataclasses
    names = {f.name for f in dataclasses.fields(ExperimentSpec)}
    assert set(_FIELD_INTRO) <= names
    assert min(_FIELD_INTRO.values()) == 2
    assert max(_FIELD_INTRO.values()) == SPEC_VERSION
