"""Strategy registry: dispatch, round-trip, cc_decay semantics, and the
Appendix-A cost-report variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import (FedConfig, STRATEGIES, cost_report,
                               init_fed_state, make_round_fn)
from repro.core.schedules import make_plan
from repro.core.strategies import (CCDecay, Strategy, available_strategies,
                                   get_strategy, register)
from repro.data.federated import build_federated
from repro.data.partition import partition_gamma
from repro.data.synthetic import make_dataset, train_test_split
from repro.models.simple import make_classifier

N = 4


@pytest.fixture(scope="module")
def setup():
    ds = make_dataset("gaussian", n=256, dim=8, n_classes=4, seed=0)
    tr, _ = train_test_split(ds)
    parts = partition_gamma(tr, N, gamma=0.5, seed=0)
    fd = build_federated(tr, parts)
    model = make_classifier("mlp", input_shape=(8,), n_classes=4, width=4)
    return model, fd


# ---------------------------------------------------------------------------
# registry dispatch
# ---------------------------------------------------------------------------


def test_unknown_strategy_raises():
    with pytest.raises(ValueError, match="unknown strategy"):
        get_strategy("definitely_not_registered")
    with pytest.raises(ValueError, match="unknown strategy"):
        FedConfig(strategy="definitely_not_registered")


def test_all_registered_names_round_trip():
    names = available_strategies()
    assert len(names) >= 8            # paper's seven + cc_decay
    for name in names:
        s = get_strategy(name)
        assert s.name == name
        # every registered name must build a valid config
        assert FedConfig(strategy=name).strategy == name


def test_paper_names_present():
    for name in ("fedavg", "dropout", "s1", "s2", "cc", "ccc", "fednova",
                 "cc_decay"):
        assert name in available_strategies()
    # back-compat module constant mirrors the registry
    assert set(STRATEGIES) == set(available_strategies())


def test_register_requires_name_and_allows_plugins():
    with pytest.raises(ValueError):
        register(Strategy(name=""))
    probe = CCDecay(name="_test_probe_gamma_half", gamma=0.5)
    try:
        register(probe)
        assert get_strategy("_test_probe_gamma_half") is probe
        assert FedConfig(strategy="_test_probe_gamma_half").resolve() is probe
    finally:
        from repro.core import strategies as S
        S._REGISTRY.pop("_test_probe_gamma_half", None)


def test_fused_capability_flags():
    assert get_strategy("cc").fused_capable
    for name in ("s1", "s2", "ccc", "fednova", "cc_decay"):
        assert not get_strategy(name).fused_capable


# ---------------------------------------------------------------------------
# cc_decay semantics: γ·Δ replay with geometric fade over consecutive skips
# ---------------------------------------------------------------------------


def test_cc_decay_skipper_contributes_decayed_delta(setup):
    model, fd = setup
    gamma = get_strategy("cc_decay").gamma
    fed = FedConfig(strategy="cc_decay", local_steps=1)
    state = init_fed_state(jax.random.PRNGKey(0), model, N)
    rf = make_round_fn(model, fd, fed)
    k = jnp.full((N,), 1, jnp.int32)
    all_on = jnp.ones(N, bool)
    state = rf(state, all_on, all_on, k)        # round 0: everyone trains
    d0 = jax.tree.map(lambda d: np.asarray(d[0]), state["deltas"])
    skip0 = jnp.asarray([False, True, True, True])
    for step in range(1, 4):
        state = rf(state, all_on, skip0, k)
        for a, b in zip(jax.tree.leaves(d0),
                        jax.tree.leaves(state["deltas"])):
            np.testing.assert_allclose(gamma ** step * a, np.asarray(b)[0],
                                       atol=1e-6)


def test_cc_decay_gamma_one_matches_cc(setup):
    model, fd = setup
    probe = CCDecay(name="_test_gamma_one", gamma=1.0)
    from repro.core import strategies as S
    register(probe)
    try:
        k = jnp.full((N,), 1, jnp.int32)
        all_on = jnp.ones(N, bool)
        train = jnp.asarray([True, False, True, False])
        outs = {}
        for name in ("cc", "_test_gamma_one"):
            fed = FedConfig(strategy=name, local_steps=1)
            state = init_fed_state(jax.random.PRNGKey(0), model, N)
            rf = make_round_fn(model, fd, fed)
            state = rf(state, all_on, all_on, k)
            state = rf(state, all_on, train, k)
            outs[name] = state
        for a, b in zip(jax.tree.leaves(outs["cc"]["params"]),
                        jax.tree.leaves(outs["_test_gamma_one"]["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)
    finally:
        S._REGISTRY.pop("_test_gamma_one", None)


# ---------------------------------------------------------------------------
# Appendix-A cost accounting
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def plan():
    p = np.array([1.0, 0.5, 0.25, 0.125])
    return make_plan("round_robin", p, 80, seed=0)


def test_cost_report_client_variant(plan):
    mb = 1000
    rep = cost_report(plan, mb, variant="client")
    trained = (plan.selection & plan.training).sum()
    estimated = (plan.selection & ~plan.training).sum()
    # Alg. 1: every selected client uploads a full model either way
    assert rep["upload_bytes"] == (trained + estimated) * mb
    assert rep["client_storage_bytes"] == mb
    assert rep["server_storage_bytes"] == 0
    assert rep["compute_saved_frac"] == pytest.approx(
        1.0 - plan.compute_fraction())


def test_cost_report_server_variant(plan):
    mb = 1000
    rep = cost_report(plan, mb, variant="server")
    trained = (plan.selection & plan.training).sum()
    estimated = (plan.selection & ~plan.training).sum()
    # Alg. 2: skippers send one bit; the server stores every client's Δ
    assert rep["upload_bytes"] == trained * mb + estimated // 8 + 1
    assert rep["client_storage_bytes"] == 0
    assert rep["server_storage_bytes"] == plan.n_clients * mb


@pytest.mark.parametrize("frac", [0.0, 0.5, 1.0])
def test_cost_report_mixed_interpolates(plan, frac):
    mb = 1000
    mixed = cost_report(plan, mb, variant="mixed", mixed_client_frac=frac)
    client = cost_report(plan, mb, variant="client")
    server = cost_report(plan, mb, variant="server")
    assert server["upload_bytes"] <= mixed["upload_bytes"] + 1
    assert mixed["upload_bytes"] <= client["upload_bytes"]
    # server-side storage shrinks as more clients hold their own Δ
    assert mixed["server_storage_bytes"] == int(
        (1 - frac) * plan.n_clients * mb)


def test_cost_report_unknown_variant_raises(plan):
    with pytest.raises(ValueError):
        cost_report(plan, 1000, variant="nonsense")
