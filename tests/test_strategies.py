"""Strategy registry: dispatch, round-trip, cc_decay semantics, the
Appendix-A cost-report variants, and property-based hook invariants
(replayed deterministically through the hypothesis shim when the real
package is absent)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.engine import (FedConfig, STRATEGIES, cost_report,
                               init_fed_state, make_round_fn)
from repro.core.schedules import make_plan
from repro.core.strategies import (CCDecay, RoundCtx, Strategy,
                                   available_strategies, get_strategy,
                                   register)
from repro.data.federated import build_federated
from repro.data.partition import partition_gamma
from repro.data.synthetic import make_dataset, train_test_split
from repro.models.simple import make_classifier

N = 4


@pytest.fixture(scope="module")
def setup():
    ds = make_dataset("gaussian", n=256, dim=8, n_classes=4, seed=0)
    tr, _ = train_test_split(ds)
    parts = partition_gamma(tr, N, gamma=0.5, seed=0)
    fd = build_federated(tr, parts)
    model = make_classifier("mlp", input_shape=(8,), n_classes=4, width=4)
    return model, fd


# ---------------------------------------------------------------------------
# registry dispatch
# ---------------------------------------------------------------------------


def test_unknown_strategy_raises():
    with pytest.raises(ValueError, match="unknown strategy"):
        get_strategy("definitely_not_registered")
    with pytest.raises(ValueError, match="unknown strategy"):
        FedConfig(strategy="definitely_not_registered")


def test_all_registered_names_round_trip():
    names = available_strategies()
    assert len(names) >= 8            # paper's seven + cc_decay
    for name in names:
        s = get_strategy(name)
        assert s.name == name
        # every registered name must build a valid config
        assert FedConfig(strategy=name).strategy == name


def test_paper_names_present():
    for name in ("fedavg", "dropout", "s1", "s2", "cc", "ccc", "fednova",
                 "cc_decay"):
        assert name in available_strategies()
    # back-compat module constant mirrors the registry
    assert set(STRATEGIES) == set(available_strategies())


def test_register_requires_name_and_allows_plugins():
    with pytest.raises(ValueError):
        register(Strategy(name=""))
    probe = CCDecay(name="_test_probe_gamma_half", gamma=0.5)
    try:
        register(probe)
        assert get_strategy("_test_probe_gamma_half") is probe
        assert FedConfig(strategy="_test_probe_gamma_half").resolve() is probe
    finally:
        from repro.core import strategies as S
        S._REGISTRY.pop("_test_probe_gamma_half", None)


def test_fused_capability_flags():
    """Every built-in strategy ships a ``FusedEpilogue``; only the bare
    ``Strategy`` base (custom registrations) defaults to non-capable."""
    from repro.core.strategies import Strategy, available_strategies

    for name in available_strategies():
        s = get_strategy(name)
        assert s.fused_capable, name
        assert s.needs_stale == (name in ("s2", "ccc")), name
    assert not Strategy(name="_probe").fused_capable


# ---------------------------------------------------------------------------
# cc_decay semantics: γ·Δ replay with geometric fade over consecutive skips
# ---------------------------------------------------------------------------


def test_cc_decay_skipper_contributes_decayed_delta(setup):
    model, fd = setup
    gamma = get_strategy("cc_decay").gamma
    fed = FedConfig(strategy="cc_decay", local_steps=1)
    state = init_fed_state(jax.random.PRNGKey(0), model, N)
    rf = make_round_fn(model, fd, fed)
    k = jnp.full((N,), 1, jnp.int32)
    all_on = jnp.ones(N, bool)
    state = rf(state, all_on, all_on, k)        # round 0: everyone trains
    d0 = jax.tree.map(lambda d: np.asarray(d[0]), state["deltas"])
    skip0 = jnp.asarray([False, True, True, True])
    for step in range(1, 4):
        state = rf(state, all_on, skip0, k)
        for a, b in zip(jax.tree.leaves(d0),
                        jax.tree.leaves(state["deltas"])):
            np.testing.assert_allclose(gamma ** step * a, np.asarray(b)[0],
                                       atol=1e-6)


def test_cc_decay_gamma_one_matches_cc(setup):
    model, fd = setup
    probe = CCDecay(name="_test_gamma_one", gamma=1.0)
    from repro.core import strategies as S
    register(probe)
    try:
        k = jnp.full((N,), 1, jnp.int32)
        all_on = jnp.ones(N, bool)
        train = jnp.asarray([True, False, True, False])
        outs = {}
        for name in ("cc", "_test_gamma_one"):
            fed = FedConfig(strategy=name, local_steps=1)
            state = init_fed_state(jax.random.PRNGKey(0), model, N)
            rf = make_round_fn(model, fd, fed)
            state = rf(state, all_on, all_on, k)
            state = rf(state, all_on, train, k)
            outs[name] = state
        for a, b in zip(jax.tree.leaves(outs["cc"]["params"]),
                        jax.tree.leaves(outs["_test_gamma_one"]["params"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)
    finally:
        S._REGISTRY.pop("_test_gamma_one", None)


# ---------------------------------------------------------------------------
# Appendix-A cost accounting
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def plan():
    p = np.array([1.0, 0.5, 0.25, 0.125])
    return make_plan("round_robin", p, 80, seed=0)


def test_cost_report_client_variant(plan):
    mb = 1000
    rep = cost_report(plan, mb, variant="client")
    trained = (plan.selection & plan.training).sum()
    estimated = (plan.selection & ~plan.training).sum()
    # Alg. 1: every selected client uploads a full model either way
    assert rep["upload_bytes"] == (trained + estimated) * mb
    assert rep["client_storage_bytes"] == mb
    assert rep["server_storage_bytes"] == 0
    assert rep["compute_saved_frac"] == pytest.approx(
        1.0 - plan.compute_fraction())


def test_cost_report_server_variant(plan):
    mb = 1000
    rep = cost_report(plan, mb, variant="server")
    trained = (plan.selection & plan.training).sum()
    estimated = (plan.selection & ~plan.training).sum()
    # Alg. 2: skippers send one bit; the server stores every client's Δ
    assert rep["upload_bytes"] == trained * mb + estimated // 8 + 1
    assert rep["client_storage_bytes"] == 0
    assert rep["server_storage_bytes"] == plan.n_clients * mb


@pytest.mark.parametrize("frac", [0.0, 0.5, 1.0])
def test_cost_report_mixed_interpolates(plan, frac):
    mb = 1000
    mixed = cost_report(plan, mb, variant="mixed", mixed_client_frac=frac)
    client = cost_report(plan, mb, variant="client")
    server = cost_report(plan, mb, variant="server")
    assert server["upload_bytes"] <= mixed["upload_bytes"] + 1
    assert mixed["upload_bytes"] <= client["upload_bytes"]
    # server-side storage shrinks as more clients hold their own Δ
    assert mixed["server_storage_bytes"] == int(
        (1 - frac) * plan.n_clients * mb)


def test_cost_report_unknown_variant_raises(plan):
    with pytest.raises(ValueError):
        cost_report(plan, 1000, variant="nonsense")


# ---------------------------------------------------------------------------
# property-based hook invariants (any strategy, any masks)
# ---------------------------------------------------------------------------


def _tree(n, scale=1.0, seed=0):
    r = np.random.default_rng(seed)
    return {"w": jnp.asarray(scale * r.standard_normal((n, 3)), jnp.float32),
            "b": jnp.asarray(scale * r.standard_normal((n,)), jnp.float32)}


def _ctx(sel, train, k, rnd=1, tau=100):
    n = len(sel)
    return RoundCtx(sel_mask=jnp.asarray(sel, bool),
                    train_mask=jnp.asarray(train, bool),
                    k_active=jnp.asarray(k, jnp.int32),
                    round=jnp.asarray(rnd, jnp.int32), tau=tau,
                    stale_delta=_tree(n, seed=1), trained_delta=_tree(n))


@settings(max_examples=25)
@given(name=st.sampled_from(available_strategies()),
       sel=st.lists(st.booleans(), min_size=N, max_size=N),
       train=st.lists(st.booleans(), min_size=N, max_size=N),
       c=st.floats(min_value=-3.0, max_value=3.0))
def test_aggregation_weights_sum_to_one(name, sel, train, c):
    """Under ANY sel/train mask (uniform step counts), every strategy's
    aggregation is a convex combination: aggregating identical per-client
    deltas returns that delta unchanged — the Eq.-3 weights sum to 1."""
    strategy = get_strategy(name)
    ctx = _ctx(sel, train, [3] * N)
    aggf = strategy.agg_mask(ctx).astype(jnp.float32)
    const = jax.tree.map(lambda x: jnp.full_like(x, c), _tree(N))
    out = strategy.aggregate(const, aggf, ctx)
    # empty rounds aggregate to exactly zero (eps denominator), otherwise
    # the weights are convex and the constant comes back unchanged
    expect = c if bool(aggf.sum() > 0) else 0.0
    for leaf in jax.tree.leaves(out):
        np.testing.assert_allclose(np.asarray(leaf), expect, atol=1e-5)


@settings(max_examples=25)
@given(name=st.sampled_from(available_strategies()),
       sel=st.lists(st.booleans(), min_size=N, max_size=N),
       train=st.lists(st.booleans(), min_size=N, max_size=N),
       stale=st.lists(st.integers(min_value=0, max_value=6),
                      min_size=N, max_size=N),
       decay=st.floats(min_value=0.3, max_value=1.0),
       c=st.floats(min_value=-3.0, max_value=3.0))
def test_merge_stale_weights_stay_convex(name, sel, train, stale, decay, c):
    """The async merge invariant: under ANY buffer mask and ANY staleness
    vector the staleness-decayed weights stay a convex combination —
    merging identical per-client deltas returns that delta unchanged, and
    an empty buffer merges to exactly zero (a no-op update)."""
    from repro.core.async_rounds import staleness_weights
    strategy = get_strategy(name)
    ctx = _ctx(sel, train, [3] * N)
    aggf = strategy.agg_mask(ctx).astype(jnp.float32)
    s = jnp.asarray(stale, jnp.int32)
    w = staleness_weights("geometric", decay, s)
    const = jax.tree.map(lambda x: jnp.full_like(x, c), _tree(N))
    out = strategy.merge_stale(const, aggf, s, w, ctx)
    expect = c if bool((aggf * w).sum() > 0) else 0.0
    for leaf in jax.tree.leaves(out):
        np.testing.assert_allclose(np.asarray(leaf), expect, atol=1e-5)


@pytest.mark.parametrize("schedule", ["geometric", "polynomial"])
@settings(max_examples=25)
@given(name=st.sampled_from(available_strategies()),
       sel=st.lists(st.booleans(), min_size=N, max_size=N),
       train=st.lists(st.booleans(), min_size=N, max_size=N),
       decay=st.floats(min_value=0.1, max_value=1.0))
def test_merge_stale_at_zero_staleness_equals_aggregate(schedule, name,
                                                        sel, train, decay):
    """At staleness 0 every schedule's weight is EXACTLY 1.0, so
    ``merge_stale`` must reproduce ``aggregate`` bit-for-bit for every
    registered strategy — the hook-level statement of the async
    executor's collapse-to-synchronous guarantee."""
    from repro.core.async_rounds import staleness_weights
    strategy = get_strategy(name)
    ctx = _ctx(sel, train, [3] * N)
    aggf = strategy.agg_mask(ctx).astype(jnp.float32)
    zero = jnp.zeros((N,), jnp.int32)
    w = staleness_weights(schedule, decay, zero)
    np.testing.assert_array_equal(np.asarray(w), 1.0)
    delta = _tree(N, seed=2)
    merged = strategy.merge_stale(delta, aggf, zero, w, ctx)
    plain = strategy.aggregate(delta, aggf, ctx)
    for a, b in zip(jax.tree.leaves(merged), jax.tree.leaves(plain)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{name}/{schedule}")


_ALL_TRAIN_PARAMS: dict = {}


def _all_train_round(setup, name):
    if name not in _ALL_TRAIN_PARAMS:
        model, fd = setup
        fed = FedConfig(strategy=name, local_steps=2, batch_size=16, lr=0.1)
        rf = make_round_fn(model, fd, fed)
        state = init_fed_state(jax.random.PRNGKey(0), model, N)
        on = jnp.ones(N, bool)
        state = rf(state, on, on, jnp.full((N,), 2, jnp.int32))
        _ALL_TRAIN_PARAMS[name] = jax.tree.map(np.asarray, state["params"])
    return _ALL_TRAIN_PARAMS[name]


@given(name=st.sampled_from(available_strategies()))
def test_estimation_is_noop_when_all_train(setup, name):
    """When every client really trains, estimates never enter the round:
    all strategies collapse to the same FedAvg update (FedNova included —
    uniform step counts make its normalization cancel exactly)."""
    ref = _all_train_round(setup, "fedavg")
    got = _all_train_round(setup, name)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref)):
        np.testing.assert_allclose(a, b, atol=1e-6, err_msg=name)


@settings(max_examples=25)
@given(name=st.sampled_from(available_strategies()),
       sel=st.lists(st.booleans(), min_size=N, max_size=N),
       train=st.lists(st.booleans(), min_size=N, max_size=N))
def test_update_history_is_mask_idempotent(name, sel, train):
    """Applying ``update_history`` twice with the same masks and round
    inputs is a no-op the second time — history written for a mask pattern
    is stable until the inputs change."""
    strategy = get_strategy(name)
    ctx = _ctx(sel, train, [3] * N)
    trained_delta, local, est = _tree(N, seed=2), _tree(N, seed=3), \
        _tree(N, seed=4)
    state = {"deltas": _tree(N, seed=5), "prev_local": _tree(N, seed=6)}
    d1, p1 = strategy.update_history(state, ctx, trained_delta, local, est)
    d2, p2 = strategy.update_history({"deltas": d1, "prev_local": p1},
                                     ctx, trained_delta, local, est)
    for a, b in zip(jax.tree.leaves((d1, p1)), jax.tree.leaves((d2, p2))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)
