"""Property tests for the per-row int8 quantizer the q8 kernel consumes.

Runs under real hypothesis when installed; otherwise the deterministic
replay shim from ``_hypothesis_compat`` (bounds examples + seeded draws).
All three properties are *analytic* bounds of symmetric quantization, not
empirical tolerances:

* round trip: |x − deq(q(x))| ≤ scale/2 per element (scale = max|row|/127
  ⇒ x/scale ∈ [−127, 127], the clip never bites, round is ≤ 1/2 off);
* zeros are a fixed point: payload 0, the clamp-floor scale, exact
  dequantization;
* masked-mean aggregation: |mean_sel(x) − mean_sel(deq(q(x)))| ≤
  mean_sel(scale_i)/2 — the per-row bounds average.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.compress import dequantize_rows, quantize_rows

_SLACK = 1 + 1e-5          # f32 rounding headroom on the analytic bounds


def _rows(n, p, seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, p))
    return (scale * x).astype(jnp.float32)


@settings(max_examples=12, deadline=None)
@given(n=st.integers(1, 8), p=st.integers(1, 300),
       seed=st.integers(0, 2 ** 16),
       scale=st.floats(min_value=1e-5, max_value=1e3))
def test_round_trip_error_within_half_scale(n, p, seed, scale):
    x = _rows(n, p, seed, scale)
    payload, scales = quantize_rows(x)
    assert payload.dtype == jnp.int8 and scales.shape == (n,)
    back = dequantize_rows(payload, scales)
    err = np.abs(np.asarray(x) - np.asarray(back))
    bound = np.asarray(scales)[:, None] * 0.5 * _SLACK
    assert (err <= bound).all(), float((err - bound).max())


@settings(max_examples=8, deadline=None)
@given(n=st.integers(1, 8), p=st.integers(1, 300))
def test_zeros_are_a_fixed_point(n, p):
    payload, scales = quantize_rows(jnp.zeros((n, p)))
    assert not np.asarray(payload).any()
    assert (np.asarray(scales) > 0).all()      # the 1e-12 clamp floor
    assert not np.asarray(dequantize_rows(payload, scales)).any()
    payload2, scales2 = quantize_rows(dequantize_rows(payload, scales))
    np.testing.assert_array_equal(np.asarray(payload2), np.asarray(payload))
    np.testing.assert_array_equal(np.asarray(scales2), np.asarray(scales))


@settings(max_examples=12, deadline=None)
@given(n=st.integers(1, 8), p=st.integers(1, 300),
       seed=st.integers(0, 2 ** 16),
       scale=st.floats(min_value=1e-5, max_value=1e3),
       mask_seed=st.integers(0, 2 ** 16))
def test_masked_mean_aggregation_within_analytic_bound(n, p, seed, scale,
                                                       mask_seed):
    x = _rows(n, p, seed, scale)
    sel = jax.random.bernoulli(jax.random.PRNGKey(mask_seed), 0.5, (n,))
    sel = sel.at[0].set(True)                  # at least one participant
    payload, scales = quantize_rows(x)
    back = dequantize_rows(payload, scales)
    w = np.asarray(sel, np.float32)
    m = w.sum()
    exact = (w[:, None] * np.asarray(x)).sum(0) / m
    approx = (w[:, None] * np.asarray(back)).sum(0) / m
    bound = (w * np.asarray(scales)).sum() / m * 0.5 * _SLACK
    assert (np.abs(exact - approx) <= bound).all()


# ---------------------------------------------------------------------------
# quantize_tree degenerate-leaf regressions: empty, 0-d and all-zero
# leaves must round-trip (jnp.max over zero elements raises, even jitted)
# ---------------------------------------------------------------------------


def test_quantize_tree_handles_empty_leaves():
    from repro.core.compress import dequantize_tree, quantize_tree
    tree = {"w": jnp.ones((3, 2)), "empty": jnp.zeros((0, 4))}
    q = quantize_tree(tree)
    assert q.payload["empty"].shape == (0, 4)
    assert q.payload["empty"].dtype == jnp.int8
    assert float(q.scales["empty"]) == 1.0
    back = dequantize_tree(q)
    assert back["empty"].shape == (0, 4)
    np.testing.assert_allclose(np.asarray(back["w"]), 1.0, atol=1e-2)

    jq = jax.jit(quantize_tree)(tree)     # the jnp.max guard is static —
    assert jq.payload["empty"].shape == (0, 4)   # safe under jit too


def test_quantize_tree_handles_scalar_and_zero_leaves():
    from repro.core.compress import dequantize_tree, quantize_tree
    tree = {"s": jnp.asarray(0.5), "z": jnp.zeros((4, 4))}
    back = dequantize_tree(quantize_tree(tree))
    np.testing.assert_allclose(float(back["s"]), 0.5, atol=0.5 / 127)
    # all-zero leaves dequantize to EXACT zeros (scale floor never
    # manufactures a payload)
    assert not np.asarray(back["z"]).any()


# ---------------------------------------------------------------------------
# bf16-params round trip (ISSUE 10 satellite): dequantize computes the
# payload·scale product in f32 and rounds ONCE to the param dtype. A
# double-rounding order — (payload * scale) rounded to bf16 per factor, or
# f32→bf16→f32 chains — would exceed the analytic bound below; the single
# extra bf16 rounding adds at most |v|·2⁻⁸ (half an ulp at 8 significand
# bits) on top of the scale/2 quantization error.
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(n=st.integers(1, 8), p=st.integers(1, 300),
       seed=st.integers(0, 2 ** 16),
       scale=st.floats(min_value=1e-5, max_value=1e3))
def test_bf16_round_trip_single_rounding(n, p, seed, scale):
    x = _rows(n, p, seed, scale).astype(jnp.bfloat16)
    payload, scales = quantize_rows(x)        # f32 cast of bf16 is exact
    back = dequantize_rows(payload, scales, dtype=jnp.bfloat16)
    assert back.dtype == jnp.bfloat16
    exact_f32 = np.asarray(payload, np.float32) * np.asarray(scales)[:, None]
    err = np.abs(np.asarray(x, np.float32) - np.asarray(back, np.float32))
    bound = (np.asarray(scales)[:, None] * 0.5
             + np.abs(exact_f32) * 2.0 ** -8) * _SLACK
    assert (err <= bound).all()


def test_bf16_tree_round_trip_single_rounding():
    from repro.core.compress import dequantize_tree, quantize_tree
    tree = {"w": (3.0 * jax.random.normal(jax.random.PRNGKey(0), (16, 16))
                  ).astype(jnp.bfloat16)}
    q = quantize_tree(tree)
    back = dequantize_tree(q, dtype=jnp.bfloat16)
    assert back["w"].dtype == jnp.bfloat16
    s = float(q.scales["w"])
    exact = np.asarray(q.payload["w"], np.float32) * s
    err = np.abs(np.asarray(tree["w"], np.float32)
                 - np.asarray(back["w"], np.float32))
    assert (err <= (s * 0.5 + np.abs(exact) * 2.0 ** -8) * _SLACK).all()
