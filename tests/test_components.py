"""Unit tests: optimizers, schedules, losses, pytree algebra, checkpoint."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.store import CheckpointManager, load_pytree, save_pytree
from repro.models import losses, nn
from repro.optim.optimizers import adamw, make_optimizer, sgd, sgd_momentum
from repro.optim.schedules import (constant_lr, cosine_decay_lr,
                                   warmup_cosine_lr)
from repro.utils import pytree as pt


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def test_sgd_step():
    opt = sgd()
    params = {"w": jnp.ones((3,))}
    grads = {"w": jnp.full((3,), 2.0)}
    new, _ = opt.update(params, grads, opt.init(params), 0.1)
    np.testing.assert_allclose(np.asarray(new["w"]), 0.8)


def test_momentum_accumulates():
    opt = sgd_momentum(beta=0.5)
    params = {"w": jnp.zeros(())}
    g = {"w": jnp.asarray(1.0)}
    s = opt.init(params)
    p1, s = opt.update(params, g, s, 1.0)     # mom=1   -> -1
    p2, s = opt.update(p1, g, s, 1.0)         # mom=1.5 -> -2.5
    assert float(p2["w"]) == pytest.approx(-2.5)


def test_adamw_first_step_is_lr_sized():
    opt = adamw(b1=0.9, b2=0.999, eps=1e-8)
    params = {"w": jnp.zeros((4,))}
    g = {"w": jnp.asarray([1.0, -1.0, 2.0, -0.5])}
    new, _ = opt.update(params, g, opt.init(params), 1e-2)
    # bias-corrected first Adam step = lr · sign(g)
    np.testing.assert_allclose(np.asarray(new["w"]),
                               -1e-2 * np.sign(g["w"]), rtol=1e-4)


def test_make_optimizer_registry():
    assert make_optimizer("sgd").name == "sgd"
    with pytest.raises(ValueError):
        make_optimizer("nope")


def test_schedules():
    assert float(constant_lr(0.1)(1000)) == pytest.approx(0.1)
    cos = cosine_decay_lr(1.0, 100, final_frac=0.1)
    assert float(cos(0)) == pytest.approx(1.0)
    assert float(cos(100)) == pytest.approx(0.1)
    wc = warmup_cosine_lr(1.0, 10, 110)
    assert float(wc(5)) == pytest.approx(0.5)
    assert float(wc(10)) == pytest.approx(1.0, abs=1e-3)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def test_chunked_xent_matches_unchunked(rng):
    b, s, d, v = 2, 32, 16, 50
    hidden = jax.random.normal(rng, (b, s, d))
    head = jax.random.normal(jax.random.fold_in(rng, 1), (d, v))
    targets = jax.random.randint(jax.random.fold_in(rng, 2), (b, s), 0, v)
    mask = jnp.ones((b, s)).at[:, -1].set(0.0)
    l1, a1 = losses.chunked_causal_xent(hidden, targets, mask, head, chunk=8)
    l2, a2 = losses.chunked_causal_xent(hidden, targets, mask, head, chunk=s)
    assert float(l1) == pytest.approx(float(l2), rel=1e-6)
    assert float(a1) == pytest.approx(float(a2), rel=1e-6)


def test_codebook_xent_mean_of_heads(rng):
    b, s, d, v, k = 2, 16, 8, 20, 3
    hidden = jax.random.normal(rng, (b, s, d))
    heads = jax.random.normal(jax.random.fold_in(rng, 1), (k, d, v))
    targets = jax.random.randint(jax.random.fold_in(rng, 2), (b, k, s), 0, v)
    mask = jnp.ones((b, s))
    l, _ = losses.multihead_codebook_xent(hidden, targets, mask, heads,
                                          chunk=8)
    per = [losses.chunked_causal_xent(hidden, targets[:, j], mask, heads[j],
                                      chunk=8)[0] for j in range(k)]
    assert float(l) == pytest.approx(float(np.mean([float(x) for x in per])),
                                     rel=1e-6)


# ---------------------------------------------------------------------------
# nn primitives
# ---------------------------------------------------------------------------


def test_rope_preserves_norm(rng):
    x = jax.random.normal(rng, (2, 8, 4, 32))
    cos, sin = nn.rope_cos_sin(jnp.arange(8)[None, :], 32)
    y = nn.apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)


def test_rope_relative_property(rng):
    """RoPE: ⟨q_m, k_n⟩ depends only on m − n."""
    hd = 16
    q = jax.random.normal(rng, (1, 1, 1, hd))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (1, 1, 1, hd))

    def dot_at(m, n):
        cm, sm = nn.rope_cos_sin(jnp.asarray([[m]]), hd)
        cn, sn = nn.rope_cos_sin(jnp.asarray([[n]]), hd)
        qm = nn.apply_rope(q, cm, sm)
        kn = nn.apply_rope(k, cn, sn)
        return float(jnp.sum(qm * kn))

    assert dot_at(3, 1) == pytest.approx(dot_at(10, 8), rel=1e-4)


def test_mrope_sections_match_plain_rope_when_positions_equal(rng):
    """If all three position rows are identical, M-RoPE == RoPE."""
    hd, s = 32, 8
    x = jax.random.normal(rng, (1, s, 2, hd))
    pos = jnp.arange(s, dtype=jnp.int32)
    pos3 = jnp.broadcast_to(pos, (3, 1, s))
    c1, s1 = nn.mrope_cos_sin(pos3, hd, (6, 5, 5))
    c2, s2 = nn.rope_cos_sin(pos[None], hd)
    y1 = nn.apply_rope(x, c1, s1)
    y2 = nn.apply_rope(x, c2, s2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_rmsnorm_scale_invariance(rng):
    p = nn.rmsnorm_init(16)
    x = jax.random.normal(rng, (4, 16))
    y1 = nn.rmsnorm_apply(p, x)
    y2 = nn.rmsnorm_apply(p, 10.0 * x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


# ---------------------------------------------------------------------------
# pytree algebra (hypothesis)
# ---------------------------------------------------------------------------


@given(st.lists(st.floats(-10, 10, allow_nan=False), min_size=3, max_size=3),
       st.lists(st.floats(-10, 10, allow_nan=False), min_size=3, max_size=3))
@settings(max_examples=20, deadline=None)
def test_tree_vector_space(a_vals, b_vals):
    a = {"x": jnp.asarray(a_vals), "y": {"z": jnp.asarray(a_vals[:2])}}
    b = {"x": jnp.asarray(b_vals), "y": {"z": jnp.asarray(b_vals[:2])}}
    s = pt.tree_add(a, b)
    d = pt.tree_sub(s, b)
    for la, lb in zip(jax.tree.leaves(d), jax.tree.leaves(a)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=1e-5)


@given(mask=st.lists(st.integers(0, 1), min_size=4, max_size=4))
@settings(max_examples=20, deadline=None)
def test_masked_mean_matches_numpy(mask):
    tree = {"w": jnp.arange(8, dtype=jnp.float32).reshape(4, 2)}
    m = jnp.asarray(mask, jnp.float32)
    got = pt.tree_masked_mean(tree, m)["w"]
    if sum(mask) == 0:
        np.testing.assert_allclose(np.asarray(got), 0.0, atol=1e-6)
    else:
        want = (np.arange(8).reshape(4, 2)
                * np.asarray(mask)[:, None]).sum(0) / sum(mask)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)


def test_tree_cosine_bounds(rng):
    a = {"w": jax.random.normal(rng, (16,))}
    assert float(pt.tree_cosine(a, a)) == pytest.approx(1.0, abs=1e-5)
    neg = pt.tree_scale(a, -1.0)
    assert float(pt.tree_cosine(a, neg)) == pytest.approx(-1.0, abs=1e-5)


def test_tree_stack_unstack_roundtrip(rng):
    trees = [{"w": jax.random.normal(jax.random.fold_in(rng, i), (3,))}
             for i in range(4)]
    stacked = pt.tree_stack(trees)
    back = pt.tree_unstack(stacked)
    for t1, t2 in zip(trees, back):
        np.testing.assert_allclose(np.asarray(t1["w"]), np.asarray(t2["w"]))


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path, rng):
    tree = {"params": {"w": jax.random.normal(rng, (4, 4)),
                       "b": jnp.zeros((4,), jnp.bfloat16)},
            "opt": [{"m": jnp.ones((2,))}, {"v": jnp.ones((3,))}],
            "step": jnp.asarray(7, jnp.int32)}
    path = os.path.join(tmp_path, "ckpt.npz")
    save_pytree(path, tree, extra={"round": 3})
    loaded, extra = load_pytree(path, like=tree)
    assert extra["round"] == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_checkpoint_manager_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros((2,))}
    for step in (1, 2, 3, 4):
        mgr.save(step, tree)
    assert mgr.steps() == [3, 4]
    restored, extra = mgr.restore(tree)
    assert extra["step"] == 4


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "c.npz")
    save_pytree(path, {"w": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        load_pytree(path, like={"w": jnp.zeros((3,))})
