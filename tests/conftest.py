"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(only launch/dryrun.py forces 512 placeholder devices, in its own process).
"""
import _hypothesis_compat

# when the real hypothesis package is absent, install the deterministic
# replay shim BEFORE test modules import `from hypothesis import ...`
_hypothesis_compat.install()

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def nprng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
