"""Dependency-free fallback for ``hypothesis``.

The tier-1 suite uses hypothesis property tests, but the container does not
ship the package (and nothing may be pip-installed). Importing this module
(done in ``conftest.py``) installs a minimal stand-in into ``sys.modules``
*only when the real package is missing*: ``@given`` then replays each test
over a deterministic sample set (strategy bounds first, then seeded random
draws) instead of hypothesis' adaptive search. When hypothesis IS
installed, this module is a no-op and the real engine runs.

Only the strategy surface the suite uses is implemented: ``integers``,
``floats``, ``booleans``, ``sampled_from`` and ``lists`` — extend here if a
test needs more.
"""
from __future__ import annotations

import functools
import random
import sys
import types

N_RANDOM_EXAMPLES = 8          # per test, on top of the bounds examples


class _Strategy:
    """A sampleable value source: fixed edge examples + random draws."""

    def __init__(self, sampler, edges=()):
        self._sampler = sampler
        self._edges = tuple(edges)

    def edges(self):
        return self._edges

    def sample(self, rng: random.Random):
        return self._sampler(rng)

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._sampler(rng)),
                         tuple(fn(e) for e in self._edges))


def _integers(min_value=0, max_value=100):
    return _Strategy(lambda rng: rng.randint(min_value, max_value),
                     edges=(min_value, max_value))


def _floats(min_value=0.0, max_value=1.0, allow_nan=False,
            allow_infinity=False, width=64):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                     edges=(min_value, max_value))


def _booleans():
    return _Strategy(lambda rng: rng.random() < 0.5, edges=(False, True))


def _sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements),
                     edges=tuple(elements[:2]))


def _lists(elements: _Strategy, min_size=0, max_size=10, unique=False):
    def sample(rng):
        size = rng.randint(min_size, max_size)
        out = []
        seen = set()
        attempts = 0
        while len(out) < size:
            attempts += 1
            if attempts > 100 * max(1, size):
                raise ValueError(
                    "could not draw a unique list: element domain smaller "
                    f"than requested size {size}")
            v = elements.sample(rng)
            if unique:
                if v in seen:
                    continue
                seen.add(v)
            out.append(v)
        return out

    edges = tuple([e] * max(1, min_size) for e in elements.edges()
                  if min_size <= max(1, min_size) <= max_size)
    return _Strategy(sample, edges=edges)


def _tuples(*strategies):
    return _Strategy(lambda rng: tuple(s.sample(rng) for s in strategies))


def _just(value):
    return _Strategy(lambda rng: value, edges=(value,))


def _given(*arg_strategies, **kw_strategies):
    """Replay the test over bounds examples + seeded random draws.

    Mirrors hypothesis' argument mapping: keyword strategies bind by name,
    positional strategies fill the test's *rightmost* remaining parameters;
    anything left over stays in the signature for pytest fixtures.
    """

    def deco(fn):
        import inspect

        inner = getattr(fn, "_compat_inner", fn)
        params = list(inspect.signature(inner).parameters.values())
        names = [p.name for p in params]
        remaining = [n for n in names if n not in kw_strategies]
        pos_names = remaining[len(remaining) - len(arg_strategies):] \
            if arg_strategies else []
        fixture_params = [p for p in params
                          if p.name not in kw_strategies
                          and p.name not in pos_names]
        strategy_map = dict(zip(pos_names, arg_strategies))
        strategy_map.update(kw_strategies)

        @functools.wraps(inner)
        def wrapper(**fixture_kwargs):
            # honor @settings(max_examples=...) as an upper bound on total
            # runs (read at call time so decorator order doesn't matter)
            budget = getattr(wrapper, "_compat_max_examples", None) \
                or getattr(fn, "_compat_max_examples", None)
            rng = random.Random(0)
            keys = list(strategy_map)
            strategies = [strategy_map[k] for k in keys]
            runs = []
            # all-min / all-max style edge combinations (zip, not product,
            # to keep the run count linear in the edge count)
            n_edges = max((len(s.edges()) for s in strategies), default=0)
            for i in range(n_edges):
                runs.append([
                    s.edges()[min(i, len(s.edges()) - 1)]
                    if s.edges() else s.sample(rng)
                    for s in strategies])
            for _ in range(N_RANDOM_EXAMPLES):
                runs.append([s.sample(rng) for s in strategies])
            if budget:
                runs = runs[:max(1, budget)]
            for values in runs:
                inner(**fixture_kwargs, **dict(zip(keys, values)))

        wrapper.__signature__ = inspect.Signature(fixture_params)
        return wrapper

    return deco


def _settings(max_examples=None, deadline=None, **_ignored):
    """Record the example budget; ``given`` caps its run count with it
    (read at call time, so decorator order doesn't matter)."""

    def deco(fn):
        fn._compat_max_examples = max_examples
        fn._compat_inner = getattr(fn, "_compat_inner", fn)
        return fn

    return deco


def _assume(condition) -> bool:
    if not condition:
        import pytest
        pytest.skip("assumption not satisfied (hypothesis shim)")
    return True


def install() -> bool:
    """Install the shim iff hypothesis is unavailable. Returns True when
    the shim is active."""
    try:
        import hypothesis  # noqa: F401
        return False
    except ImportError:
        pass
    mod = types.ModuleType("hypothesis")
    mod.given = _given
    mod.settings = _settings
    mod.assume = _assume
    mod.example = lambda *a, **k: (lambda fn: fn)
    mod.HealthCheck = types.SimpleNamespace(all=lambda: [])
    st = types.ModuleType("hypothesis.strategies")
    st.integers = _integers
    st.floats = _floats
    st.booleans = _booleans
    st.sampled_from = _sampled_from
    st.lists = _lists
    st.tuples = _tuples
    st.just = _just
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
    return True
