"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps in interpret
mode (the kernel bodies execute on CPU through the Pallas interpreter)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,h,kv,sq,sk,hd", [
    (1, 4, 4, 128, 128, 32),     # MHA square
    (2, 8, 2, 128, 128, 64),     # GQA 4:1
    (1, 4, 1, 256, 256, 32),     # MQA
    (1, 2, 2, 128, 384, 32),     # cross lengths (prefix cache)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(rng, b, h, kv, sq, sk, hd, dtype):
    kq, kk, kv_ = jax.random.split(rng, 3)
    q = _rand(kq, (b, h, sq, hd), dtype)
    k = _rand(kk, (b, kv, sk, hd), dtype)
    v = _rand(kv_, (b, kv, sk, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [0, 64, 128])
def test_flash_attention_causal_window(rng, window):
    kq, kk, kv_ = jax.random.split(rng, 3)
    q = _rand(kq, (1, 4, 256, 32), jnp.float32)
    k = _rand(kk, (1, 2, 256, 32), jnp.float32)
    v = _rand(kv_, (1, 2, 256, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_matches_model_blockwise(rng):
    """The Pallas kernel and the model's lax.scan blockwise attention agree."""
    from repro.models.attention import blockwise_attention
    kq, kk, kv_ = jax.random.split(rng, 3)
    q = _rand(kq, (2, 8, 128, 32), jnp.float32)
    k = _rand(kk, (2, 4, 128, 32), jnp.float32)
    v = _rand(kv_, (2, 4, 128, 32), jnp.float32)
    pos = jnp.arange(128)
    got = blockwise_attention(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3),
                              pos, pos, window=0, k_chunk=32)
    want = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(got.transpose(0, 2, 1, 3)),
                               np.asarray(want), atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,s,d,chunk,block_d", [
    (1, 128, 128, 64, 64),
    (2, 96, 256, 32, 128),       # s not a multiple of chunk request
    (3, 64, 192, 64, 128),       # d not a multiple of block request
])
def test_rglru_scan_shapes(rng, b, s, d, chunk, block_d):
    ka, kb, kh = jax.random.split(rng, 3)
    a = jax.random.uniform(ka, (b, s, d), minval=0.4, maxval=0.999)
    bb = jax.random.normal(kb, (b, s, d))
    h0 = jax.random.normal(kh, (b, d))
    out = ops.rglru_scan(a, bb, h0, chunk=chunk, block_d=block_d)
    want = ref.rglru_scan_ref(a, bb, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_rglru_scan_matches_associative_scan(rng):
    from repro.models.recurrent import rglru_scan as model_scan
    # build gates through the real parameterization and compare paths
    d = 64
    p = {
        "w_a": jax.random.normal(rng, (d, d)) * 0.05,
        "b_a": jnp.zeros((d,)),
        "w_x": jax.random.normal(jax.random.fold_in(rng, 1), (d, d)) * 0.05,
        "b_x": jnp.zeros((d,)),
        "lam": jnp.ones((d,)),
    }
    xi = jax.random.normal(jax.random.fold_in(rng, 2), (2, 32, d))
    h0 = jnp.zeros((2, d))
    hs, _ = model_scan(p, xi, h0)
    from repro.models.recurrent import rglru_gates
    a, b = rglru_gates(p, xi)
    b = b.at[:, 0].add(a[:, 0] * h0)
    got = ops.rglru_scan(a, b, jnp.zeros((2, d)), chunk=16, block_d=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(hs),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# sLSTM recurrence (VMEM-resident R — §Perf pair 2)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,s,h,hd,chunk", [
    (1, 64, 4, 32, 16),
    (2, 96, 2, 64, 32),      # s not a multiple of requested chunk
    (2, 32, 1, 128, 32),     # single head
])
def test_slstm_scan_kernel(rng, b, s, h, hd, chunk):
    d = h * hd
    k1, k2 = jax.random.split(rng)
    wx = jax.random.normal(k1, (b, s, 4 * d)) * 0.5
    r = jax.random.normal(k2, (4, h, hd, hd)) * (hd ** -0.5)
    h0 = jnp.zeros((b, d))
    c0 = jnp.zeros((b, d))
    n0 = jnp.zeros((b, d))
    m0 = jnp.full((b, d), -1e30)
    hs, state = ops.slstm_scan(wx, r, h0, c0, n0, m0, chunk=chunk)
    hs_ref, state_ref = ref.slstm_scan_ref(wx, r, h0, c0, n0, m0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hs_ref),
                               atol=2e-5, rtol=2e-5)
    for a, b_ in zip(state, state_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=2e-5, rtol=2e-5)


def test_slstm_kernel_matches_model_block(rng):
    """The kernel path reproduces the model's _slstm_step scan exactly
    (same gate math through the real parameterization)."""
    from repro.models import xlstm as xl
    from repro.configs import get_config
    cfg = get_config("xlstm-125m", reduced=True).replace(
        compute_dtype="float32")
    d = cfg.d_model
    p = xl.slstm_init(rng, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (2, 32, d)) * 0.3
    # model path
    out_model, _ = xl.slstm_block_apply(p, cfg, x, cache=None)
    # kernel path: wx = x @ w_in + b_in, then the recurrence
    wx = x @ p["w_in"] + p["b_in"]
    h0 = jnp.zeros((2, d))
    m0 = jnp.full((2, d), -1e30)
    hs, _ = ops.slstm_scan(wx, p["r"], h0, h0, h0, m0)
    # re-apply the block's output path (norm + gated MLP)
    from repro.models import nn
    hs_n = nn.rmsnorm_apply({"scale": p["norm_scale"]}, hs.astype(x.dtype))
    up = hs_n @ p["w_up"]
    g, u = jnp.split(up, 2, axis=-1)
    want = (nn.gelu(g) * u) @ p["w_down"]
    np.testing.assert_allclose(np.asarray(out_model), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# fused CC-FedAvg round update
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,p,block", [
    (4, 512, 128),
    (8, 1000, 256),      # p not a multiple of requested block
    (1, 256, 256),       # single client
    (3, 509, 512),       # prime P < block: pad-to-tile fallback regression
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cc_delta_update(rng, n, p, block, dtype):
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    locals_ = _rand(k1, (n, p), dtype)
    deltas = _rand(k2, (n, p), dtype)
    globals_ = _rand(k3, (p,), dtype)
    train = (jax.random.uniform(k4, (n,)) > 0.5).astype(jnp.float32)
    sel = jnp.ones((n,), jnp.float32)
    d1, g1 = ops.cc_delta_update(locals_, deltas, globals_, train, sel,
                                 block=block)
    d2, g2 = ref.cc_delta_update_ref(locals_, deltas, globals_, train, sel)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(d1, np.float32),
                               np.asarray(d2, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(g1, np.float32),
                               np.asarray(g2, np.float32), atol=tol)


def test_cc_delta_update_equals_engine_round(rng):
    """The fused kernel computes the same update as Algorithm 1 in the
    engine (strategy='cc', all clients selected)."""
    n, p = 4, 256
    k1, k2, k3 = jax.random.split(rng, 3)
    globals_ = jax.random.normal(k1, (p,))
    locals_ = globals_[None] + 0.1 * jax.random.normal(k2, (n, p))
    deltas = 0.05 * jax.random.normal(k3, (n, p))
    train = jnp.array([1.0, 0.0, 1.0, 0.0])
    sel = jnp.ones((n,))
    d_new, g_new = ops.cc_delta_update(locals_, deltas, globals_, train, sel)
    # manual Algorithm 1: Δ_i = train ? local-g : Δ_{t-1}; x' = x + mean Δ
    want_d = jnp.where(train[:, None] > 0, locals_ - globals_[None], deltas)
    want_g = globals_ + jnp.mean(want_d, axis=0)
    np.testing.assert_allclose(np.asarray(d_new), np.asarray(want_d),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(g_new), np.asarray(want_g),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# strategy-parameterized epilogue update + int8 (q8) quantized history
# ---------------------------------------------------------------------------


def _epilogue_case(rng, n, p, *, with_stale):
    """Random FusedEpilogue-shaped inputs (coefficients in strategy range)."""
    ks = jax.random.split(rng, 9)
    locals_ = jax.random.normal(ks[0], (n, p))
    deltas = 0.1 * jax.random.normal(ks[1], (n, p))
    globals_ = jax.random.normal(ks[2], (p,))
    train = (jax.random.uniform(ks[3], (n,)) > 0.5).astype(jnp.float32)
    agg_w = jax.random.uniform(ks[4], (n,))
    e_replay = jax.random.uniform(ks[5], (n,))
    e_stale = (jax.random.uniform(ks[6], (n,)) if with_stale
               else jnp.zeros((n,)))
    store_scale = jax.random.uniform(ks[7], (n,), minval=0.5, maxval=1.0)
    stale = (0.05 * jax.random.normal(ks[8], (n, p)) if with_stale
             else None)
    denom = jnp.maximum(jnp.sum(agg_w), jnp.float32(1e-12))
    post = jnp.float32(1.25)
    return (locals_, deltas, globals_, train, train, agg_w, e_replay,
            e_stale, store_scale, denom, post, stale)


@pytest.mark.parametrize("n,p,block", [
    (4, 512, 128),
    (8, 1000, 256),
    (3, 509, 512),       # prime P < block
])
@pytest.mark.parametrize("with_stale", [False, True])
def test_cc_epilogue_update_bit_exact_vs_ref(rng, n, p, block, with_stale):
    """The epilogue kernel is pinned BIT-EXACT against the unrolled
    sequential reference — refs are compared under jit (eager XLA makes
    different mul+add contraction choices and is 1 ulp off)."""
    case = _epilogue_case(rng, n, p, with_stale=with_stale)
    d1, g1 = ops.cc_epilogue_update(*case, block=block, interpret=True)
    d2, g2 = jax.jit(ref.cc_epilogue_update_ref)(*case)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))


def test_cc_epilogue_identity_equals_legacy_kernel(rng):
    """The legacy 5-arg op is exactly the identity epilogue: agg_w=sel,
    e_replay=1, e_stale=0, store_scale=1, denom=1e-9+Σsel, post=1."""
    n, p = 4, 512
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    locals_ = jax.random.normal(k1, (n, p))
    deltas = 0.1 * jax.random.normal(k2, (n, p))
    globals_ = jax.random.normal(k3, (p,))
    train = (jax.random.uniform(k4, (n,)) > 0.5).astype(jnp.float32)
    sel = jnp.ones((n,), jnp.float32)
    d1, g1 = ops.cc_delta_update(locals_, deltas, globals_, train, sel,
                                 interpret=True)
    d2, g2 = ops.cc_epilogue_update(
        locals_, deltas, globals_, train, train, sel, jnp.ones((n,)),
        jnp.zeros((n,)), jnp.ones((n,)), 1e-9 + jnp.sum(sel),
        jnp.float32(1.0), interpret=True)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))


def _q8_case(rng, n, p, *, with_stale):
    from repro.core.compress import quantize_rows
    case = _epilogue_case(rng, n, p, with_stale=with_stale)
    locals_, deltas = case[0], case[1]
    payload, scales = quantize_rows(deltas)
    return (locals_, payload, scales) + case[2:]


@pytest.mark.parametrize("n,p,block", [
    (4, 512, 128),
    (8, 1000, 256),
    (3, 509, 512),       # prime P < block
])
@pytest.mark.parametrize("with_stale", [False, True])
def test_cc_delta_update_q8_bit_exact_vs_ref(rng, n, p, block, with_stale):
    """The int8 dequant→select/aggregate→requant kernel is pinned
    BIT-EXACT (payload, scales AND aggregated global) against the
    sequential quantized reference, compared under jit."""
    import functools
    from repro.kernels.cc_delta_update_q8 import cc_delta_update_q8_fwd
    case = _q8_case(rng, n, p, with_stale=with_stale)
    q1, s1, g1 = jax.jit(functools.partial(
        cc_delta_update_q8_fwd, block=block, interpret=True))(*case)
    q2, s2, g2 = jax.jit(ref.cc_delta_update_q8_ref)(*case)
    assert q1.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))


@pytest.mark.parametrize("with_stale", [False, True])
def test_cc_delta_update_q8_jnp_matches_pallas(rng, with_stale):
    """The vectorized XLA path (what ``ops.cc_delta_update_q8`` dispatches
    to off-TPU) produces bit-identical payload/scales to the Pallas
    kernel; only the f32 summation order of the global differs."""
    import functools
    from repro.kernels.cc_delta_update_q8 import (cc_delta_update_q8_fwd,
                                                  cc_delta_update_q8_jnp)
    case = _q8_case(rng, 6, 640, with_stale=with_stale)
    q1, s1, g1 = jax.jit(functools.partial(
        cc_delta_update_q8_fwd, block=256, interpret=True))(*case)
    q2, s2, g2 = jax.jit(cc_delta_update_q8_jnp)(*case)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-6)


@pytest.mark.parametrize("with_stale", [False, True])
def test_q8_chunked_row_maxima_path_bit_exact(rng, with_stale):
    """Above ``_MX_MIN_COLS`` the jnp path switches to the chunked
    accumulator row-maxima (with upd-row skipping and a strided tail) —
    max is exactly associative, so payload/scales must stay bit-identical
    to the plain-reduce formula and to the Pallas kernel."""
    import functools
    from repro.kernels import cc_delta_update_q8 as q8
    n, p = 5, q8._MX_MIN_COLS + 509        # chunk loop + odd tail
    assert p >= q8._MX_MIN_COLS
    case = list(_q8_case(rng, n, p, with_stale=with_stale))
    case[5] = jnp.array([1.0, 0.0, 1.0, 1.0, 0.0])       # upd mix: skip path
    q1, s1, g1 = jax.jit(functools.partial(
        q8.cc_delta_update_q8_fwd, block=16384, interpret=True))(*case)
    q2, s2, g2 = jax.jit(q8.cc_delta_update_q8_jnp)(*case)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-6)
    # and the chunked maxima themselves equal the plain reduce, bit for bit
    mx_plain = jnp.max(jnp.abs(case[0] - case[3][None]), axis=1)
    mx_chunk = jax.jit(q8._row_maxima)(case[0], case[3], case[5])
    upd = np.asarray(case[5]) > 0
    np.testing.assert_array_equal(np.asarray(mx_chunk)[upd],
                                  np.asarray(mx_plain)[upd])


def test_q8_non_update_rows_keep_payload(rng):
    """Rows with upd=0 must keep their int8 payload byte-identical (no
    requantization drift round over round) — only the scale is folded by
    ``store_scale`` (the decay-in-scale trick)."""
    n, p = 4, 512
    (locals_, payload, scales, _, _, _, agg_w, e_replay, e_stale,
     _, denom, post, _) = _q8_case(rng, n, p, with_stale=False)
    upd = jnp.array([1.0, 0.0, 1.0, 0.0])
    store = jnp.array([1.0, 0.9, 1.0, 1.0])
    q, s, _ = ops.cc_delta_update_q8(
        locals_, payload, scales, jnp.zeros((p,)), upd, upd, agg_w,
        e_replay, e_stale, store, denom, post)
    np.testing.assert_array_equal(np.asarray(q[1]), np.asarray(payload[1]))
    np.testing.assert_array_equal(np.asarray(q[3]), np.asarray(payload[3]))
    np.testing.assert_allclose(np.asarray(s[1]),
                               np.asarray(scales[1]) * 0.9, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(s[3]), np.asarray(scales[3]))
    assert not np.array_equal(np.asarray(q[0]), np.asarray(payload[0]))
