"""Two-tier topology layer: invariants, resume, and per-tier accounting.

Property-based pins (through the hypothesis shim when the real package is
absent):

* every client lands in exactly one edge and no edge is empty, for every
  assignment scheme and any (N, E);
* the nested-mean identity that justifies the sync-round design: the
  edge-mass-weighted mean of per-edge masked means equals the flat global
  masked mean for ANY mask;
* assignments are pure functions of their spec fields, so a resumed
  session rebuilds the identical topology.

Plus the PR-4-style stateful-policy pin for the hierarchical executor —
a mid-edge-period save/restore with EnergyAware continues bit-identically
including the edge-tier carry and the ledger — and the quantized-upload
wiring of ``core/compress.py`` into ``Session.cost_report``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import ExperimentSpec, Session
from repro.checkpoint.store import (FED_STATE_KEYS, HIER_STATE_KEYS,
                                    POLICY_STATE_KEYS)
from repro.core.compress import (dequantize_tree, quantize_tree,
                                 quantization_error, tier_upload_report)
from repro.core.hierarchy import (TOPOLOGY_KINDS, EdgeTopology, edge_mass,
                                  edge_masked_means, edge_weighted_mean)
from repro.system.devices import edge_scaled_profile, make_profile
from repro.utils.pytree import tree_masked_mean


def hier_spec(**kw) -> ExperimentSpec:
    base = dict(dataset="gaussian", n_samples=256, dim=8, n_classes=4,
                n_clients=8, partition="gamma", gamma=0.5, budget="power",
                beta=2, model="mlp", width=4, strategy="cc", local_steps=2,
                batch_size=16, lr=0.1, schedule="adhoc", rounds=8,
                eval_every=4, seed=0, executor="hierarchical",
                topology="contiguous", n_edges=4, edge_period=2)
    base.update(kw)
    return ExperimentSpec(**base)


# ---------------------------------------------------------------------------
# topology invariants (property-based)
# ---------------------------------------------------------------------------


@settings(max_examples=24, deadline=None)
@given(n_clients=st.integers(1, 40), n_edges=st.integers(1, 40),
       kind=st.sampled_from(TOPOLOGY_KINDS))
def test_every_client_in_exactly_one_edge(n_clients, n_edges, kind):
    if n_edges > n_clients:
        n_edges = n_clients
    topo = EdgeTopology.make(kind, n_clients, n_edges, edge_period=1)
    a = topo.assignment
    assert a.shape == (n_clients,)
    assert ((0 <= a) & (a < n_edges)).all()        # one edge id per client
    sizes = topo.edge_sizes
    assert sizes.sum() == n_clients                # ... and only one
    assert (sizes >= 1).all()                      # no empty edges
    # member masks partition the federation
    total = np.zeros(n_clients, int)
    for e in range(n_edges):
        total += topo.member_mask(e).astype(int)
    assert (total == 1).all()


@settings(max_examples=16, deadline=None)
@given(n_clients=st.integers(2, 24), n_edges=st.integers(1, 6),
       mask_seed=st.integers(0, 10_000), assign_seed=st.integers(0, 10_000))
def test_edge_weighted_mean_of_edge_means_is_global_masked_mean(
        n_clients, n_edges, mask_seed, assign_seed):
    """The identity the sync round is built on: weighting each edge by its
    aggregation mass makes the nested client→edge→server mean equal the
    flat masked mean — for any mask, including masks that silence whole
    edges."""
    if n_edges > n_clients:
        n_edges = n_clients
    rng = np.random.default_rng(assign_seed)
    # arbitrary total assignment (every edge nonempty via seeding a perm)
    a = np.concatenate([np.arange(n_edges),
                        rng.integers(0, n_edges, n_clients - n_edges)])
    rng.shuffle(a)
    mask = np.random.default_rng(mask_seed).random(n_clients) < 0.6
    tree = {"w": jnp.asarray(
        np.random.default_rng(mask_seed + 1).normal(
            size=(n_clients, 3, 2)), jnp.float32)}
    nested = edge_weighted_mean(
        edge_masked_means(tree, jnp.asarray(mask), a, n_edges),
        edge_mass(jnp.asarray(mask), a, n_edges))
    flat = tree_masked_mean(tree, jnp.asarray(mask, jnp.float32))
    np.testing.assert_allclose(np.asarray(nested["w"]),
                               np.asarray(flat["w"]), atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(n_edges=st.integers(1, 8), edge_period=st.integers(1, 5),
       kind=st.sampled_from(TOPOLOGY_KINDS))
def test_assignment_stable_under_rebuild(n_edges, edge_period, kind):
    """Topologies are pure functions of their spec fields — the property a
    resumed session relies on to rebuild the identical client→edge map."""
    a = EdgeTopology.make(kind, 16, n_edges, edge_period)
    b = EdgeTopology.make(kind, 16, n_edges, edge_period)
    np.testing.assert_array_equal(a.assignment, b.assignment)
    assert a.n_edges == b.n_edges and a.edge_period == b.edge_period


def test_topology_validation_errors():
    with pytest.raises(ValueError, match="unknown topology"):
        EdgeTopology.make("ring", 8, 2)
    with pytest.raises(ValueError, match="n_edges"):
        EdgeTopology.contiguous(4, 5)
    with pytest.raises(ValueError, match="edge_period"):
        EdgeTopology.contiguous(4, 2, edge_period=0)
    with pytest.raises(ValueError, match="empty"):
        EdgeTopology(np.zeros(4, np.int32), n_edges=2)
    with pytest.raises(ValueError, match="ids must lie"):
        EdgeTopology(np.array([0, 1, 2, 3]), n_edges=2)
    with pytest.raises(ValueError, match="edge must be"):
        EdgeTopology.contiguous(4, 2).member_mask(2)


def test_contiguous_uniform_detection():
    assert EdgeTopology.contiguous(8, 4).is_contiguous_uniform
    assert EdgeTopology.contiguous(8, 1).is_contiguous_uniform
    assert not EdgeTopology.contiguous(7, 2).is_contiguous_uniform  # 4+3
    assert not EdgeTopology.striped(8, 4).is_contiguous_uniform
    assert EdgeTopology.striped(8, 1).is_contiguous_uniform  # E=1 is both


def test_sync_count():
    topo = EdgeTopology.contiguous(8, 2, edge_period=3)
    assert [topo.sync_count(t) for t in range(8)] == [0, 0, 0, 1, 1, 1, 2, 2]
    with pytest.raises(ValueError, match="rounds_done"):
        topo.sync_count(-1)


# ---------------------------------------------------------------------------
# spec v3: topology fields round-trip + validation
# ---------------------------------------------------------------------------


def test_spec_topology_round_trip(tmp_path):
    spec = hier_spec(edge_speed=(1.0, 0.5, 2.0, 1.0),
                     edge_harvest=(1.0, 1.0, 0.25, 1.0))
    back = ExperimentSpec.from_dict(spec.to_dict())
    assert back == spec
    path = spec.save(str(tmp_path / "spec.json"))
    assert ExperimentSpec.load(path) == spec
    topo = spec.edge_topology()
    assert topo.n_edges == 4 and topo.edge_period == 2
    np.testing.assert_array_equal(topo.assignment,
                                  spec.edge_topology().assignment)


def test_spec_v2_json_still_loads():
    """Pre-topology specs (no v3 fields) load with flat defaults."""
    d = hier_spec().to_dict()
    for f in ("topology", "n_edges", "edge_period", "edge_speed",
              "edge_harvest"):
        d.pop(f)
    d.update(spec_version=2, executor="scan")
    spec = ExperimentSpec.from_dict(d)
    assert spec.topology == "flat" and spec.edge_topology() is None


def test_spec_topology_validation():
    with pytest.raises(ValueError, match="topology"):
        hier_spec(topology="ring")
    with pytest.raises(ValueError, match="hierarchical"):
        hier_spec(executor="scan")                 # topology w/o executor
    with pytest.raises(ValueError, match="hierarchical"):
        hier_spec(topology="flat", n_edges=1, edge_period=1)
    with pytest.raises(ValueError, match="n_edges"):
        hier_spec(n_edges=9)
    with pytest.raises(ValueError, match="edge_period"):
        hier_spec(edge_period=0)
    with pytest.raises(ValueError, match="non-flat"):
        ExperimentSpec(n_edges=2)
    with pytest.raises(ValueError, match="edge_speed"):
        hier_spec(edge_speed=(1.0, 2.0))           # wrong length
    with pytest.raises(ValueError, match="edge_harvest"):
        hier_spec(edge_harvest=(1.0, 0.0, 1.0, 1.0))
    with pytest.raises(ValueError, match="use_fused"):
        hier_spec(use_fused=True)


def test_session_rejects_topology_mismatch():
    spec = hier_spec()
    b = spec.build()
    with pytest.raises(ValueError, match="EdgeTopology"):
        Session(b.model, b.data, b.fed, b.plan, executor="hierarchical")
    with pytest.raises(ValueError, match="hierarchical"):
        Session(b.model, b.data, b.fed, b.plan, topology=b.topology)


def test_edge_scaled_profile():
    p = np.full(6, 0.5)
    base = make_profile("budget", p, seed=0)
    topo = EdgeTopology.contiguous(6, 3)
    prof = edge_scaled_profile(base, topo.assignment,
                               flops_scale=(1.0, 2.0, 0.5),
                               harvest_scale=(1.0, 1.0, 0.25))
    np.testing.assert_allclose(np.asarray(prof.flops_rate),
                               np.repeat([0.5, 1.0, 0.25], 2))
    np.testing.assert_allclose(np.asarray(prof.harvest),
                               np.repeat([0.5, 0.5, 0.125], 2))
    # untouched families stay identical
    np.testing.assert_array_equal(np.asarray(prof.train_cost),
                                  np.asarray(base.train_cost))
    with pytest.raises(ValueError, match="one entry per edge"):
        edge_scaled_profile(base, topo.assignment, flops_scale=(1.0,))
    with pytest.raises(ValueError, match="> 0"):
        edge_scaled_profile(base, topo.assignment,
                            harvest_scale=(1.0, -1.0, 1.0))


def test_session_builds_edge_scaled_profile():
    spec = hier_spec(n_edges=2, edge_speed=(1.0, 0.5))
    sess = Session.from_spec(spec)
    rate = np.asarray(sess.profile.flops_rate)
    base = np.asarray(make_profile("budget", spec.budgets(),
                                   seed=spec.seed).flops_rate)
    np.testing.assert_allclose(rate[:4], base[:4])
    np.testing.assert_allclose(rate[4:], 0.5 * base[4:])


# ---------------------------------------------------------------------------
# mid-edge-period resume with a stateful policy (the PR-4 pin, two-tier)
# ---------------------------------------------------------------------------


def test_hier_resume_stateful_policy_matches_uninterrupted(tmp_path):
    """Kill-and-restore in the MIDDLE of an edge period with EnergyAware:
    the edge-tier carry (accumulated edge displacements), the policy's
    device state and the energy ledger must all continue bit-identically —
    a resume that restarted ``edge_params`` from the global model would
    silently rewind the current period."""
    spec = hier_spec(n_edges=2, edge_period=3, policy="energy", rounds=10,
                     eval_every=3, load_mean=0.3, load_jitter=0.2,
                     energy_init=1.0)
    full = Session.from_spec(spec).run()

    part = Session.from_spec(spec, ckpt_dir=str(tmp_path))
    part.run(4)                  # 4 % 3 != 0 → mid-period interrupt
    part.save()
    del part

    resumed = Session.restore_from(str(tmp_path))
    assert resumed.t == 4
    # the checkpoint carried live edge displacement (mid-period ≠ global)
    mid_edge = jax.tree.leaves(resumed.state["edge_params"])[0]
    assert not np.array_equal(
        np.asarray(mid_edge)[0],
        np.asarray(jax.tree.leaves(resumed.state["params"])[0]))
    resumed.run()
    assert resumed.metrics.history == full.metrics.history
    keys = FED_STATE_KEYS + POLICY_STATE_KEYS + HIER_STATE_KEYS
    for key in keys:
        for a, b in zip(jax.tree.leaves(resumed.state[key]),
                        jax.tree.leaves(full.state[key])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=key)


def test_hier_checkpoint_carries_edge_tier(tmp_path):
    spec = hier_spec(rounds=4, eval_every=4)
    sess = Session.from_spec(spec, ckpt_dir=str(tmp_path))
    sess.run(3)                  # mid-period (edge_period=2)
    path = sess.save()
    with np.load(path) as z:
        keys = set(z.files)
    assert any(k.startswith("edge_params/") for k in keys)
    # restore_from rebuilds the identical topology purely from the spec
    resumed = Session.restore_from(str(tmp_path))
    np.testing.assert_array_equal(resumed.topology.assignment,
                                  sess.topology.assignment)
    for a, b in zip(jax.tree.leaves(resumed.state["edge_params"]),
                    jax.tree.leaves(sess.state["edge_params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# quantized uploads: round-trip + per-tier cost accounting
# ---------------------------------------------------------------------------


def test_quantize_roundtrip_on_live_session_deltas():
    """The in-loop wiring regression for ``core/compress.py``: quantizing
    the Δ history a real session produced round-trips with small relative
    error, preserves structure/shape/dtype, and keeps exact zeros exact."""
    sess = Session.from_spec(hier_spec(rounds=4, eval_every=4)).run()
    deltas = sess.state["deltas"]
    q = quantize_tree(deltas)
    back = dequantize_tree(q)
    assert jax.tree.structure(back) == jax.tree.structure(deltas)
    for orig, rec, pay in zip(jax.tree.leaves(deltas),
                              jax.tree.leaves(back),
                              jax.tree.leaves(q.payload)):
        assert pay.dtype == jnp.int8
        assert rec.shape == orig.shape and rec.dtype == orig.dtype
        scale = np.abs(np.asarray(orig)).max() / 127.0
        np.testing.assert_allclose(np.asarray(rec), np.asarray(orig),
                                   atol=scale * 0.51)
        # untrained clients' rows are exact zeros and stay exact
        zeros = np.asarray(orig) == 0.0
        assert (np.asarray(rec)[zeros] == 0.0).all()
    assert quantization_error(deltas) < 0.02


def test_cost_report_tiers():
    spec = hier_spec(n_edges=4, edge_period=2, rounds=8, eval_every=8,
                     schedule="full")
    sess = Session.from_spec(spec).run()
    rep = sess.cost_report()
    model_bytes = rep["upload_bytes"] // (8 * 8)   # full: N×T uploads
    tiers = rep["tiers"]
    assert tiers["client_to_edge_bytes"] == rep["upload_bytes"]
    # 8 rounds / period 2 → 4 syncs × 4 edges
    assert tiers["edge_to_server_bytes"] == 4 * 4 * model_bytes
    assert tiers["client_to_edge_bytes_int8"] == rep["upload_bytes"] // 4
    assert tiers["edge_to_server_bytes_int8"] == \
        tiers["edge_to_server_bytes"] // 4
    assert rep["upload_bytes_int8"] == rep["upload_bytes"] // 4


def test_cost_report_flat_has_no_tiers_but_int8():
    sess = Session.from_spec(hier_spec(
        executor="scan", topology="flat", n_edges=1, edge_period=1,
        rounds=2, eval_every=2)).run()
    rep = sess.cost_report()
    assert "tiers" not in rep
    assert rep["upload_bytes_int8"] == rep["upload_bytes"] // 4


def test_tier_upload_report_validation():
    with pytest.raises(ValueError, match="n_syncs"):
        tier_upload_report(client_upload_bytes=10, n_syncs=-1, n_edges=2,
                           model_bytes=4)


# ---------------------------------------------------------------------------
# CLI: topology shorthands
# ---------------------------------------------------------------------------


def test_cli_runs_hierarchical_spec(tmp_path, capsys):
    import json

    from repro.api.cli import main as cli_main
    spec_path = str(tmp_path / "spec.json")
    assert cli_main(["init", spec_path, "--set", "rounds=2",
                     "--set", "eval_every=2", "--set", "n_samples=256",
                     "--set", "dim=8", "--set", "n_classes=4",
                     "--set", "n_clients=4", "--set", "width=4",
                     "--set", "local_steps=2"]) == 0
    assert cli_main(["run", spec_path, "--quiet",
                     "--topology", "contiguous", "--edges", "2",
                     "--edge-period", "2",
                     "--set", "executor=hierarchical"]) == 0
    summary = json.loads(capsys.readouterr().out.strip().splitlines()[-1])
    assert summary["rounds_done"] == 2
