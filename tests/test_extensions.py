"""Beyond-paper extensions: Δ compression + continuous-batching server."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.compress import (compressed_report, dequantize_tree,
                                 quantization_error, quantize_tree)
from repro.core.schedules import make_plan
from repro.models import decoder
from repro.serving import BatchingServer, Request


# ---------------------------------------------------------------------------
# Δ compression
# ---------------------------------------------------------------------------


def test_quantize_roundtrip_small_error(rng):
    tree = {"a": 0.01 * jax.random.normal(rng, (64, 32)),
            "b": {"c": 0.1 * jax.random.normal(rng, (128,))}}
    err = quantization_error(tree)
    assert err < 0.01           # int8 symmetric: ~0.4% RMS on gaussians


def test_quantize_payload_is_int8(rng):
    tree = {"w": jax.random.normal(rng, (16, 16))}
    q = quantize_tree(tree)
    assert all(leaf.dtype == jnp.int8 for leaf in jax.tree.leaves(q.payload))
    back = dequantize_tree(q)
    np.testing.assert_allclose(np.asarray(back["w"]),
                               np.asarray(tree["w"]), atol=0.02)


def test_quantized_aggregation_close_to_exact(rng):
    """mean(dequant(quant(Δ_i))) ≈ mean(Δ_i) — compression composes with
    the paper's unbiased aggregation."""
    deltas = [0.05 * jax.random.normal(jax.random.fold_in(rng, i), (256,))
              for i in range(4)]
    exact = jnp.mean(jnp.stack(deltas), 0)
    approx = jnp.mean(jnp.stack(
        [dequantize_tree(quantize_tree(d)) for d in deltas]), 0)
    assert float(jnp.linalg.norm(exact - approx)
                 / jnp.linalg.norm(exact)) < 0.01


def test_compressed_report():
    plan = make_plan("round_robin", np.array([1.0, 0.5]), 40, seed=0)
    rep = compressed_report(plan, model_bytes=4000)
    assert rep["upload_bytes_compressed"] == rep["upload_bytes"] // 4
    assert rep["compression_ratio"] == 4


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def server_setup(rng):
    cfg = get_config("qwen3-1.7b", reduced=True)
    params = decoder.model_init(rng, cfg)
    return cfg, params


def test_batching_server_completes_all_requests(server_setup, rng):
    cfg, params = server_setup
    srv = BatchingServer(cfg, params, n_slots=2, capacity=64)
    reqs = []
    for i in range(5):            # more requests than slots → queueing
        prompt = jax.random.randint(jax.random.fold_in(rng, i),
                                    (8 + 2 * i,), 0, cfg.vocab)
        r = Request(uid=i, prompt=prompt, max_new_tokens=4)
        reqs.append(r)
        srv.submit(r)
    srv.run(max_steps=100)
    for r in reqs:
        assert r.done
        assert len(r.generated) == 4
        assert all(0 <= t < cfg.vocab for t in r.generated)


def test_batching_server_matches_unbatched_greedy(server_setup, rng):
    """Tokens from the slot-based server equal plain greedy decoding of
    the same prompt (continuous batching must not change results)."""
    from repro.launch.serve import generate
    cfg, params = server_setup
    prompt = jax.random.randint(jax.random.fold_in(rng, 99), (12,),
                                0, cfg.vocab)
    want = [int(jax.device_get(t)[0]) for t in
            generate(cfg, params, prompt[None], gen=4)]
    srv = BatchingServer(cfg, params, n_slots=2, capacity=64)
    r = Request(uid=0, prompt=prompt, max_new_tokens=4)
    srv.submit(r)
    srv.run(max_steps=50)
    assert r.generated == want
